"""Scheduler study: four cohort policies under 30% stragglers, on
identical seeds, task, straggler profile, and (semi-async FedLesScan)
aggregation — only the `Scheduler` (fl/scheduler.py) varies:

    random      uniform sampling (FedAvg-style, straggler-blind)
    fedlesscan  Algorithm 2 tier selection (DBSCAN behaviour clusters)
    apodotiko   score-based softmax sampling (duration EMA, success
                rate, cold-start rate, staleness; annealed temperature)
    adaptive    trailing-EUR cohort sizing over random selection

Reported per policy: final accuracy, mean EUR, time-to-accuracy (first
virtual second the evaluated accuracy reaches --target), and total cost
from the CostMeter.  Acceptance: apodotiko's EUR must match or beat the
fedlesscan scheduler's on the same seeds.

    PYTHONPATH=src python examples/scheduler_study.py [--ratio 0.3]
"""
import argparse
from pathlib import Path

from repro.data import label_sorted_shards, make_image_classification
from repro.data.synthetic import ArrayDataset
from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                 run_experiment)
from repro.fl.metrics import time_to_accuracy
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import make_cnn

SCHEDULERS = ("random", "fedlesscan", "apodotiko", "adaptive")
OUT = Path(__file__).resolve().parent.parent / "results" / "scheduler_study"


def build_task(n_clients: int, seed: int = 0):
    full = make_image_classification(1300, image_size=14, n_classes=5,
                                     seed=seed)
    train = ArrayDataset(full.x[:1100], full.y[:1100])
    test = ArrayDataset(full.x[1100:], full.y[1100:])
    parts = label_sorted_shards(train, n_clients, 2, seed=seed)
    test_parts = label_sorted_shards(test, n_clients, 2, seed=seed)
    task = ClassificationTask(
        make_cnn(14, 1, 5, 32),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    return task, parts, test_parts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ratio", type=float, default=0.3)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--cohort", type=int, default=6)
    ap.add_argument("--eval-every", type=int, default=2)
    ap.add_argument("--target", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    task, parts, test_parts = build_task(args.clients, seed=args.seed)
    print(f"straggler ratio {int(args.ratio * 100)}%, {args.rounds} rounds "
          f"x cohort {args.cohort}, semi-async fedlesscan aggregation\n")
    print(f"{'scheduler':12s} {'acc':>6s} {'EUR':>5s} "
          f"{'t@{:.0%}'.format(args.target):>8s} {'time(s)':>8s} "
          f"{'cost($)':>8s}")

    results = {}
    for scheduler in SCHEDULERS:
        cfg = ExperimentConfig(
            strategy="fedlesscan", scheduler=scheduler,
            n_rounds=args.rounds, clients_per_round=args.cohort,
            eval_every=args.eval_every, seed=args.seed,
            trace_path=str(OUT / f"{scheduler}.jsonl"),
            scenario=ScenarioConfig(straggler_fraction=args.ratio,
                                    round_timeout_s=30.0, seed=args.seed))
        res = run_experiment(task, parts, test_parts, cfg)
        results[scheduler] = res
        tta = time_to_accuracy(res.accuracy_curve,
                               [r.duration_s for r in res.rounds],
                               args.target)
        tta_s = f"{tta:8.0f}" if tta != float("inf") else "     inf"
        print(f"{scheduler:12s} {res.final_accuracy:6.3f} "
              f"{res.mean_eur:5.2f} {tta_s} {res.total_duration_s:8.0f} "
              f"{res.total_cost:8.4f}")

    apo = results["apodotiko"].mean_eur
    fls = results["fedlesscan"].mean_eur
    ok = apo >= fls
    print(f"\napodotiko EUR {apo:.2f} {'>=' if ok else '<'} "
          f"fedlesscan EUR {fls:.2f} ({'ok' if ok else 'REGRESSION'})")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
