"""Federate an assigned architecture: a reduced mamba2 / gemma2 variant is
the FL payload — FedLesScan schedules clients whose local task is
next-token prediction on private token streams.

This is the bridge between the paper's orchestration layer and the
assigned-architecture model zoo: the same Strategy/controller/FaaS stack,
with the transformer train step as Client_Update's workload.

    PYTHONPATH=src python examples/federated_pretrain.py --arch mamba2-130m
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import ArrayDataset, make_token_lm
from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                 run_experiment)
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models import forward, init_params
from repro.models.small import ModelDef


def arch_as_model(arch_id: str) -> ModelDef:
    """Wrap a reduced assigned architecture as a next-token classifier
    (predict token at the last position)."""
    cfg = get_config(arch_id).reduced().replace(vocab=256)

    def init(rng):
        return init_params(cfg, rng)

    def apply(params, tokens):                       # (B, S) → (B, vocab)
        logits = forward(cfg, params, {"tokens": tokens})
        return logits[:, -1, :]

    return ModelDef(init, apply, f"{arch_id}-reduced-lm")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--stragglers", type=float, default=0.25)
    args = ap.parse_args()

    ds = make_token_lm(40_000, vocab=256, seq_len=32, seed=0)
    n = len(ds)
    cut = int(n * 0.85)
    train = ArrayDataset(ds.x[:cut], ds.y[:cut, -1])
    test = ArrayDataset(ds.x[cut:], ds.y[cut:, -1])

    rng = np.random.default_rng(0)
    order = rng.permutation(cut)
    shards = np.array_split(order, args.clients)
    parts = {f"client_{i}": ArrayDataset(train.x[s], train.y[s])
             for i, s in enumerate(shards)}
    test_parts = {f"client_{i}": test for i in range(args.clients)}

    model = arch_as_model(args.arch)
    task = ClassificationTask(
        model, TaskConfig(epochs=1, batch_size=16, learning_rate=1e-3,
                          per_sample_time_s=0.02))

    cfg = ExperimentConfig(
        strategy="fedlesscan", n_rounds=args.rounds, clients_per_round=4,
        eval_every=2,
        scenario=ScenarioConfig(straggler_fraction=args.stragglers,
                                round_timeout_s=60.0))
    res = run_experiment(task, parts, test_parts, cfg, verbose=True)
    print(f"\nfederated {args.arch}: final top-1 next-token acc "
          f"{res.final_accuracy:.3f}, EUR {res.mean_eur:.2f}, "
          f"cost ${res.total_cost:.4f}")


if __name__ == "__main__":
    main()
