"""Serving example: prefill a prompt batch, then decode tokens
autoregressively from the KV/SSM cache — the serve-side path that the
decode_32k / long_500k dry-run shapes lower at production scale.

    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b --new 8
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = args.batch, args.prompt_len

    key = jax.random.PRNGKey(1)
    shape = (B, cfg.n_codebooks, S) if cfg.n_codebooks else (B, S)
    prompt = jax.random.randint(key, shape, 0, cfg.vocab)
    batch = {"tokens": prompt}
    if cfg.n_patches:
        batch["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_patches, cfg.d_model)) * 0.1

    t0 = time.time()
    logits, cache = prefill(cfg, params, batch,
                            cache_len=S + args.new,
                            cache_dtype=jnp.float32)
    print(f"prefill: {S} tokens × {B} seqs in {time.time()-t0:.2f}s "
          f"(logits {tuple(logits.shape)})")

    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    tok = (prompt[..., -1:])
    generated = []
    t0 = time.time()
    for i in range(args.new):
        pos = jnp.full((B,), S + i, jnp.int32)
        logits, cache = step(params, cache, tok, pos)
        last = logits[:, -1, :cfg.vocab]
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, last / args.temperature)
        else:
            nxt = jnp.argmax(last, axis=-1)
        tok = (jnp.broadcast_to(nxt[:, None, None],
                                (B, cfg.n_codebooks, 1))
               if cfg.n_codebooks else nxt[:, None].astype(jnp.int32))
        generated.append(nxt)
    dt = time.time() - t0
    out = jnp.stack(generated, axis=1)
    print(f"decoded {args.new} tokens × {B} seqs in {dt:.2f}s "
          f"({args.new*B/dt:.1f} tok/s on CPU, reduced config)")
    print("generated ids:", out.tolist())


if __name__ == "__main__":
    main()
