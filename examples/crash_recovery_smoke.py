"""Crash-recovery smoke test: SIGKILL a training run mid-round, resume
from its last checkpoint, and assert the recovered run reproduces an
uninterrupted same-seed run exactly.

Exercises the full-fidelity checkpoint path end-to-end across *process*
boundaries (the checkpoint is written by a child process that is killed
without warning, the resume happens in the parent):

    1. run a clean same-seed reference in-process → metrics + trace;
    2. spawn the same experiment as a subprocess with checkpointing on,
       wait until a checkpoint pair lands on disk, SIGKILL the child;
    3. resume from the last checkpoint in-process and compare the final
       metrics (and the replayed rounds) with the clean reference.

CI runs this as the crash-recovery job and uploads the two JSONL traces
as artifacts when the comparison fails.

    PYTHONPATH=src python examples/crash_recovery_smoke.py
    PYTHONPATH=src python examples/crash_recovery_smoke.py --child out/
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

from repro.data import label_sorted_shards, make_image_classification
from repro.data.synthetic import ArrayDataset
from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                 run_experiment)
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import make_cnn

N_ROUNDS = 12
CHECKPOINT_EVERY = 2


def build_experiment():
    full = make_image_classification(320, image_size=14, n_classes=3, seed=0)
    train = ArrayDataset(full.x[:240], full.y[:240])
    test = ArrayDataset(full.x[240:], full.y[240:])
    parts = label_sorted_shards(train, 6, 2, seed=0)
    test_parts = label_sorted_shards(test, 6, 2, seed=0)
    task = ClassificationTask(
        make_cnn(14, 1, 3, 8),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    return task, parts, test_parts


def config(**kw) -> ExperimentConfig:
    return ExperimentConfig(
        strategy="fedlesscan", n_rounds=N_ROUNDS, clients_per_round=4,
        eval_every=0, seed=0,
        scenario=ScenarioConfig(straggler_fraction=0.3, slow_factor=6.0,
                                round_timeout_s=60.0, seed=0), **kw)


def run_child(workdir: Path) -> None:
    """Subprocess body: train with checkpointing until SIGKILLed."""
    task, parts, test_parts = build_experiment()
    run_experiment(task, parts, test_parts,
                   config(checkpoint_dir=str(workdir / "ck"),
                          checkpoint_every=CHECKPOINT_EVERY))
    # reaching this line just means the kill raced past the run's end;
    # the parent still resumes from the last checkpoint on disk


def wait_for_checkpoint(ckdir: Path, proc, timeout_s: float = 300.0) -> None:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        pairs = {p.stem for p in ckdir.glob("round_*.json")} \
            & {p.stem for p in ckdir.glob("round_*.npz")}
        if pairs:
            return
        if proc.poll() is not None:
            return                      # child finished before the kill
        time.sleep(0.2)
    raise RuntimeError(f"no checkpoint appeared in {ckdir} "
                       f"within {timeout_s}s")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="results/crash_recovery")
    ap.add_argument("--child", metavar="WORKDIR",
                    help="internal: run the killable training subprocess")
    args = ap.parse_args()

    if args.child:
        run_child(Path(args.child))
        return 0

    workdir = Path(args.workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    task, parts, test_parts = build_experiment()

    print("[1/3] clean same-seed reference run")
    clean = run_experiment(
        task, parts, test_parts,
        config(trace_path=str(workdir / "clean_trace.jsonl")))

    print("[2/3] child run with checkpointing — SIGKILL mid-round")
    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    proc = subprocess.Popen(
        [sys.executable, __file__, "--child", str(workdir)], env=env)
    wait_for_checkpoint(workdir / "ck", proc)
    if proc.poll() is None:
        proc.send_signal(signal.SIGKILL)
    proc.wait()
    print(f"    child exited with {proc.returncode} "
          f"(negative = killed by signal)")

    print("[3/3] resume from the last checkpoint and compare")
    resumed = run_experiment(
        task, parts, test_parts,
        config(resume_from=str(workdir / "ck"),
               trace_path=str(workdir / "resumed_trace.jsonl")))

    failures = []
    if resumed.final_accuracy != clean.final_accuracy:
        failures.append(f"final_accuracy {resumed.final_accuracy!r} != "
                        f"clean {clean.final_accuracy!r}")
    clean_by_round = {r.round_number: r for r in clean.rounds}
    for r in resumed.rounds:
        want = clean_by_round.get(r.round_number)
        if want is None:
            failures.append(f"resumed produced unknown round "
                            f"{r.round_number}")
            continue
        for attr in ("selected", "successes", "late", "crashed",
                     "duration_s", "cost"):
            if getattr(r, attr) != getattr(want, attr):
                failures.append(
                    f"round {r.round_number} {attr}: "
                    f"{getattr(r, attr)!r} != {getattr(want, attr)!r}")
    report = {
        "clean_final_accuracy": clean.final_accuracy,
        "resumed_final_accuracy": resumed.final_accuracy,
        "resumed_rounds": [r.round_number for r in resumed.rounds],
        "failures": failures,
    }
    (workdir / "report.json").write_text(json.dumps(report, indent=2))
    if failures:
        print("FAIL: recovered run diverged from the clean run:")
        for f in failures:
            print("  -", f)
        return 1
    print(f"OK: resumed rounds {report['resumed_rounds']} replay the "
          f"clean run exactly (final acc {clean.final_accuracy:.3f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
