"""Straggler study: sweep straggler ratios across all three strategies —
the end-to-end driver reproducing the shape of paper Tables II–IV on the
Google-Speech-like task.

    PYTHONPATH=src python examples/straggler_study.py [--ratios 0,0.3,0.5]
"""
import argparse

from repro.data import label_sorted_shards, make_speech_commands
from repro.data.synthetic import ArrayDataset
from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                 run_experiment)
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import make_speech_cnn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ratios", default="0,0.3,0.5")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=24)
    args = ap.parse_args()
    ratios = [float(r) for r in args.ratios.split(",")]

    full = make_speech_commands(3000, frames=16, mels=16, n_classes=8,
                                seed=0)
    train = ArrayDataset(full.x[:2500], full.y[:2500])
    test = ArrayDataset(full.x[2500:], full.y[2500:])
    parts = label_sorted_shards(train, args.clients, 2)
    test_parts = label_sorted_shards(test, args.clients, 2)
    task = ClassificationTask(
        make_speech_cnn(16, 16, 8),
        TaskConfig(epochs=2, batch_size=16, per_sample_time_s=0.04))

    print(f"{'strategy':12s} {'strag%':>6s} {'acc':>6s} {'EUR':>5s} "
          f"{'time(s)':>8s} {'cost($)':>8s} {'bias':>4s}")
    for ratio in ratios:
        for strategy in ("fedavg", "fedprox", "fedlesscan"):
            cfg = ExperimentConfig(
                strategy=strategy, n_rounds=args.rounds,
                clients_per_round=6, eval_every=0,
                scenario=ScenarioConfig(straggler_fraction=ratio,
                                        round_timeout_s=30.0))
            res = run_experiment(task, parts, test_parts, cfg)
            print(f"{strategy:12s} {int(ratio*100):5d}% "
                  f"{res.final_accuracy:6.3f} {res.mean_eur:5.2f} "
                  f"{res.total_duration_s:8.0f} {res.total_cost:8.4f} "
                  f"{res.bias:4d}")


if __name__ == "__main__":
    main()
