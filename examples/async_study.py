"""Async study: sync vs semi-async vs barrier-free training modes under
straggler injection, on identical seeds, task, and straggler profile.

Four strategies ride the same mode-agnostic TrainingDriver:

    fedavg      sync        round barrier, late updates discarded
    fedlesscan  semi-async  round barrier + staleness-damped late merges
    fedasync    async       barrier-free, merge-per-arrival (Xie et al.)
    fedbuff     async       barrier-free, buffer-K merges (Nguyen et al.)

Each run exports its JSONL trace (one record per invocation attempt,
billing charge, and aggregation event) to results/async_study/, and the
first async strategy is run twice to demonstrate byte-identical traces —
virtual-clock determinism survives the barrier-free mode.

``--server-opt`` adds a sweep column: every strategy is additionally run
with each named server optimizer on the merge pipeline (core/merge.py),
so the table shows e.g. how FedAdam/FedYogi server updates interact with
staleness-damped async pseudo-gradients.

``--compression`` adds compressed-update rows (core/compress.py): every
strategy is additionally run with each named codec on the client→server
wire, and the table gains Δcost($)/ΔEUR columns against that strategy's
plaintext run. Plaintext runs model the upload as free; compressed runs
bill real egress bytes and transfer time, so Δcost($) is the wire cost
the run now accounts for — tighter codecs (top-k) add less than looser
ones (int8) — while ΔEUR shows whether the codec hurt update delivery.

    PYTHONPATH=src python examples/async_study.py [--ratio 0.3 --rounds 8]
    PYTHONPATH=src python examples/async_study.py --server-opt fedadam \
        --server-opt fedyogi
    PYTHONPATH=src python examples/async_study.py --compression topk \
        --compression int8
"""
import argparse
from pathlib import Path

from repro.data import label_sorted_shards, make_image_classification
from repro.data.synthetic import ArrayDataset
from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                 run_experiment)
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import make_cnn

STRATEGIES = ("fedavg", "fedlesscan", "fedasync", "fedbuff")
OUT = Path(__file__).resolve().parent.parent / "results" / "async_study"


def build_task(n_clients: int, seed: int = 0):
    full = make_image_classification(1300, image_size=14, n_classes=5,
                                     seed=seed)
    train = ArrayDataset(full.x[:1100], full.y[:1100])
    test = ArrayDataset(full.x[1100:], full.y[1100:])
    parts = label_sorted_shards(train, n_clients, 2, seed=seed)
    test_parts = label_sorted_shards(test, n_clients, 2, seed=seed)
    task = ClassificationTask(
        make_cnn(14, 1, 5, 32),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    return task, parts, test_parts


# adaptive server optimizers take a smaller step than the identity
SERVER_OPT_LR = {"sgd": 1.0, "fedavgm": 0.9, "fedadagrad": 0.1,
                 "fedadam": 0.1, "fedyogi": 0.1}


def run_one(strategy: str, task, parts, test_parts, args,
            trace_path: Path, server_opt: str = "sgd",
            compress: str = "none"):
    cfg = ExperimentConfig(
        strategy=strategy, n_rounds=args.rounds,
        clients_per_round=args.cohort, eval_every=0, seed=args.seed,
        buffer_k=args.buffer_k, trace_path=str(trace_path),
        server_opt=server_opt,
        server_opt_lr=SERVER_OPT_LR.get(server_opt, 0.1),
        compress_scheme=compress,
        scenario=ScenarioConfig(straggler_fraction=args.ratio,
                                round_timeout_s=30.0, seed=args.seed))
    return run_experiment(task, parts, test_parts, cfg)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ratio", type=float, default=0.3)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--cohort", type=int, default=6)
    ap.add_argument("--buffer-k", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--server-opt", action="append", default=None,
                    metavar="NAME", dest="server_opts",
                    help="additional merge-pipeline server optimizers to "
                         "sweep (repeatable; 'sgd' — the identity — "
                         "always runs first)")
    ap.add_argument("--compression", action="append", default=None,
                    metavar="SCHEME", dest="compressions",
                    help="update codecs to sweep (repeatable; 'topk' or "
                         "'int8') — each adds a row per strategy with "
                         "Δcost($)/ΔEUR against the plaintext run")
    ap.add_argument("--skip-determinism-check", action="store_true")
    args = ap.parse_args()
    server_opts = ["sgd"] + [o for o in (args.server_opts or [])
                             if o != "sgd"]
    compressions = [c for c in (args.compressions or []) if c != "none"]

    task, parts, test_parts = build_task(args.clients, seed=args.seed)
    print(f"straggler ratio {int(args.ratio * 100)}%, "
          f"{args.rounds} rounds x cohort {args.cohort}\n")
    print(f"{'strategy':12s} {'srv-opt':10s} {'compress':9s} {'mode':10s} "
          f"{'acc':>6s} {'EUR':>5s} {'aggs':>5s} {'time(s)':>8s} "
          f"{'cost($)':>8s} {'Δcost($)':>9s} {'ΔEUR':>6s}")

    def show(strategy, server_opt, compress, res, base=None):
        delta = ("" if base is None else
                 f"{res.total_cost - base.total_cost:+9.4f} "
                 f"{res.mean_eur - base.mean_eur:+6.2f}")
        print(f"{strategy:12s} {server_opt:10s} {compress:9s} "
              f"{res.mode:10s} {res.final_accuracy:6.3f} "
              f"{res.mean_eur:5.2f} {len(res.rounds):5d} "
              f"{res.total_duration_s:8.0f} {res.total_cost:8.4f} {delta}")

    results = {}
    for strategy in STRATEGIES:
        for server_opt in server_opts:
            suffix = "" if server_opt == "sgd" else f"_{server_opt}"
            trace = OUT / f"{strategy}{suffix}.jsonl"
            res = run_one(strategy, task, parts, test_parts, args, trace,
                          server_opt=server_opt)
            results.setdefault(strategy, res)     # sgd row anchors checks
            show(strategy, server_opt, "-", res)
        for scheme in compressions:
            trace = OUT / f"{strategy}_{scheme}.jsonl"
            res = run_one(strategy, task, parts, test_parts, args, trace,
                          compress=scheme)
            show(strategy, "sgd", scheme, res, base=results[strategy])

    semi = results["fedlesscan"].mean_eur
    for name in ("fedasync", "fedbuff"):
        ok = results[name].mean_eur >= semi
        print(f"\n{name} EUR {results[name].mean_eur:.2f} "
              f"{'>=' if ok else '<'} semi-async EUR {semi:.2f} "
              f"({'ok' if ok else 'REGRESSION'})")

    if not args.skip_determinism_check:
        trace = OUT / "fedbuff.jsonl"
        again = OUT / "fedbuff_rerun.jsonl"
        run_one("fedbuff", task, parts, test_parts, args, again)
        identical = trace.read_bytes() == again.read_bytes()
        print(f"\ndeterminism: rerun trace byte-identical = {identical}")
        if not identical:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
