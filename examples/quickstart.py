"""Quickstart: train a global model with FedLesScan on simulated FaaS.

Runs a 12-round federated session over 20 clients (30% stragglers) on a
synthetic MNIST-like task and prints the metrics the paper reports:
accuracy, EUR, duration, cost, bias.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.data import label_sorted_shards, make_image_classification
from repro.data.synthetic import ArrayDataset
from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                 run_experiment)
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import make_cnn


def main() -> None:
    # --- data: label-sorted non-IID shards (paper's MNIST protocol) ----
    full = make_image_classification(3600, image_size=14, n_classes=5,
                                     seed=0)
    train = ArrayDataset(full.x[:3000], full.y[:3000])
    test = ArrayDataset(full.x[3000:], full.y[3000:])
    parts = label_sorted_shards(train, n_clients=20, shards_per_client=2)
    test_parts = label_sorted_shards(test, n_clients=20,
                                     shards_per_client=2)

    # --- model + task ---------------------------------------------------
    model = make_cnn(image_size=14, channels=1, n_classes=5, fc_width=64)
    task = ClassificationTask(
        model, TaskConfig(epochs=2, batch_size=32, per_sample_time_s=0.05))

    # --- run FedLesScan vs FedAvg under 30% stragglers -------------------
    for strategy in ("fedavg", "fedlesscan"):
        cfg = ExperimentConfig(
            strategy=strategy, n_rounds=12, clients_per_round=6,
            eval_every=4,
            scenario=ScenarioConfig(straggler_fraction=0.3,
                                    round_timeout_s=30.0))
        res = run_experiment(task, parts, test_parts, cfg, verbose=True)
        print(f"\n=== {strategy} ===")
        print(f"final accuracy : {res.final_accuracy:.3f}")
        print(f"mean EUR       : {res.mean_eur:.2f}")
        print(f"total duration : {res.total_duration_s:.0f} s (virtual)")
        print(f"total cost     : ${res.total_cost:.4f}")
        print(f"selection bias : {res.bias}\n")


if __name__ == "__main__":
    main()
