"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fed_agg, flash_attention, ssd_scan
from repro.kernels.ref import fed_agg_ref, flash_attention_ref, ssd_ref

RNG = np.random.default_rng(0)


# ------------------------------------------------------------- fed_agg
@pytest.mark.parametrize("K,P", [(1, 16), (4, 1000), (16, 4096), (7, 333)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fed_agg_matches_ref(K, P, dtype):
    u = jnp.asarray(RNG.normal(size=(K, P)), dtype)
    c = jnp.asarray(RNG.random(K), jnp.float32)
    got = fed_agg(u, c, tile_p=512)
    want = fed_agg_ref(u, c)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_fed_agg_eq3_coefficients():
    """Aggregating 3 identical updates with Eq.3 coeffs == scaled update."""
    P = 256
    w = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    u = jnp.stack([w, w, w])
    c = jnp.asarray([0.5, 0.3, 0.2])
    np.testing.assert_allclose(fed_agg(u, c), w, rtol=1e-5)


# ------------------------------------------------------------- attention
@pytest.mark.parametrize("B,H,Hkv,S,d", [
    (1, 2, 2, 128, 32), (2, 4, 2, 256, 64), (1, 8, 1, 192, 32),
    (1, 2, 2, 100, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, Hkv, S, d, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, S, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, d)), dtype)
    got = flash_attention(q, k, v, bq=64, bk=64)
    want = flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_window(window):
    q = jnp.asarray(RNG.normal(size=(1, 2, 160, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 160, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 160, 32)), jnp.float32)
    got = flash_attention(q, k, v, window=window, bq=64, bk=64)
    want = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)) * 3, jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)) * 3, jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.float32)
    got = flash_attention(q, k, v, softcap=20.0, bq=64, bk=64)
    want = flash_attention_ref(q, k, v, softcap=20.0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    # and it must differ from the uncapped result
    uncapped = flash_attention_ref(q, k, v)
    assert float(jnp.max(jnp.abs(want - uncapped))) > 1e-4


# ------------------------------------------------------------- ssd
@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 32), (2, 128, 4, 32, 16, 64), (1, 96, 1, 8, 4, 32),
    (1, 256, 2, 64, 128, 128),
])
def test_ssd_scan_matches_sequential(b, l, h, p, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, l, h, p)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.normal(size=(b, l, h))) * 0.3, jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    got = ssd_scan(x, a, B, C, chunk=chunk)
    want = ssd_ref(x, a, B, C)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_ssd_scan_bf16():
    x = jnp.asarray(RNG.normal(size=(1, 64, 2, 16)) * 0.5, jnp.bfloat16)
    a = jnp.asarray(-np.abs(RNG.normal(size=(1, 64, 2))) * 0.3, jnp.float32)
    B = jnp.asarray(RNG.normal(size=(1, 64, 2, 8)) * 0.5, jnp.bfloat16)
    C = jnp.asarray(RNG.normal(size=(1, 64, 2, 8)) * 0.5, jnp.bfloat16)
    got = ssd_scan(x, a, B, C, chunk=32)
    want = ssd_ref(x, a, B, C)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=6e-2, atol=6e-2)


def test_ssd_state_continuity_vs_model_path():
    """The model's jnp chunked SSD must agree with the kernel for the
    same inputs (two independent chunked implementations)."""
    from repro.models.ssm import ssd_chunked
    x = jnp.asarray(RNG.normal(size=(1, 128, 2, 16)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.normal(size=(1, 128, 2))) * 0.3, jnp.float32)
    B = jnp.asarray(RNG.normal(size=(1, 128, 2, 8)) * 0.5, jnp.float32)
    C = jnp.asarray(RNG.normal(size=(1, 128, 2, 8)) * 0.5, jnp.float32)
    y1 = ssd_scan(x, a, B, C, chunk=32)
    y2, _ = ssd_chunked(x, a, B, C, chunk=64)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
