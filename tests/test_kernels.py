"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (APPLY_OPTS, fed_agg, fed_agg_apply,
                           fed_agg_apply_sharded, fed_agg_sharded,
                           flash_attention, ssd_scan, topk_mask)
from repro.kernels.ref import (fed_agg_apply_ref, fed_agg_ref,
                               flash_attention_ref, ssd_ref, topk_ref)
from repro.launch.mesh import make_host_mesh

RNG = np.random.default_rng(0)


# ------------------------------------------------------------- fed_agg
@pytest.mark.parametrize("K,P", [(1, 16), (4, 1000), (16, 4096), (7, 333)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fed_agg_matches_ref(K, P, dtype):
    u = jnp.asarray(RNG.normal(size=(K, P)), dtype)
    c = jnp.asarray(RNG.random(K), jnp.float32)
    got = fed_agg(u, c, tile_p=512)
    want = fed_agg_ref(u, c)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_fed_agg_eq3_coefficients():
    """Aggregating 3 identical updates with Eq.3 coeffs == scaled update."""
    P = 256
    w = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    u = jnp.stack([w, w, w])
    c = jnp.asarray([0.5, 0.3, 0.2])
    np.testing.assert_allclose(fed_agg(u, c), w, rtol=1e-5)


# ------------------------------------------------------- fed_agg_apply
@pytest.mark.parametrize("opt", APPLY_OPTS)
@pytest.mark.parametrize("K,P", [(4, 1000), (7, 333)])
def test_fed_agg_apply_matches_ref(opt, K, P):
    u = jnp.asarray(RNG.normal(size=(K, P)), jnp.float32)
    c = jnp.asarray(RNG.random(K), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    m = jnp.asarray(RNG.normal(size=(P,)) * 0.1, jnp.float32)
    v = jnp.asarray(np.abs(RNG.normal(size=(P,))) * 0.1, jnp.float32)
    args = (0.1, 0.8, 0.9, 0.99, 1e-3)          # lr, mix, b1, b2, eps
    got = fed_agg_apply(u, c, g, m, v, *args, opt=opt, tile_p=512)
    want = fed_agg_apply_ref(u, c, g, m, v, *args, opt=opt)
    for got_x, want_x in zip(got, want):
        np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x),
                                   rtol=1e-5, atol=1e-5)


def test_fed_agg_sharded_matches_ref():
    """Mesh dispatch (P-dim shards) against the unsharded oracle."""
    mesh = make_host_mesh()
    K, P = 5, 777
    u = jnp.asarray(RNG.normal(size=(K, P)), jnp.float32)
    c = jnp.asarray(RNG.random(K), jnp.float32)
    got = fed_agg_sharded(u, c, mesh, tile_p=256)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(fed_agg_ref(u, c)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("opt", ["sgd", "fedadam"])
def test_fed_agg_apply_sharded_matches_ref(opt):
    mesh = make_host_mesh()
    K, P = 4, 513
    u = jnp.asarray(RNG.normal(size=(K, P)), jnp.float32)
    c = jnp.asarray(RNG.random(K), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    m = jnp.zeros((P,), jnp.float32)
    v = jnp.zeros((P,), jnp.float32)
    args = (0.05, 1.0, 0.9, 0.99, 1e-3)
    got = fed_agg_apply_sharded(u, c, g, m, v, *args, opt=opt,
                                mesh=mesh, tile_p=256)
    want = fed_agg_apply_ref(u, c, g, m, v, *args, opt=opt)
    for got_x, want_x in zip(got, want):
        np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x),
                                   rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- topk_mask
@pytest.mark.parametrize("P,k", [(1000, 10), (333, 333), (4096, 41)])
def test_topk_mask_matches_ref(P, k):
    """The threshold-mask decode equals the top_k+scatter oracle,
    including the lowest-index-wins tie-break."""
    x = jnp.asarray(RNG.normal(size=(P,)), jnp.float32)
    _, _, want = topk_ref(x, k)
    mags, idx = jax.lax.top_k(jnp.abs(x), min(k, P))
    tau = mags[min(k, P) - 1]
    last_keep = jnp.max(jnp.where(mags == tau, idx, -1)).astype(jnp.int32)
    got = topk_mask(x, tau, last_keep, tile_p=256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=0)


def test_topk_mask_tie_break():
    """Equal magnitudes: the kernel must keep the lowest indices, exactly
    like lax.top_k (the wire format the decode path reconstructs)."""
    x = jnp.asarray([1.0, -1.0, 1.0, 0.5, -1.0, 0.25], jnp.float32)
    k = 2
    _, _, want = topk_ref(x, k)
    mags, idx = jax.lax.top_k(jnp.abs(x), k)
    tau = mags[k - 1]
    last_keep = jnp.max(jnp.where(mags == tau, idx, -1)).astype(jnp.int32)
    got = topk_mask(x, tau, last_keep, tile_p=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------- attention
@pytest.mark.parametrize("B,H,Hkv,S,d", [
    (1, 2, 2, 128, 32), (2, 4, 2, 256, 64), (1, 8, 1, 192, 32),
    (1, 2, 2, 100, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, H, Hkv, S, d, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, S, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, S, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, S, d)), dtype)
    got = flash_attention(q, k, v, bq=64, bk=64)
    want = flash_attention_ref(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 64])
def test_flash_attention_window(window):
    q = jnp.asarray(RNG.normal(size=(1, 2, 160, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 160, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 160, 32)), jnp.float32)
    got = flash_attention(q, k, v, window=window, bq=64, bk=64)
    want = flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)) * 3, jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)) * 3, jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.float32)
    got = flash_attention(q, k, v, softcap=20.0, bq=64, bk=64)
    want = flash_attention_ref(q, k, v, softcap=20.0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
    # and it must differ from the uncapped result
    uncapped = flash_attention_ref(q, k, v)
    assert float(jnp.max(jnp.abs(want - uncapped))) > 1e-4


# ------------------------------------------------------------- ssd
@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 32), (2, 128, 4, 32, 16, 64), (1, 96, 1, 8, 4, 32),
    (1, 256, 2, 64, 128, 128),
])
def test_ssd_scan_matches_sequential(b, l, h, p, n, chunk):
    x = jnp.asarray(RNG.normal(size=(b, l, h, p)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.normal(size=(b, l, h))) * 0.3, jnp.float32)
    B = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, l, h, n)) * 0.5, jnp.float32)
    got = ssd_scan(x, a, B, C, chunk=chunk)
    want = ssd_ref(x, a, B, C)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_ssd_scan_bf16():
    x = jnp.asarray(RNG.normal(size=(1, 64, 2, 16)) * 0.5, jnp.bfloat16)
    a = jnp.asarray(-np.abs(RNG.normal(size=(1, 64, 2))) * 0.3, jnp.float32)
    B = jnp.asarray(RNG.normal(size=(1, 64, 2, 8)) * 0.5, jnp.bfloat16)
    C = jnp.asarray(RNG.normal(size=(1, 64, 2, 8)) * 0.5, jnp.bfloat16)
    got = ssd_scan(x, a, B, C, chunk=32)
    want = ssd_ref(x, a, B, C)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=6e-2, atol=6e-2)


def test_ssd_state_continuity_vs_model_path():
    """The model's jnp chunked SSD must agree with the kernel for the
    same inputs (two independent chunked implementations)."""
    from repro.models.ssm import ssd_chunked
    x = jnp.asarray(RNG.normal(size=(1, 128, 2, 16)) * 0.5, jnp.float32)
    a = jnp.asarray(-np.abs(RNG.normal(size=(1, 128, 2))) * 0.3, jnp.float32)
    B = jnp.asarray(RNG.normal(size=(1, 128, 2, 8)) * 0.5, jnp.float32)
    C = jnp.asarray(RNG.normal(size=(1, 128, 2, 8)) * 0.5, jnp.float32)
    y1 = ssd_scan(x, a, B, C, chunk=32)
    y2, _ = ssd_chunked(x, a, B, C, chunk=64)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
