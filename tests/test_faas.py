"""Unit tests: simulated FaaS platform semantics."""

from repro.faas import (ClientProfile, FaaSConfig, SimulatedFaaSPlatform,
                        invocation_cost)
from repro.faas.cost import FunctionShape


def _platform(**kw):
    defaults = dict(failure_rate=0.0, network_jitter_s=0.0)
    defaults.update(kw)
    return SimulatedFaaSPlatform(FaaSConfig(**defaults), seed=0)


def test_cold_start_then_warm():
    p = _platform()
    o1 = p.invoke("c", 10.0, 0.0)
    assert o1.cold and o1.cold_start_s > 0
    o2 = p.invoke("c", 10.0, o1.finish_time + 1.0)
    assert not o2.cold and o2.cold_start_s == 0.0
    assert p.cold_starts == 1


def test_scale_to_zero_forces_new_cold_start():
    p = _platform(warm_idle_timeout_s=100.0)
    o1 = p.invoke("c", 10.0, 0.0)
    late = o1.finish_time + 101.0
    o2 = p.invoke("c", 10.0, late)
    assert o2.cold


def test_function_timeout_kills():
    p = _platform(function_timeout_s=50.0)
    o = p.invoke("c", 500.0, 0.0)
    assert o.crashed and o.finish_time == float("inf")


def test_crash_profile_never_finishes():
    p = _platform()
    o = p.invoke("c", 1.0, 0.0, ClientProfile(crash=True))
    assert o.crashed


def test_slow_factor_scales_compute():
    p1, p2 = _platform(), _platform()
    o1 = p1.invoke("c", 10.0, 0.0)
    o2 = p2.invoke("c", 10.0, 0.0, ClientProfile(slow_factor=3.0))
    assert abs(o2.compute_s / o1.compute_s - 3.0) < 1e-9


def test_failure_rate_statistics():
    p = SimulatedFaaSPlatform(
        FaaSConfig(failure_rate=0.2, network_jitter_s=0.0), seed=1)
    fails = sum(p.invoke(f"c{i}", 1.0, 0.0).crashed for i in range(500))
    assert 50 < fails < 150          # ~100 expected


def test_gcf_cost_model_reference_values():
    """2048 MB / 1 vCPU for 100 s ≈ 100·(0.000024 + 2·0.0000025) + inv."""
    c = invocation_cost(100.0, FunctionShape(memory_mb=2048, vcpus=1.0))
    expect = 100.0 * (0.0000240 + 2.0 * 0.0000025) + 0.40 / 1e6
    assert abs(c - expect) < 1e-9
    # billing rounds up to 100 ms
    assert invocation_cost(0.001, FunctionShape()) == invocation_cost(
        0.1, FunctionShape())
