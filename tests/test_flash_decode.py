"""Sequence-sharded flash-decoding: combine math vs unsharded oracle.

The single-device case exercises the shard_map path trivially; the real
multi-shard combine is validated in a subprocess with 8 forced host
devices (the device count must be set before jax initialises).
"""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_host_mesh
from repro.sharding.flash_decode import (reference_decode_attention,
                                         sharded_decode_attention)


def test_single_shard_matches_oracle():
    rng = np.random.default_rng(0)
    B, H, K, S, hd = 2, 8, 4, 64, 32
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, K, S, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, K, S, hd)), jnp.float32)
    pos = jnp.asarray([10, 63], jnp.int32)
    mesh = make_host_mesh()
    with mesh:
        got = sharded_decode_attention(q, kc, vc, pos, mesh)
    want = reference_decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.sharding.flash_decode import (reference_decode_attention,
                                             sharded_decode_attention)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(0)
    B, H, K, S, hd = 4, 8, 4, 128, 16
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, K, S, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, K, S, hd)), jnp.float32)
    pos = jnp.asarray([5, 64, 100, 127], jnp.int32)
    with mesh:
        got = sharded_decode_attention(q, kc, vc, pos, mesh)
    want = reference_decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
    # the lowered HLO must NOT all-gather the KV cache
    from jax.sharding import NamedSharding, PartitionSpec as P
    with mesh:
        f = jax.jit(lambda q_, k_, v_, p_: sharded_decode_attention(
            q_, k_, v_, p_, mesh))
        hlo = f.lower(q, kc, vc, pos).compile().as_text()
    kv_bytes = B * K * S * hd * 4
    import re
    for line in hlo.splitlines():
        if "all-gather(" in line:
            m = re.search(r"f32\\[([0-9,]+)\\]", line)
            if m:
                n = 1
                for d in m.group(1).split(","):
                    n *= int(d)
                assert n * 4 < kv_bytes / 2, f"KV gather detected: {line[:120]}"
    print("MULTI-OK")
""")


def test_multi_shard_combine_subprocess():
    res = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         cwd="/root/repo")
    assert "MULTI-OK" in res.stdout, res.stdout + res.stderr
