"""Dedicated coverage for faas/cost.py and fl/metrics.py.

Pins the GCF billing semantics the experiment tables rest on: the 100 ms
ceil, the straggler whole-round charge, the free-tier discount (both
paths), the CostMeter per-client/per-round attribution, and the metric
edge cases (EUR, windowed EUR, bias, weighted accuracy).
"""
import numpy as np
import pytest

from repro.faas.cost import (CostMeter, FreeTierAllowance, FunctionShape,
                             PriceBook, invocation_cost,
                             straggler_invocation_cost)
from repro.fl.metrics import (bias, effective_update_ratio,
                              invocation_distribution, weighted_accuracy,
                              windowed_update_ratio)

SHAPE = FunctionShape(memory_mb=2048, vcpus=1.0)


# ---------------------------------------------------------------- billing
def test_billing_ceils_to_100ms_increments():
    # anything in (0.2, 0.3] bills identically to exactly 0.3 s
    assert invocation_cost(0.201, SHAPE) == pytest.approx(
        invocation_cost(0.3, SHAPE))
    assert invocation_cost(0.299, SHAPE) == pytest.approx(
        invocation_cost(0.3, SHAPE))
    # but crosses to the next increment above it
    assert invocation_cost(0.301, SHAPE) > invocation_cost(0.3, SHAPE)


def test_billing_has_100ms_minimum():
    assert invocation_cost(0.0001, SHAPE) == pytest.approx(
        invocation_cost(0.1, SHAPE))


def test_straggler_billed_for_whole_round():
    """Paper §VI-C: a straggler is charged as if it ran the full round."""
    round_s = 120.0
    assert straggler_invocation_cost(round_s, SHAPE) == pytest.approx(
        invocation_cost(round_s, SHAPE))
    # strictly worse than the work it actually did
    assert straggler_invocation_cost(round_s, SHAPE) > invocation_cost(
        5.0, SHAPE)


def test_invocation_cost_components():
    prices = PriceBook()
    c = invocation_cost(10.0, SHAPE, prices)
    expected = (10.0 * 1.0 * prices.vcpu_second
                + 10.0 * 2.0 * prices.gib_second
                + prices.per_invocation)
    assert c == pytest.approx(expected)


# ---------------------------------------------------------------- free tier
def test_free_tier_flag_off_charges_tier1_prices():
    prices = PriceBook(free_tier=False)
    # even with an allowance present, free_tier=False ignores it
    allowance = FreeTierAllowance()
    c = invocation_cost(10.0, SHAPE, prices, allowance)
    assert c == pytest.approx(invocation_cost(10.0, SHAPE, PriceBook()))
    assert allowance.vcpu_seconds == 180_000.0       # untouched


def test_free_tier_absorbs_usage_until_exhausted():
    prices = PriceBook(free_tier=True)
    allowance = FreeTierAllowance(invocations=2, vcpu_seconds=15.0,
                                  gib_seconds=30.0)
    # first call fits fully inside the grant: $0
    assert invocation_cost(10.0, SHAPE, prices, allowance) == 0.0
    assert allowance.vcpu_seconds == pytest.approx(5.0)
    # second call exceeds it: only the overflow is billed
    c = invocation_cost(10.0, SHAPE, prices, allowance)
    expected = ((10.0 - 5.0) * prices.vcpu_second
                + (20.0 - 10.0) * prices.gib_second)  # 2 GiB x 10 s, 10 free
    assert c == pytest.approx(expected)
    assert allowance.invocations == 0.0
    # third call is fully past the grant: full Tier-1 price
    c3 = invocation_cost(10.0, SHAPE, prices, allowance)
    assert c3 == pytest.approx(invocation_cost(10.0, SHAPE, PriceBook()))


def test_cost_meter_free_tier_vs_raw():
    free = CostMeter(prices=PriceBook(free_tier=True))
    raw = CostMeter()
    for _ in range(5):
        free.charge(10.0)
        raw.charge(10.0)
    assert free.total == 0.0                  # inside the monthly grant
    assert raw.total > 0.0
    assert free.invocations == raw.invocations == 5


# ---------------------------------------------------------------- attribution
def test_cost_meter_attributes_by_client_and_round():
    meter = CostMeter()
    meter.charge(10.0, client_id="a", round_number=0)
    meter.charge(20.0, client_id="b", round_number=0)
    meter.charge_straggler(120.0, client_id="a", round_number=1)
    assert set(meter.by_client) == {"a", "b"}
    assert sum(meter.by_client.values()) == pytest.approx(meter.total)
    assert set(meter.rounds) == {0, 1}
    assert sum(meter.rounds.values()) == pytest.approx(meter.total)
    # the straggler whole-round charge dominates a's bill
    assert meter.by_client["a"] > meter.by_client["b"]


def test_cost_meter_unattributed_charges_only_hit_total():
    meter = CostMeter()
    meter.charge(10.0)
    assert meter.total > 0.0
    assert meter.by_client == {} and meter.rounds == {}


# ---------------------------------------------------------------- metrics
def test_eur_edge_cases():
    assert effective_update_ratio(0, 0) == 1.0     # empty cohort: no waste
    assert effective_update_ratio(0, 4) == 0.0
    assert effective_update_ratio(3, 4) == pytest.approx(0.75)


def test_windowed_eur_for_async_mode():
    assert windowed_update_ratio(0, 0) == 1.0      # idle window: no waste
    assert windowed_update_ratio(2, 4) == pytest.approx(0.5)
    # a window can exceed 1.0 when stragglers from earlier windows land
    assert windowed_update_ratio(3, 2) == pytest.approx(1.5)


def test_bias_edge_cases():
    assert bias({}) == 0
    assert bias({"a": 3}) == 0
    assert bias({"a": 5, "b": 1, "c": 3}) == 4
    np.testing.assert_array_equal(
        invocation_distribution({"a": 5, "b": 1, "c": 3}),
        np.array([1, 3, 5]))


def test_weighted_accuracy_edge_cases():
    assert weighted_accuracy([]) == 0.0
    # zero total cardinality falls back to the plain mean
    assert weighted_accuracy([(0.2, 0), (0.8, 0)]) == pytest.approx(0.5)
    # cardinality-weighted otherwise
    assert weighted_accuracy([(1.0, 30), (0.0, 10)]) == pytest.approx(0.75)
