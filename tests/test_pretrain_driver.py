"""Integration: the pjit pretraining driver trains a reduced assigned
arch end to end (sharded init → jit train steps → checkpoint restore)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.synthetic import make_token_lm
from repro.launch.mesh import make_host_mesh
from repro.models import make_train_step
from repro.sharding import opt_specs, param_specs, to_named


def test_pretrain_loss_decreases(tmp_path):
    cfg = get_config("mamba2-130m").reduced().replace(
        efficient_ce=True, learning_rate=1e-3)
    mesh = make_host_mesh()
    train_step, init_state = make_train_step(cfg)
    rng = jax.random.PRNGKey(0)

    with mesh:
        state_struct = jax.eval_shape(lambda: init_state(rng))
        p_specs = param_specs(state_struct["params"], mesh)
        state_specs = {"params": p_specs,
                       "opt": opt_specs(state_struct["opt"], p_specs, mesh)}
        state_sh = to_named(state_specs, mesh)
        state = jax.jit(init_state, out_shardings=state_sh)(rng)

        data = make_token_lm(20_000, vocab=cfg.vocab, seq_len=32, seed=0)
        jit_step = jax.jit(train_step, donate_argnums=(0,))

        losses = []
        ckpt = CheckpointManager(str(tmp_path), keep=2)
        for step in range(30):
            idx = (np.arange(8) + step * 8) % data.x.shape[0]
            batch = {"tokens": jnp.asarray(data.x[idx]),
                     "labels": jnp.asarray(data.y[idx])}
            state, loss = jit_step(state, batch)
            losses.append(float(loss))
        ckpt.save(state, 30)

    assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.85
    restored = ckpt.restore(jax.tree_util.tree_map(np.asarray, state))
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert np.all(np.isfinite(np.asarray(leaf, np.float32)))


def test_pretrain_cli_smoke():
    cmd = [sys.executable, "-m", "repro.launch.pretrain",
           "--arch", "gemma2-2b", "--steps", "6", "--batch", "4",
           "--seq", "32", "--log-every", "3"]
    res = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                         cwd="/root/repo",
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert res.returncode == 0, res.stderr[-2000:]
    assert "final: loss" in res.stdout
