"""Cohort-sharded multi-device executor + overlapped dispatch (PR 10).

The contract under test: splitting the vectorized executor's cohort (K)
dim over a ``("clients",)`` mesh changes *where* local training runs but
not what it computes (≤1e-5 vs single-device; a size-1 mesh is the
identical code path), and deferring the executor launch to the round's
first INVOKE_START (``REPRO_OVERLAP_DISPATCH``) leaves every golden
trace byte-identical — virtual time never observes the wall clock.
Plus the riding satellites: mesh-keyed jit caches / per-mesh compile
accounting, the lazy once-only ``work_provider`` hook on the event
engine, and ``dispatch_s`` timing fields that appear only when asked
for.
"""
import hashlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from fleet_parity_common import GOLDEN_DIR, run_scenario

from repro.core import ClientHistoryDB, ClientUpdate, StrategyConfig, make_strategy
from repro.core.compress import CompressionConfig, UpdateCompressor
from repro.data import make_image_classification
from repro.data.synthetic import ArrayDataset
from repro.faas import CostMeter, FaaSConfig, MockInvoker, SimulatedFaaSPlatform
from repro.faas.events import EventQueue
from repro.faas.invoker import InvocationEngine
from repro.faas.trace import REC_ATTEMPT, TraceRecorder
from repro.fl.client import ClientPool
from repro.fl.controller import TrainingDriver
from repro.fl.executor import VectorizedExecutor, _bucket
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.launch.mesh import make_clients_mesh
from repro.models.small import make_cnn


# ----------------------------------------------------------------------
# shared real-task fixture (same shape as test_round_pipeline's)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    full = make_image_classification(360, image_size=14, n_classes=5,
                                     seed=0)
    x, y = np.asarray(full.x), np.asarray(full.y)
    parts = {f"c{i}": ArrayDataset(x[i * 40:(i + 1) * 40],
                                   y[i * 40:(i + 1) * 40])
             for i in range(8)}
    model = make_cnn(14, 1, 5, 16, "tiny")
    task = ClassificationTask(
        model, TaskConfig(epochs=1, batch_size=16, per_sample_time_s=0.05))
    return task, parts


def _driver(task, parts, strategy_name, mode, seed=0, trace=None):
    history = ClientHistoryDB()
    history.ensure(parts.keys())
    strategy = make_strategy(
        strategy_name,
        StrategyConfig(clients_per_round=4, max_rounds=10, buffer_k=3),
        history, seed=seed)
    pool = ClientPool(task, parts, None, proximal_mu=strategy.proximal_mu(),
                      seed=seed)
    platform = SimulatedFaaSPlatform(
        FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.3,
                   perf_variation=(0.9, 1.1), failure_rate=0.0,
                   network_jitter_s=0.4),
        seed=seed, recorder=trace)
    invoker = MockInvoker(platform, pool.work_fn, {})
    drv = TrainingDriver(strategy, invoker, pool, history,
                         CostMeter(trace=trace),
                         round_timeout_s=30.0, eval_every=0,
                         seed=seed, vectorized=True, mode=mode,
                         trace=trace)
    return drv, pool


def _digest(params) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def _run(task, parts, strategy_name, mode, n_rounds=2):
    trace = TraceRecorder()
    drv, pool = _driver(task, parts, strategy_name, mode, trace=trace)
    # the executor is cached on the task across drivers: pin defaults
    pool.executor.configure_mesh(None)
    pool.executor.collect_timing = False
    params, _res = drv.run(task.init_params(0), n_rounds)
    return _digest(params), trace.dumps().encode()


# ----------------------------------------------------------------------
# bucket math: mesh-divisible padding
# ----------------------------------------------------------------------
@pytest.mark.parametrize("k,mult,want", [
    (1, 1, 1), (2, 1, 2), (3, 1, 4), (5, 1, 8), (16, 1, 16),
    (1, 2, 2), (3, 2, 4), (3, 8, 8), (5, 8, 8), (9, 8, 16),
    (16, 8, 16), (17, 8, 32), (6, 3, 9),
])
def test_bucket_rounds_to_mesh_multiple(k, mult, want):
    b = _bucket(k, mult)
    assert b == want
    assert b >= k and b % mult == 0


# ----------------------------------------------------------------------
# single-device mesh is the identical code path
# ----------------------------------------------------------------------
def test_single_device_mesh_is_inert(setup):
    task, parts = setup
    pool = ClientPool(task, parts, None, proximal_mu=0.0, seed=0)
    cids = [f"c{i}" for i in range(3)]
    datasets = [pool.clients[c].dataset for c in cids]
    seeds = [pool.client_seed(c, 0) for c in cids]
    params = task.init_params(0)

    plain = VectorizedExecutor(task)
    # on this host make_clients_mesh clamps the ask to the devices that
    # exist; a size-1 result must normalize away entirely
    meshed = VectorizedExecutor(task, mesh=make_clients_mesh(1))
    assert meshed.mesh is None and meshed._mesh_key() is None

    a = plain.run_group(cids, datasets, params, 0.0, seeds)
    b = meshed.run_group(cids, datasets, params, 0.0, seeds)
    for cid in cids:
        pa, la = a[cid]
        pb, lb = b[cid]
        assert la == lb
        for x, y in zip(jax.tree_util.tree_leaves(pa),
                        jax.tree_util.tree_leaves(pb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_configure_mesh_size_one_keeps_compile_key(setup):
    """configure_mesh with a degenerate mesh lands on the same (None)
    compile-accounting key — no phantom recompiles."""
    task, parts = setup
    pool = ClientPool(task, parts, None, proximal_mu=0.0, seed=0)
    ex = VectorizedExecutor(task)
    cids = [f"c{i}" for i in range(2)]
    datasets = [pool.clients[c].dataset for c in cids]
    seeds = [pool.client_seed(c, 0) for c in cids]
    ex.run_group(cids, datasets, task.init_params(0), 0.0, seeds)
    before = ex.compile_count
    assert before == 1
    ex.configure_mesh(make_clients_mesh(1))
    ex.run_group(cids, datasets, task.init_params(0), 0.0, seeds)
    assert ex.compile_count == before
    assert ex.compile_count_total == before


# ----------------------------------------------------------------------
# overlapped dispatch: byte parity on the gate, goldens included
# ----------------------------------------------------------------------
def test_overlap_gate_byte_parity_real_training(setup, monkeypatch):
    task, parts = setup
    runs = {}
    for flag in ("1", "0"):
        monkeypatch.setenv("REPRO_OVERLAP_DISPATCH", flag)
        runs[flag] = _run(task, parts, "fedavg", "sync")
    assert runs["1"][0] == runs["0"][0]      # final params digest
    assert runs["1"][1] == runs["0"][1]      # full trace bytes


@pytest.mark.parametrize("name", ["sync_fedavg_apodotiko",
                                  "semiasync_fedlesscan",
                                  "async_fedbuff_rotation"])
def test_golden_traces_overlap_toggle(name, monkeypatch):
    golden = (GOLDEN_DIR / f"{name}.jsonl").read_bytes()
    monkeypatch.setenv("REPRO_OVERLAP_DISPATCH", "1")
    on_trace, on_digest = run_scenario(name)
    monkeypatch.setenv("REPRO_OVERLAP_DISPATCH", "0")
    off_trace, off_digest = run_scenario(name)
    assert on_trace == golden
    assert off_trace == golden
    assert on_digest == off_digest


# ----------------------------------------------------------------------
# engine: the deferred work_provider hook
# ----------------------------------------------------------------------
def test_work_provider_lazy_and_consumed_once():
    provider_calls = []
    wf_calls = []

    def wf(cid, params, rnd):
        wf_calls.append(cid)
        return ClientUpdate(cid, {"w": jnp.zeros(3)}, 5, rnd), 4.0

    cids = ["a", "b", "c"]
    provided = {cid: (ClientUpdate(cid, {"w": jnp.ones(3)}, 5, 0), 4.0)
                for cid in cids}

    def provider():
        provider_calls.append(1)
        return provided

    platform = SimulatedFaaSPlatform(FaaSConfig(failure_rate=0.0), seed=0)
    engine = InvocationEngine(MockInvoker(platform, wf, {}))
    queue = EventQueue()
    engine.open_round(queue, cids, {"w": jnp.zeros(3)}, 0, 0.0,
                      work_provider=provider)
    assert provider_calls == []              # lazy: nothing ran yet

    done = []
    while True:
        ev = queue.pop()
        if ev is None:
            break
        completion = engine.handle(queue, ev)
        if completion is not None:
            done.append(completion)
    assert provider_calls == [1]             # exactly one batch dispatch
    assert wf_calls == []                    # per-client path never ran
    assert {c.client_id for c in done} == set(cids)
    for c in done:
        assert c.update is provided[c.client_id][0]


def test_work_provider_none_falls_back_to_work_fn():
    wf_calls = []

    def wf(cid, params, rnd):
        wf_calls.append(cid)
        return ClientUpdate(cid, {"w": jnp.zeros(3)}, 5, rnd), 4.0

    platform = SimulatedFaaSPlatform(FaaSConfig(failure_rate=0.0), seed=0)
    engine = InvocationEngine(MockInvoker(platform, wf, {}))
    queue = EventQueue()
    engine.open_round(queue, ["a", "b"], {"w": jnp.zeros(3)}, 0, 0.0,
                      work_provider=lambda: None)
    while True:
        ev = queue.pop()
        if ev is None:
            break
        engine.handle(queue, ev)
    assert sorted(wf_calls) == ["a", "b"]


# ----------------------------------------------------------------------
# dispatch timing: only-when-set
# ----------------------------------------------------------------------
def _attempts(trace_bytes):
    import json
    return [json.loads(line) for line in trace_bytes.decode().splitlines()
            if json.loads(line).get("type") == REC_ATTEMPT]


def test_dispatch_timing_absent_by_default(setup):
    task, parts = setup
    _, trace_bytes = _run(task, parts, "fedavg", "sync")
    atts = _attempts(trace_bytes)
    assert atts
    assert all("dispatch_s" not in a for a in atts)


def test_dispatch_timing_present_when_collected(setup):
    task, parts = setup
    trace = TraceRecorder()
    drv, pool = _driver(task, parts, "fedavg", "sync", trace=trace)
    pool.executor.configure_mesh(None)
    pool.executor.collect_timing = True
    try:
        drv.run(task.init_params(0), 2)
    finally:
        pool.executor.collect_timing = False
    atts = _attempts(trace.dumps().encode())
    timed = [a for a in atts if "dispatch_s" in a]
    assert timed                             # vectorized cohort attempts
    assert all(isinstance(a["dispatch_s"], float)
               and a["dispatch_s"] >= 0.0 for a in timed)
    assert pool.executor.last_dispatch_s is not None


def test_update_record_round_trips_dispatch_s():
    from repro.core.aggregation import update_from_record, update_to_record
    upd = ClientUpdate("c", {"w": jnp.zeros(2)}, 4, 1, dispatch_s=0.25)
    rec = update_to_record(upd)
    assert rec["dispatch_s"] == 0.25
    back = update_from_record(rec, {"w": jnp.zeros(2)})
    assert back.dispatch_s == 0.25
    dense = update_to_record(ClientUpdate("c", {"w": jnp.zeros(2)}, 4, 1))
    assert "dispatch_s" not in dense         # only-when-set


# ----------------------------------------------------------------------
# forced 2-device subprocess: sharded parity end to end
# ----------------------------------------------------------------------
MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    assert jax.device_count() == 2
    from repro.data import make_image_classification
    from repro.data.synthetic import ArrayDataset
    from repro.fl.client import ClientPool
    from repro.fl.executor import VectorizedExecutor
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.launch.mesh import make_clients_mesh
    from repro.models.small import make_cnn

    full = make_image_classification(160, image_size=14, n_classes=4,
                                     seed=0)
    x, y = np.asarray(full.x), np.asarray(full.y)
    parts = {f"c{i}": ArrayDataset(x[i * 20:(i + 1) * 20],
                                   y[i * 20:(i + 1) * 20])
             for i in range(8)}
    model = make_cnn(14, 1, 4, 8, "tiny")
    task = ClassificationTask(
        model, TaskConfig(epochs=1, batch_size=10, per_sample_time_s=0.05))
    pool = ClientPool(task, parts, None, proximal_mu=0.0, seed=0)
    params = task.init_params(0)
    cids = [f"c{i}" for i in range(4)]
    datasets = [pool.clients[c].dataset for c in cids]
    seeds = [pool.client_seed(c, 0) for c in cids]

    mesh = make_clients_mesh(2)
    assert int(mesh.size) == 2
    ex = VectorizedExecutor(task)

    # ---- executor-level parity: sharded vs single-device, 1e-5 -------
    single = ex.run_group(cids, datasets, params, 0.0, seeds)
    ex.configure_mesh(mesh)
    sharded = ex.run_group(cids, datasets, params, 0.0, seeds)
    for cid in cids:
        ps, ls = sharded[cid]
        p1, l1 = single[cid]
        assert abs(ls - l1) < 1e-5, (cid, ls, l1)
        for a, b in zip(jax.tree_util.tree_leaves(ps),
                        jax.tree_util.tree_leaves(p1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)

    # ---- per-mesh compile accounting + mesh-keyed jit cache ----------
    meshed_count = ex.compile_count
    assert meshed_count == 1
    ex.run_group(cids, datasets, params, 0.0, seeds)
    assert ex.compile_count == meshed_count          # flat per mesh
    ex.configure_mesh(None)
    assert ex.compile_count == 1                     # the no-mesh counter
    ex.run_group(cids, datasets, params, 0.0, seeds)
    assert ex.compile_count == 1                     # flat there too
    assert ex.compile_count_total == 2
    assert {k[1] for k in ex._jit_cache} == {None,
                                             tuple(mesh.shape.items())}
    # odd cohort: the bucket must round up to the device count
    odd = cids[:3]
    ex.configure_mesh(mesh)
    ex.run_group(odd, [pool.clients[c].dataset for c in odd], params, 0.0,
                 [pool.client_seed(c, 0) for c in odd])

    # ---- driver-level parity across all three modes ------------------
    import hashlib
    from repro.core import ClientHistoryDB, StrategyConfig, make_strategy
    from repro.faas import (CostMeter, FaaSConfig, MockInvoker,
                            SimulatedFaaSPlatform)
    from repro.fl.controller import TrainingDriver

    def digest_leaves(tree):
        return [np.asarray(l) for l in jax.tree_util.tree_leaves(tree)]

    def run(strategy_name, mode, mesh):
        history = ClientHistoryDB()
        history.ensure(parts.keys())
        strategy = make_strategy(
            strategy_name,
            StrategyConfig(clients_per_round=4, max_rounds=10, buffer_k=3),
            history, seed=0)
        p = ClientPool(task, parts, None,
                       proximal_mu=strategy.proximal_mu(), seed=0)
        p.executor.configure_mesh(mesh)
        platform = SimulatedFaaSPlatform(
            FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.3,
                       perf_variation=(0.9, 1.1), failure_rate=0.0,
                       network_jitter_s=0.4),
            seed=0)
        invoker = MockInvoker(platform, p.work_fn, {})
        drv = TrainingDriver(strategy, invoker, p, history, CostMeter(),
                             round_timeout_s=30.0, eval_every=0, seed=0,
                             vectorized=True, mode=mode)
        out, _res = drv.run(task.init_params(0), 2)
        return digest_leaves(out)

    for strategy_name, mode in (("fedavg", "sync"),
                                ("fedlesscan", "semi-async"),
                                ("fedbuff", "async")):
        a = run(strategy_name, mode, mesh)
        b = run(strategy_name, mode, None)
        assert len(a) == len(b)
        for x, y in zip(a, b):
            np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5,
                                       err_msg=f"{strategy_name}/{mode}")
    print("EXECUTOR-SHARDED-OK")
""")


def test_sharded_executor_two_device_subprocess():
    res = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         cwd=str(GOLDEN_DIR.parent.parent))
    assert "EXECUTOR-SHARDED-OK" in res.stdout, res.stdout + res.stderr
