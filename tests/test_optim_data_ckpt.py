"""Unit tests: optimizers, data pipeline, checkpointing, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import (batches, dirichlet_partition, label_sorted_shards,
                        lognormal_sizes, make_image_classification,
                        partition_by_sizes)
from repro.optim import (adam, apply_updates, clip_by_global_norm,
                         global_norm, proximal_grad, sgd)


# ---------------------------------------------------------------- optim
def _minimize(opt, steps=300):
    target = jnp.asarray([3.0, -2.0])
    params = {"w": jnp.zeros(2)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return params["w"], target


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.05, momentum=0.9),
                                 adam(0.05)])
def test_optimizers_converge_quadratic(opt):
    w, target = _minimize(opt)
    np.testing.assert_allclose(w, target, atol=1e-2)


def test_proximal_grad_pulls_to_global():
    params = {"w": jnp.asarray([5.0])}
    gparams = {"w": jnp.asarray([1.0])}
    g0 = {"w": jnp.asarray([0.0])}
    g = proximal_grad(g0, params, gparams, mu=0.1)
    np.testing.assert_allclose(g["w"], [0.4], rtol=1e-6)
    assert proximal_grad(g0, params, gparams, 0.0) is g0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


# ---------------------------------------------------------------- data
def test_label_sorted_shards_non_iid():
    ds = make_image_classification(1000, 14, n_classes=10, seed=0)
    parts = label_sorted_shards(ds, 50, shards_per_client=2, seed=0)
    assert len(parts) == 50
    assert sum(len(p) for p in parts.values()) == 1000
    # most clients see few classes (the paper's non-IID construction)
    classes_per_client = [len(np.unique(p.y)) for p in parts.values()]
    assert np.median(classes_per_client) <= 3


def test_dirichlet_partition_alpha_controls_skew():
    ds = make_image_classification(2000, 14, n_classes=10, seed=0)
    skewed = dirichlet_partition(ds, 10, alpha=0.05, seed=0)
    uniform = dirichlet_partition(ds, 10, alpha=100.0, seed=0)

    def mean_classes(parts):
        return np.mean([len(np.unique(p.y)) for p in parts.values()
                        if len(p) > 0])
    assert mean_classes(skewed) < mean_classes(uniform)


def test_lognormal_sizes_and_partition():
    sizes = lognormal_sizes(30, 100, seed=0)
    assert sizes.min() >= 8
    ds = make_image_classification(4000, 14, seed=0)
    parts = partition_by_sizes(ds, sizes, seed=0)
    assert len(parts) == 30


def test_batches_cover_epoch():
    ds = make_image_classification(105, 14, seed=0)
    seen = 0
    for x, y in batches(ds, 32, np.random.default_rng(0)):
        seen += x.shape[0]
        assert x.shape[0] <= 32
    assert seen == 105


# ---------------------------------------------------------------- ckpt
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    p = tmp_path / "x.npz"
    save_pytree(tree, str(p))
    loaded = load_pytree(str(p), tree)
    np.testing.assert_array_equal(loaded["a"], tree["a"])
    np.testing.assert_array_equal(loaded["b"]["c"], tree["b"]["c"])


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(3)}
    for step in (1, 2, 3, 4):
        mgr.save(tree, step)
    assert mgr.steps() == [3, 4]
    restored = mgr.restore(tree)
    np.testing.assert_array_equal(restored["w"], tree["w"])


# ---------------------------------------------------------------- sharding
def test_sharding_specs_divisible():
    """Every spec dimension assigned to a mesh axis must divide."""
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import init_params
    from repro.sharding import param_specs

    mesh = make_host_mesh()          # 1 device; axis sizes 1 — always valid
    cfg = get_config("gemma2-2b").reduced()
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(params, mesh)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        for dim, axis in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0
