"""Unit tests: DBSCAN + Calinski–Harabasz (from scratch, vs brute force),
and the vectorized grid-search hot path vs the scalar reference."""
import numpy as np

from repro.core import (calinski_harabasz, calinski_harabasz_batch,
                        cluster_clients, dbscan, pairwise_sq_dists)
from repro.core.clustering import ClusteringResult, _fold_noise


def _brute_force_dbscan(x, eps, min_samples):
    """Independent O(N^3) reimplementation for cross-checking labels
    (up to label permutation)."""
    n = len(x)
    d = np.sqrt(((x[:, None] - x[None]) ** 2).sum(-1))
    neigh = [set(np.nonzero(d[i] <= eps)[0]) for i in range(n)]
    core = [len(neigh[i]) >= min_samples for i in range(n)]
    labels = [-1] * n
    c = 0
    for i in range(n):
        if labels[i] != -1 or not core[i]:
            continue
        stack, labels[i] = [i], c
        while stack:
            p = stack.pop()
            for q in neigh[p]:
                if labels[q] == -1:
                    labels[q] = c
                    if core[q]:
                        stack.append(q)
        c += 1
    return np.array(labels)


def _same_partition(a, b):
    """Labelings equal up to renaming."""
    mapping = {}
    for x, y in zip(a, b):
        if x in mapping and mapping[x] != y:
            return False
        mapping[x] = y
    return len(set(mapping.values())) == len(mapping)


def test_dbscan_matches_brute_force():
    rng = np.random.default_rng(0)
    for trial in range(8):
        x = np.concatenate([
            rng.normal(0, 0.3, (12, 2)),
            rng.normal(5, 0.3, (9, 2)),
            rng.normal((0, 5), 0.3, (7, 2)),
            rng.uniform(-10, 10, (4, 2)),   # noise
        ])
        for eps in (0.5, 1.0, 2.0):
            got = dbscan(x, eps, min_samples=3)
            want = _brute_force_dbscan(x, eps, 3)
            # noise labels must agree exactly; clusters up to permutation
            assert np.array_equal(got == -1, want == -1)
            assert _same_partition(got[got >= 0], want[want >= 0])


def test_ch_index_prefers_true_clustering():
    rng = np.random.default_rng(1)
    x = np.concatenate([rng.normal(0, 0.2, (20, 2)),
                        rng.normal(10, 0.2, (20, 2))])
    true = np.array([0] * 20 + [1] * 20)
    bad = np.array(([0, 1] * 20))
    assert calinski_harabasz(x, true) > calinski_harabasz(x, bad)


def test_ch_degenerate_cases():
    x = np.random.default_rng(2).normal(size=(5, 2))
    assert calinski_harabasz(x, np.zeros(5, int)) == float("-inf")   # k=1
    assert calinski_harabasz(x, np.arange(5)) == float("-inf")       # k=N


def test_grid_search_separates_fast_and_slow():
    """Two behavioural groups (fast vs slow clients) must split."""
    rng = np.random.default_rng(3)
    fast = np.stack([rng.normal(10, 1, 25), np.zeros(25)], 1)
    slow = np.stack([rng.normal(100, 5, 25), np.zeros(25)], 1)
    res = cluster_clients(np.concatenate([fast, slow]))
    assert res.n_clusters >= 2
    labels_fast = set(res.labels[:25])
    labels_slow = set(res.labels[25:])
    assert labels_fast.isdisjoint(labels_slow)


def test_identical_clients_single_cluster():
    x = np.ones((10, 2))
    res = cluster_clients(x)
    assert res.n_clusters == 1
    assert len(set(res.labels)) == 1


# ------------------------------------------------------- BFS determinism
def _bfs_reference_dbscan(x, eps, min_samples):
    """Independent FIFO-BFS DBSCAN: index-order seeds, FIFO expansion,
    sorted neighbour lists — the exact order contract of `dbscan`."""
    n = len(x)
    d = np.sqrt(((x[:, None] - x[None]) ** 2).sum(-1))
    neigh = [sorted(np.nonzero(d[i] <= eps)[0]) for i in range(n)]
    core = [len(neigh[i]) >= min_samples for i in range(n)]
    labels = [-1] * n
    c = 0
    for i in range(n):
        if labels[i] != -1 or not core[i]:
            continue
        labels[i] = c
        queue = [i]
        while queue:
            p = queue.pop(0)                 # FIFO — breadth first
            for q in neigh[p]:
                if labels[q] == -1:
                    labels[q] = c
                    if core[q]:
                        queue.append(int(q))
        c += 1
    return np.array(labels)


def test_dbscan_expansion_is_bfs_and_deterministic():
    """Regression for the docstring/behaviour mismatch: expansion claimed
    BFS but popped the stack tail (DFS).  Labels must now match an
    independent FIFO-BFS reference *exactly* (same cluster ids, not just
    the same partition), and repeated runs must be byte-identical."""
    rng = np.random.default_rng(42)
    for _ in range(6):
        x = np.concatenate([
            rng.normal(0, 0.4, (15, 2)),
            rng.normal(4, 0.4, (10, 2)),
            rng.uniform(-8, 8, (5, 2)),
        ])
        for eps in (0.4, 0.8, 1.5):
            got = dbscan(x, eps, min_samples=3)
            assert np.array_equal(got, _bfs_reference_dbscan(x, eps, 3))
            assert np.array_equal(got, dbscan(x, eps, min_samples=3))


def test_dbscan_accepts_precomputed_distances():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(30, 2))
    d2 = pairwise_sq_dists(x)
    assert np.array_equal(dbscan(x, 0.9, 3), dbscan(x, 0.9, 3, d2=d2))


# ------------------------------------------------ vectorized grid search
def _cluster_clients_reference(x, min_samples=2):
    """Pre-vectorization scalar reference: per-ε DBSCAN with a fresh
    distance matrix, scored by the scalar `calinski_harabasz`."""
    n = x.shape[0]
    if n == 0:
        return ClusteringResult(np.zeros(0, np.int64), 0.0, 0.0, 0)
    if n == 1:
        return ClusteringResult(np.zeros(1, np.int64), 0.0, 0.0, 1)
    d = np.sqrt(np.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1))
    pos = d[d > 0]
    if pos.size == 0:
        return ClusteringResult(np.zeros(n, np.int64), 0.0, 0.0, 1)
    eps_grid = np.unique(np.quantile(pos, np.linspace(0.05, 0.95, 13)))
    best = None
    for eps in eps_grid:
        if eps <= 0:
            continue
        labels = _fold_noise(dbscan(x, float(eps), min_samples))
        score = calinski_harabasz(x, labels)
        cand = ClusteringResult(labels, float(eps), score,
                                len(np.unique(labels)))
        if best is None or cand.score > best.score:
            best = cand
    if best is None or best.n_clusters < 2 or not np.isfinite(best.score):
        return ClusteringResult(np.zeros(n, np.int64),
                                float(eps_grid[-1]), 0.0, 1)
    return best


def test_vectorized_grid_search_matches_scalar_reference():
    """Acceptance: the batched-distance / vectorized-CH hot path returns
    labels identical to the scalar reference on randomized inputs."""
    rng = np.random.default_rng(0)
    for trial in range(10):
        k = int(rng.integers(1, 4))
        x = np.concatenate(
            [rng.normal(rng.uniform(-20, 20, 2), rng.uniform(0.2, 2.0),
                        (int(rng.integers(3, 15)), 2)) for _ in range(k)]
            + [rng.uniform(-25, 25, (int(rng.integers(0, 4)), 2))])
        got = cluster_clients(x)
        want = _cluster_clients_reference(x)
        assert np.array_equal(got.labels, want.labels)
        assert got.eps == want.eps
        assert got.n_clusters == want.n_clusters


def test_batch_ch_matches_scalar():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(40, 2))
    labelings = np.stack([
        rng.integers(0, 4, 40),
        np.zeros(40, np.int64),                  # k=1 → -inf
        np.arange(40),                           # k=N → -inf
        np.repeat([0, 1], 20),
    ])
    got = calinski_harabasz_batch(x, labelings)
    want = np.array([calinski_harabasz(x, lab) for lab in labelings])
    finite = np.isfinite(want)
    assert np.array_equal(finite, np.isfinite(got))
    assert np.allclose(got[finite], want[finite], rtol=1e-9)
    assert np.array_equal(got[~finite], want[~finite])


# ---------------------------------------------------- degenerate inputs
def test_single_client_clustering_ch_undefined():
    """One participant: the CH index is undefined (k == N == 1) — the
    grid search must fall back to a single cluster, not crash."""
    res = cluster_clients(np.array([[42.0, 1.0]]))
    assert res.n_clusters == 1
    assert list(res.labels) == [0]
    # two clients: every labeling has k < 2 or k == N → single cluster
    res2 = cluster_clients(np.array([[0.0, 0.0], [10.0, 0.0]]))
    assert res2.n_clusters == 1
    assert calinski_harabasz(np.array([[0.0, 0.0], [10.0, 0.0]]),
                             np.array([0, 1])) == float("-inf")
