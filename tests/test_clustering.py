"""Unit tests: DBSCAN + Calinski–Harabasz (from scratch, vs brute force)."""
import numpy as np

from repro.core import calinski_harabasz, cluster_clients, dbscan


def _brute_force_dbscan(x, eps, min_samples):
    """Independent O(N^3) reimplementation for cross-checking labels
    (up to label permutation)."""
    n = len(x)
    d = np.sqrt(((x[:, None] - x[None]) ** 2).sum(-1))
    neigh = [set(np.nonzero(d[i] <= eps)[0]) for i in range(n)]
    core = [len(neigh[i]) >= min_samples for i in range(n)]
    labels = [-1] * n
    c = 0
    for i in range(n):
        if labels[i] != -1 or not core[i]:
            continue
        stack, labels[i] = [i], c
        while stack:
            p = stack.pop()
            for q in neigh[p]:
                if labels[q] == -1:
                    labels[q] = c
                    if core[q]:
                        stack.append(q)
        c += 1
    return np.array(labels)


def _same_partition(a, b):
    """Labelings equal up to renaming."""
    mapping = {}
    for x, y in zip(a, b):
        if x in mapping and mapping[x] != y:
            return False
        mapping[x] = y
    return len(set(mapping.values())) == len(mapping)


def test_dbscan_matches_brute_force():
    rng = np.random.default_rng(0)
    for trial in range(8):
        x = np.concatenate([
            rng.normal(0, 0.3, (12, 2)),
            rng.normal(5, 0.3, (9, 2)),
            rng.normal((0, 5), 0.3, (7, 2)),
            rng.uniform(-10, 10, (4, 2)),   # noise
        ])
        for eps in (0.5, 1.0, 2.0):
            got = dbscan(x, eps, min_samples=3)
            want = _brute_force_dbscan(x, eps, 3)
            # noise labels must agree exactly; clusters up to permutation
            assert np.array_equal(got == -1, want == -1)
            assert _same_partition(got[got >= 0], want[want >= 0])


def test_ch_index_prefers_true_clustering():
    rng = np.random.default_rng(1)
    x = np.concatenate([rng.normal(0, 0.2, (20, 2)),
                        rng.normal(10, 0.2, (20, 2))])
    true = np.array([0] * 20 + [1] * 20)
    bad = np.array(([0, 1] * 20))
    assert calinski_harabasz(x, true) > calinski_harabasz(x, bad)


def test_ch_degenerate_cases():
    x = np.random.default_rng(2).normal(size=(5, 2))
    assert calinski_harabasz(x, np.zeros(5, int)) == float("-inf")   # k=1
    assert calinski_harabasz(x, np.arange(5)) == float("-inf")       # k=N


def test_grid_search_separates_fast_and_slow():
    """Two behavioural groups (fast vs slow clients) must split."""
    rng = np.random.default_rng(3)
    fast = np.stack([rng.normal(10, 1, 25), np.zeros(25)], 1)
    slow = np.stack([rng.normal(100, 5, 25), np.zeros(25)], 1)
    res = cluster_clients(np.concatenate([fast, slow]))
    assert res.n_clusters >= 2
    labels_fast = set(res.labels[:25])
    labels_slow = set(res.labels[25:])
    assert labels_fast.isdisjoint(labels_slow)


def test_identical_clients_single_cluster():
    x = np.ones((10, 2))
    res = cluster_clients(x)
    assert res.n_clusters == 1
    assert len(set(res.labels)) == 1
