"""repro-lint engine tests: every rule fires on the fixture corpus at
its expected location, pragmas and the baseline round-trip, and the real
``src/repro`` tree stays clean modulo the committed baseline."""
import json
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import baseline as baseline_mod
from repro.analysis import gates
from repro.analysis.core import (FileContext, line_fingerprint,
                                 load_project, run_rules)
from repro.analysis.rules import ALL_RULES, select_rules

HERE = Path(__file__).resolve().parent
FIXTURES = HERE / "analysis_fixtures"
REPO = HERE.parent
SRC_REPRO = REPO / "src" / "repro"

# ground truth for the corpus: every (rule, relpath, line) it must emit
EXPECTED = {
    ("DET001", "core/bad_random.py", 8),
    ("DET001", "core/bad_random.py", 9),
    ("DET002", "faas/bad_wallclock.py", 8),
    ("DET002", "faas/bad_wallclock.py", 9),
    ("DET002", "faas/bad_wallclock.py", 10),
    ("DET003", "core/bad_hash.py", 5),
    ("DET004", "core/bad_set_iter.py", 6),
    ("DET004", "core/bad_set_iter.py", 8),
    ("DET004", "core/bad_set_iter.py", 9),
    ("JAX001", "kernels/bad_host_sync.py", 10),
    ("JAX001", "kernels/bad_host_sync.py", 11),
    ("JAX001", "kernels/bad_host_sync.py", 12),
    ("JAX002", "core/bad_use_after_donate.py", 11),
    ("JAX002", "core/bad_use_after_donate.py", 16),
    ("JAX003", "fl/bad_jit_in_round.py", 8),
    ("JAX004", "kernels/bad_shard_axes.py", 10),
    ("JAX004", "kernels/bad_shard_axes.py", 16),
    ("GATE001", "core/bad_env_gate.py", 4),
    ("GATE001", "core/bad_env_gate.py", 5),
    ("CON001", "kernels/__init__.py", 5),
    ("CON002", "faas/trace.py", 16),
    ("CON002", "faas/trace.py", 17),
    ("CON002", "faas/trace.py", 22),
}


def corpus_findings():
    project = load_project(FIXTURES, tests_dir=None)
    return project, run_rules(project, ALL_RULES)


# ------------------------------------------------------------ the corpus
def test_corpus_matches_ground_truth_exactly():
    """No missing findings, no extras — the corpus is the rule spec."""
    _, findings = corpus_findings()
    got = {(f.rule, f.path, f.line) for f in findings}
    assert got == EXPECTED


@pytest.mark.parametrize("rule_id", sorted({r for r, _, _ in EXPECTED}))
def test_each_rule_fires_at_expected_lines(rule_id):
    project = load_project(FIXTURES, tests_dir=None)
    findings = run_rules(project, select_rules([rule_id]))
    got = {(f.rule, f.path, f.line) for f in findings}
    want = {t for t in EXPECTED if t[0] == rule_id}
    assert got == want


def test_every_registered_rule_has_corpus_coverage():
    """Adding a rule without a fixture proving it fires is a test gap."""
    covered = {r for r, _, _ in EXPECTED}
    assert {r.id for r in ALL_RULES} == covered


def test_findings_carry_messages_and_locations():
    _, findings = corpus_findings()
    for f in findings:
        assert f.message and f.location().endswith(f":{f.line}")
        assert f.severity == "error"


# ------------------------------------------------------------- pragmas
def test_pragma_suppresses_by_id_and_slug():
    """core/pragma_ok.py violates DET003 + DET001 but pragmas (one by
    rule id, one by slug) silence both."""
    _, findings = corpus_findings()
    assert not [f for f in findings if f.path == "core/pragma_ok.py"]


def test_pragma_only_covers_its_own_line(tmp_path):
    src = ('def f(a):\n'
           '    x = hash(a)  # repro-lint: disable=DET003\n'
           '    return hash(x)\n')
    p = tmp_path / "mod.py"
    p.write_text(src)
    project = load_project(p)
    findings = run_rules(project, select_rules(["DET003"]))
    assert [f.line for f in findings] == [3]


# ------------------------------------------------------------- baseline
def test_baseline_round_trip(tmp_path):
    """write -> load -> partition grandfathers the whole corpus."""
    project, findings = corpus_findings()
    path = tmp_path / "baseline.json"
    baseline_mod.write(path, project, findings)
    base = baseline_mod.load(path)
    assert len(base) == len(findings)
    new, old = baseline_mod.partition(project, findings, base)
    assert new == [] and len(old) == len(findings)


def test_baseline_fingerprint_survives_renumbering(tmp_path):
    """Inserting lines above a finding must not invalidate the baseline
    (it keys on line content, not line number) — but editing the flagged
    line itself must."""
    corpus = tmp_path / "corpus"
    shutil.copytree(FIXTURES, corpus)
    project, findings = (lambda p: (p, run_rules(p, ALL_RULES)))(
        load_project(corpus, tests_dir=None))
    path = tmp_path / "baseline.json"
    baseline_mod.write(path, project, findings)
    base = baseline_mod.load(path)

    target = corpus / "core" / "bad_hash.py"
    target.write_text("# pushed down\n# two lines\n" + target.read_text())
    project2 = load_project(corpus, tests_dir=None)
    findings2 = run_rules(project2, ALL_RULES)
    new, _ = baseline_mod.partition(project2, findings2, base)
    assert new == []                       # renumbering: still baselined

    target.write_text(target.read_text().replace(
        "hash(client_id) % 2**32", "hash(client_id) % 2**16"))
    project3 = load_project(corpus, tests_dir=None)
    findings3 = run_rules(project3, ALL_RULES)
    new, _ = baseline_mod.partition(project3, findings3, base)
    assert [(f.rule, f.path) for f in new] == [
        ("DET003", "core/bad_hash.py")]    # edited line: resurfaces


def test_line_fingerprint_strips_indentation(tmp_path):
    p = tmp_path / "m.py"
    p.write_text("x = hash('a')\n")
    a = line_fingerprint(FileContext(p, "m.py"), 1)
    p.write_text("    x = hash('a')\n")
    b = line_fingerprint(FileContext(p, "m.py"), 1)
    assert a == b


def test_duplicate_line_occurrence_index():
    """Two identical flagged lines get distinct :0 / :1 fingerprints."""
    project, findings = corpus_findings()
    fps = baseline_mod.fingerprints(project, findings)
    assert len(fps) == len(set(fps))


# ----------------------------------------------------- the real package
def test_src_repro_clean_modulo_committed_baseline():
    """The shipped tree must carry no findings beyond the committed
    baseline — the same check CI enforces."""
    project = load_project(SRC_REPRO, tests_dir=HERE)
    findings = run_rules(project, ALL_RULES)
    base = baseline_mod.load()             # the committed baseline.json
    new, _ = baseline_mod.partition(project, findings, base)
    assert new == [], [f"{f.location()}: {f.rule} {f.message}"
                       for f in new]


def test_syntax_error_becomes_finding(tmp_path):
    p = tmp_path / "broken.py"
    p.write_text("def f(:\n")
    findings = run_rules(load_project(p), ALL_RULES)
    assert [f.rule for f in findings] == ["E000"]


def test_select_rules_rejects_unknown():
    with pytest.raises(KeyError):
        select_rules(["NOPE999"])


# ------------------------------------------------------------ gates
def test_gates_registry_declares_known_flags():
    for name in (gates.AGG_KERNEL, gates.COMPRESS, gates.DEVICE_PIPELINE,
                 gates.OVERLAP_DISPATCH, gates.PALLAS_INTERPRET):
        assert name in gates.GATES
        assert gates.GATES[name].doc


def test_gates_read_at_call_time(monkeypatch):
    monkeypatch.delenv(gates.COMPRESS, raising=False)
    assert gates.compress_enabled()        # default "1"
    monkeypatch.setenv(gates.COMPRESS, "0")
    assert not gates.compress_enabled()
    monkeypatch.setenv(gates.AGG_KERNEL, "0")
    assert not gates.agg_kernel_enabled()
    monkeypatch.setenv(gates.AGG_KERNEL, "1")
    assert gates.agg_kernel_enabled()


def test_gates_interpret_override_three_state(monkeypatch):
    monkeypatch.delenv(gates.PALLAS_INTERPRET, raising=False)
    assert gates.pallas_interpret_override() is None
    monkeypatch.setenv(gates.PALLAS_INTERPRET, "1")
    assert gates.pallas_interpret_override() is True
    monkeypatch.setenv(gates.PALLAS_INTERPRET, "0")
    assert gates.pallas_interpret_override() is False


def test_gates_reject_undeclared_name():
    with pytest.raises(KeyError):
        gates.raw("REPRO_NOT_A_GATE")


# ------------------------------------------------------------ CLI
def _run_cli(*argv):
    env = {"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        capture_output=True, text=True, env=env, cwd=REPO)


def test_cli_json_on_corpus(tmp_path):
    out = tmp_path / "report.json"
    proc = _run_cli(str(FIXTURES), "--format", "json", "--no-baseline",
                    "--tests-dir", str(tmp_path / "missing"),
                    "--output", str(out))
    assert proc.returncode == 1            # corpus is all violations
    report = json.loads(out.read_text())
    assert report["summary"]["new"] == len(EXPECTED)
    got = {(f["rule"], f["path"], f["line"])
           for f in report["findings"]}
    assert got == EXPECTED
    assert all(f["fingerprint"] for f in report["findings"])


def test_cli_clean_tree_exits_zero():
    proc = _run_cli(str(SRC_REPRO), "--tests-dir", str(HERE))
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_list_rules():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    for rule in ALL_RULES:
        assert rule.id in proc.stdout
