"""Unit tests for model-zoo components: RoPE, softcap, MoE routing,
ring-buffer caches, SSD state continuity, sliding-window equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import (_ring_valid, decode_self_attention,
                                    attn_init, init_kv_cache,
                                    self_attention)
from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, cross_entropy_loss, softcap
from repro.models.moe import moe_block, moe_init, router_load
from repro.models.ssm import mamba_block, mamba_decode_step, mamba_init

RNG = np.random.default_rng(0)


# ----------------------------------------------------------------- RoPE
def test_rope_preserves_norm():
    x = jnp.asarray(RNG.normal(size=(2, 8, 4, 64)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (2, 8))
    y = apply_rope(x, pos, fraction=1.0, theta=10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)


def test_rope_relative_position_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    hd = 32
    q = jnp.asarray(RNG.normal(size=(1, 1, 1, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 1, 1, hd)), jnp.float32)

    def dot_at(i, j):
        qi = apply_rope(q, jnp.array([[i]]), 1.0, 10000.0)
        kj = apply_rope(k, jnp.array([[j]]), 1.0, 10000.0)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(0, 0) - dot_at(50, 50)) < 1e-3


def test_partial_rope_passthrough():
    """chatglm-style fraction=0.5: the last half of head dims unchanged."""
    x = jnp.asarray(RNG.normal(size=(1, 4, 2, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(4)[None], (1, 4))
    y = apply_rope(x, pos, fraction=0.5, theta=10000.0)
    np.testing.assert_array_equal(y[..., 16:], x[..., 16:])
    assert not np.allclose(y[..., :16], x[..., :16])


# ----------------------------------------------------------------- softcap
def test_softcap_bounds_and_identity():
    x = jnp.linspace(-200, 200, 101)
    y = softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    np.testing.assert_allclose(softcap(x, 0.0), x)  # 0 = disabled
    small = jnp.linspace(-0.1, 0.1, 11)
    np.testing.assert_allclose(softcap(small, 50.0), small, atol=1e-5)


# ----------------------------------------------------------------- CE
def test_ce_impls_identical():
    logits = jnp.asarray(RNG.normal(size=(4, 7, 33)), jnp.float32)
    tgt = jnp.asarray(RNG.integers(0, 33, size=(4, 7)), jnp.int32)
    a = cross_entropy_loss(logits, tgt, impl="logsoftmax")
    b = cross_entropy_loss(logits, tgt, impl="logsumexp")
    np.testing.assert_allclose(a, b, rtol=1e-6)


# ----------------------------------------------------------------- MoE
def _moe_cfg(**kw):
    base = dict(name="t", arch_type="moe", n_layers=2, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
                n_experts=4, top_k=2, moe_group_size=16,
                capacity_factor=8.0)
    base.update(kw)
    return ArchConfig(**base)


def test_moe_drop_free_matches_dense_mixture():
    """With huge capacity, MoE output == gate-weighted dense expert sum."""
    cfg = _moe_cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(2, 8, 32)) * 0.5, jnp.float32)
    got = moe_block(p, x, cfg)

    # dense oracle
    flat = x.reshape(-1, 32)
    logits = flat @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(flat @ p["wg"][e]) * (flat @ p["wu"][e])
        outs.append(h @ p["wd"][e])
    outs = jnp.stack(outs, 1)                     # (T, E, D)
    w = jnp.zeros((flat.shape[0], cfg.n_experts))
    for c in range(cfg.top_k):
        w = w + jax.nn.one_hot(topi[:, c], cfg.n_experts) * topv[:, c:c+1]
    want = jnp.einsum("te,ted->td", w, outs).reshape(x.shape)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_moe_parallel_dense_residual():
    cfg = _moe_cfg(parallel_dense_mlp=True)
    p = moe_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(RNG.normal(size=(1, 8, 32)) * 0.5, jnp.float32)
    with_dense = moe_block(p, x, cfg)
    without = moe_block(p, x, cfg.replace(parallel_dense_mlp=False))
    assert not np.allclose(with_dense, without)


def test_router_load_counts():
    cfg = _moe_cfg()
    p = moe_init(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(RNG.normal(size=(1, 64, 32)), jnp.float32)
    load = router_load(p, x, cfg)
    assert int(load.sum()) == 64 * cfg.top_k


# ----------------------------------------------------------------- window
def test_sliding_window_equals_full_when_window_covers():
    cfg = get_config("gemma2-2b").reduced()
    p = attn_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(1, 16, cfg.d_model)) * 0.3, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16)[None], (1, 16))
    full = self_attention(p, x, pos, cfg, window=None)
    wide = self_attention(p, x, pos, cfg, window=1000)
    np.testing.assert_allclose(full, wide, rtol=1e-5, atol=1e-5)
    narrow = self_attention(p, x, pos, cfg, window=2)
    assert not np.allclose(full, narrow, atol=1e-4)


def test_ring_valid_mask():
    idx = jnp.arange(4)
    # pos=1, ring size 4: slots 0,1 valid
    v = _ring_valid(idx, jnp.array([1]), 4)[0]
    assert v.tolist() == [True, True, False, False]
    # pos=5: ring holds times 2..5 in slots 2,3,0,1 → all valid
    v = _ring_valid(idx, jnp.array([5]), 4)[0]
    assert v.tolist() == [True, True, True, True]


def test_ring_buffer_decode_matches_window_attention():
    """Decode with a ring cache beyond the wrap point equals full-seq
    windowed attention at the last position."""
    cfg = get_config("gemma2-2b").reduced().replace(window=8)
    p = attn_init(jax.random.PRNGKey(0), cfg)
    S = 20
    x = jnp.asarray(RNG.normal(size=(1, S, cfg.d_model)) * 0.3, jnp.float32)
    pos_full = jnp.broadcast_to(jnp.arange(S)[None], (1, S))
    want = self_attention(p, x, pos_full, cfg, window=8)[:, -1]

    cache = init_kv_cache(cfg, 1, 8, jnp.float32)
    out = None
    for t in range(S):
        out, cache = decode_self_attention(
            p, x[:, t:t + 1], cache, jnp.array([t]), cfg, window=8)
    np.testing.assert_allclose(out[:, 0], want, rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------- SSD
def test_mamba_prefill_cache_continues_decode():
    cfg = get_config("mamba2-130m").reduced()
    p = mamba_init(jax.random.PRNGKey(0), cfg)
    S = 12
    x = jnp.asarray(RNG.normal(size=(1, S + 1, cfg.d_model)) * 0.3,
                    jnp.float32)
    full = mamba_block(p, x, cfg)[:, -1]
    _, cache = mamba_block(p, x[:, :S], cfg, return_cache=True)
    dec, _ = mamba_decode_step(p, x[:, S:S + 1], cache, cfg)
    np.testing.assert_allclose(dec[:, 0], full, rtol=2e-3, atol=2e-3)


# ----------------------------------------------------------------- pallas
@pytest.mark.parametrize("arch", ["gemma2-2b", "chatglm3-6b"])
def test_pallas_attention_path_matches_jnp(arch):
    """cfg.use_pallas_attention routes full-seq attention through the
    Pallas flash kernel (interpret mode on CPU) — must equal the jnp
    path incl. sliding window + softcap (gemma2)."""
    from repro.models import forward, init_params
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    a = forward(cfg, params, {"tokens": tok})
    b = forward(cfg.replace(use_pallas_attention=True), params,
                {"tokens": tok})
    assert float(jnp.max(jnp.abs(a - b))) < 1e-3
