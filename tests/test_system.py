"""End-to-end behaviour: dry-run machinery on a tiny mesh (1 CPU device).

The production 512-device dry-run runs via `python -m repro.launch.dryrun`
in its own process (XLA device-count flag must be set before jax init);
here we verify the same build/lower/compile path works on the host mesh,
plus the HLO collective parser on a known program.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.shapes import InputShape
from repro.launch.hlo_analysis import (Roofline, collective_summary,
                                       parse_collectives)
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import build_step


TINY_SHAPES = {
    "train": InputShape("train_tiny", 32, 4, "train"),
    "prefill": InputShape("prefill_tiny", 32, 2, "prefill"),
    "decode": InputShape("decode_tiny", 32, 2, "decode"),
}


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-130m", "arctic-480b"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_build_lower_compile_host_mesh(arch, kind):
    cfg = get_config(arch).reduced()
    shape = TINY_SHAPES[kind]
    mesh = make_host_mesh()
    with mesh:
        jf, args = build_step(cfg, shape, mesh)
        compiled = jf.lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    assert float(cost.get("flops", 0)) > 0


def test_collective_parser_on_psum_program():
    mesh = make_host_mesh()
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return jax.lax.with_sharding_constraint(
            x.sum(), NamedSharding(mesh, P()))

    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    with mesh:
        jf = jax.jit(f, in_shardings=NamedSharding(mesh, P(None, None)))
        hlo = jf.lower(x).compile().as_text()
    ops = parse_collectives(hlo)       # 1-device mesh: likely no collectives
    summary = collective_summary(ops)
    assert summary["total_wire_bytes"] >= 0.0


def test_collective_parser_synthetic_hlo():
    hlo = '''
HloModule test
%body (p: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %t = tuple(...)
}
%cond (p: (s32[], f32[16,128])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}
ENTRY %main (a: f32[16,128]) -> f32[16,128] {
  %w = (s32[], f32[16,128]) while(%init), condition=%cond, body=%body
  %ar = f32[4,128]{1,0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%sum
  ROOT %r = f32[16,128] get-tuple-element(%w), index=1
}
'''
    ops = parse_collectives(hlo)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce"]
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.trip_count == 12                       # scan body multiplied
    assert ag.shape_bytes == 16 * 128 * 4
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.trip_count == 1
    assert ar.wire_bytes == pytest.approx(2 * 3 / 4 * 4 * 128 * 4)


def test_roofline_terms():
    r = Roofline(flops=197e12, hbm_bytes=819e9, wire_bytes=25e9,
                 model_flops=197e12 * 256, chips=256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant in ("compute", "memory")
    assert r.useful_flops_ratio == pytest.approx(1.0)


def test_input_shapes_assignment():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
