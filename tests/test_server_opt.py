"""Delta-based merge pipeline + pluggable server optimizers (core/merge.py).

Covers the three tentpole guarantees:

* identity (``sgd`` lr=1, no momentum) reproduces the pre-pipeline
  merges **byte-identically** in every strategy family;
* the adaptive families (FedAvgM / FedAdagrad / FedAdam / FedYogi) match
  an independent per-element scalar reference, and the fused Pallas
  kernel path matches the `tree_map` reference path to fp32 tolerance
  (``REPRO_AGG_KERNEL=0`` semantics);
* interrupt/resume replays byte-identically with non-trivial optimizer
  moments in flight (moments snapshot into the v2 array store).

Plus the unified empty-cohort / zero-update behaviour per training mode.
"""
import json
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClientHistoryDB, ClientUpdate, MergePipeline,
                        SERVER_OPTS, ServerOptConfig, StrategyConfig,
                        fedavg_aggregate, make_strategy)
from repro.core.aggregation import aggregate
from repro.faas import CostMeter, FaaSConfig, MockInvoker, SimulatedFaaSPlatform
from repro.faas.platform import ClientProfile
from repro.faas.trace import TraceRecorder
from repro.fl.checkpointing import RoundCheckpointer
from repro.fl.controller import TrainingDriver

IDS = [f"c{i}" for i in range(8)]


def _work_fn(cid, params, rnd):
    w = params["w"] + 0.1 * (rnd + 1)
    return ClientUpdate(cid, {"w": w}, 10, rnd), 10.0


class _StubPool:
    def __init__(self, client_ids):
        self._ids = list(client_ids)
        self.clients = {}

    @property
    def client_ids(self):
        return self._ids


def _driver(strategy_name="fedlesscan", seed=0, profiles=None, trace=None,
            round_timeout_s=60.0, clients_per_round=3, ids=None, **strat_kw):
    ids = IDS if ids is None else ids
    history = ClientHistoryDB()
    history.ensure(ids)
    strategy = make_strategy(
        strategy_name,
        StrategyConfig(clients_per_round=clients_per_round, max_rounds=10,
                       **strat_kw),
        history, seed=seed)
    platform = SimulatedFaaSPlatform(
        FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.3,
                   perf_variation=(0.9, 1.1), failure_rate=0.0,
                   network_jitter_s=0.4),
        seed=seed, recorder=trace)
    invoker = MockInvoker(platform, _work_fn, profiles or {})
    return TrainingDriver(strategy, invoker, _StubPool(ids), history,
                          CostMeter(trace=trace),
                          round_timeout_s=round_timeout_s,
                          eval_every=0, seed=seed, trace=trace)


def _rand_updates(rng, tree_like, k=4):
    def one():
        return {key: jnp.asarray(rng.normal(size=np.shape(val)),
                                 jnp.float32)
                for key, val in tree_like.items()}
    return [ClientUpdate(f"c{i}", one(), 10 + i, 0) for i in range(k)]


def _ravel(tree):
    return np.concatenate([np.asarray(tree[k], np.float64).ravel()
                           for k in sorted(tree)])


# ---------------------------------------------------------------- scalar ref
def _scalar_merge(cfg: ServerOptConfig, g, mats, coeffs, mix, m, v):
    """Independent per-element reference: plain Python floats, no jax."""
    out = list(g)
    for j in range(len(g)):
        s = sum(c * mat[j] for c, mat in zip(coeffs, mats))
        delta = mix * (s - g[j])
        if cfg.name in ("sgd", "fedavgm"):
            m[j] = cfg.momentum * m[j] + delta
            step = m[j]
        else:
            m[j] = cfg.b1 * m[j] + (1.0 - cfg.b1) * delta
            dsq = delta * delta
            if cfg.name == "fedadagrad":
                v[j] = v[j] + dsq
            elif cfg.name == "fedadam":
                v[j] = cfg.b2 * v[j] + (1.0 - cfg.b2) * dsq
            else:
                v[j] = v[j] - (1.0 - cfg.b2) * dsq * math.copysign(
                    1.0, v[j] - dsq) * (0.0 if v[j] == dsq else 1.0)
            step = m[j] / (math.sqrt(v[j]) + cfg.eps)
        out[j] = g[j] + cfg.lr * step
    return out, m, v


@pytest.mark.parametrize("opt", ["fedavgm", "fedadagrad", "fedadam",
                                 "fedyogi"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_server_opt_matches_scalar_reference(opt, use_kernel):
    """Randomized-pytree parity of each family against the per-element
    scalar reference, on both the kernel and the tree_map path."""
    rng = np.random.default_rng(7)
    like = {"b": jnp.zeros(3), "w": jnp.zeros((2, 4))}
    cfg = ServerOptConfig(name=opt, lr=0.3, momentum=0.9, b2=0.95)
    pipe = MergePipeline(cfg, use_kernel=use_kernel)
    g_tree = {k: jnp.asarray(rng.normal(size=np.shape(v)), jnp.float32)
              for k, v in like.items()}
    g = list(_ravel(g_tree))
    m = [0.0] * len(g)
    v = [0.0] * len(g)
    for _ in range(4):                      # several steps: moments live
        updates = _rand_updates(rng, like)
        coeffs = rng.uniform(0.05, 0.5, size=len(updates))
        g_tree = pipe.merge(g_tree, updates, coeffs, mix=0.8)
        mats = [list(_ravel(u.params)) for u in updates]
        g, m, v = _scalar_merge(cfg.normalized(), g, mats,
                                list(coeffs), 0.8, m, v)
        np.testing.assert_allclose(_ravel(g_tree), g, rtol=2e-4, atol=2e-5)
    assert pipe.steps == 4
    assert pipe.last_update_norm > 0.0


@pytest.mark.parametrize("opt", ["fedavgm", "fedadagrad", "fedadam",
                                 "fedyogi", "sgd"])
def test_kernel_and_reference_paths_agree(opt):
    """The fused fed_agg_apply kernel and the tree_map twin produce the
    same trajectory (params, moments, ‖Δ‖₂) to fp32 tolerance."""
    rng = np.random.default_rng(3)
    like = {"w": jnp.zeros((5, 7)), "b": jnp.zeros(11)}
    cfg = ServerOptConfig(name=opt, lr=0.5, momentum=0.8)
    kern = MergePipeline(cfg, use_kernel=True)
    tree = MergePipeline(cfg, use_kernel=False)
    gk = gt = {k: jnp.asarray(rng.normal(size=np.shape(v)), jnp.float32)
               for k, v in like.items()}
    for _ in range(3):
        updates = _rand_updates(rng, like)
        coeffs = rng.uniform(0.1, 0.4, size=len(updates))
        gk = kern.merge(gk, updates, coeffs, mix=0.9)
        gt = tree.merge(gt, updates, coeffs, mix=0.9)
        np.testing.assert_allclose(_ravel(gk), _ravel(gt),
                                   rtol=1e-4, atol=1e-5)
        assert kern.last_update_norm == pytest.approx(
            tree.last_update_norm, rel=1e-4)
    np.testing.assert_allclose(_ravel(kern._m), _ravel(tree._m),
                               rtol=1e-4, atol=1e-5)


def test_env_gate_reverts_to_reference_path(monkeypatch):
    """REPRO_AGG_KERNEL=0 (use_kernel unset) routes the optimizer merge
    through the tree_map path — same result as use_kernel=False."""
    rng = np.random.default_rng(5)
    like = {"w": jnp.zeros(6)}
    g = {"w": jnp.asarray(rng.normal(size=6), jnp.float32)}
    updates = _rand_updates(rng, like, k=3)
    coeffs = np.ones(3) / 3
    monkeypatch.setenv("REPRO_AGG_KERNEL", "0")
    auto = MergePipeline(ServerOptConfig(name="fedadam"))
    ref = MergePipeline(ServerOptConfig(name="fedadam"), use_kernel=False)
    out_a = auto.merge(g, updates, coeffs)
    out_r = ref.merge(g, updates, coeffs)
    assert np.array_equal(_ravel(out_a), _ravel(out_r))


# ------------------------------------------------------------ identity path
def test_identity_is_byte_identical_to_legacy_merges():
    h = ClientHistoryDB()
    rng = np.random.default_rng(1)
    ups = [ClientUpdate(f"c{i}",
                        {"w": jnp.asarray(rng.normal(size=9), jnp.float32)},
                        7 + i, 0) for i in range(4)]
    g = {"w": jnp.asarray(rng.normal(size=9), jnp.float32)}

    fedavg = make_strategy("fedavg", StrategyConfig(), h)
    assert fedavg.merger.is_identity
    got = fedavg.aggregate(ups, 0, global_params=g)
    want = fedavg_aggregate(ups)
    assert np.array_equal(np.asarray(got["w"]), np.asarray(want["w"]))

    fedasync = make_strategy("fedasync", StrategyConfig(), h)
    got = fedasync.on_client_finish(ups[0], 1.0, 2, 5, global_params=g)
    alpha = 0.6 * (3 + 1) ** -0.5
    anchor = ClientUpdate("__g__", g, 0, 5)
    want = aggregate([anchor, ups[0]], np.array([1 - alpha, alpha]))
    assert np.array_equal(np.asarray(got["w"]), np.asarray(want["w"]))

    # fedlesscan's staleness path: same-round + stale mix, legacy Eq. 3
    from repro.core import staleness_aggregate
    stale_mix = [ClientUpdate(u.client_id, u.params, u.num_samples, rn)
                 for u, rn in zip(ups, (3, 3, 2, 2))]
    fls = make_strategy("fedlesscan", StrategyConfig(), h)
    got = fls.aggregate(stale_mix, 3, now=0.0, global_params=g)
    want = staleness_aggregate(stale_mix, 3, tau=2)
    assert np.array_equal(np.asarray(got["w"]), np.asarray(want["w"]))

    # fedbuff's buffered flush: legacy (1−η)·global + η·weighted average
    fedbuff = make_strategy("fedbuff", StrategyConfig(buffer_k=2), h)
    assert fedbuff.on_client_finish(ups[0], 1.0, 4, 5,
                                    global_params=g) is None
    got = fedbuff.on_client_finish(ups[1], 2.0, 5, 5, global_params=g)
    eta = 0.7
    weights = np.array([ups[0].num_samples * (5 - 4 + 1) ** -0.5,
                        ups[1].num_samples * 1.0], dtype=np.float64)
    legacy = np.concatenate(([1.0 - eta], eta * weights / weights.sum()))
    want = aggregate([anchor, ups[0], ups[1]], legacy)
    assert np.array_equal(np.asarray(got["w"]), np.asarray(want["w"]))


def test_fedavgm_defaults_momentum_and_validates_name():
    assert ServerOptConfig(name="fedavgm").normalized().momentum == 0.9
    assert ServerOptConfig(name="fedavgm",
                           momentum=0.5).normalized().momentum == 0.5
    assert not ServerOptConfig(name="sgd", lr=0.5).is_identity
    assert ServerOptConfig().is_identity
    with pytest.raises(ValueError, match="unknown server optimizer"):
        MergePipeline(ServerOptConfig(name="adamw"))
    assert set(SERVER_OPTS) == {"sgd", "fedavgm", "fedadagrad",
                                "fedadam", "fedyogi"}


def test_moments_stay_fp32_for_low_precision_params(tmp_path):
    """bf16 model params must not quantize the fp32 moment buffers — on
    the kernel path (moments unravel through an f32 view, not the
    params-dtype unravel) or through a checkpoint round-trip (the array
    store restores server_opt/* entries as fp32)."""
    rng = np.random.default_rng(2)
    g = {"w": jnp.asarray(rng.normal(size=8), jnp.bfloat16)}
    updates = [ClientUpdate(f"c{i}",
                            {"w": jnp.asarray(rng.normal(size=8),
                                              jnp.bfloat16)}, 10, 0)
               for i in range(3)]
    pipe = MergePipeline(ServerOptConfig(name="fedadam"), use_kernel=True)
    out = pipe.merge(g, updates, np.ones(3) / 3)
    assert out["w"].dtype == jnp.bfloat16            # params keep dtype
    assert pipe._m["w"].dtype == jnp.float32         # moments stay fp32
    # the bf16-quantized copy differs — proves no round-trip happened
    exact = np.asarray(pipe._m["w"], np.float32)
    assert not np.array_equal(exact,
                              np.asarray(exact.astype(jnp.bfloat16),
                                         np.float32))

    # checkpoint round-trip through the npz array store keeps fp32 bits
    from repro.fl.checkpointing import (_atomic_write_npz, _flat_entries,
                                        _unflatten_like)
    entries = _flat_entries("extra|server_opt/m", pipe._m)
    path = tmp_path / "m.npz"
    _atomic_write_npz(path, entries)
    data = np.load(path)
    restored = _unflatten_like(data, "extra|server_opt/m", g,
                               force_dtype=np.float32)
    assert np.array_equal(np.asarray(restored["w"], np.float32), exact)


def test_opt_path_requires_global_params():
    rng = np.random.default_rng(0)
    ups = _rand_updates(rng, {"w": jnp.zeros(4)}, k=2)
    pipe = MergePipeline(ServerOptConfig(name="fedadam"))
    with pytest.raises(ValueError, match="needs the current global"):
        pipe.merge(None, ups, np.ones(2) / 2)


# ------------------------------------------------- empty-cohort unification
ALL_CRASH = {cid: ClientProfile(crash=True) for cid in IDS}


@pytest.mark.parametrize("strategy_name,mode",
                         [("fedavg", "sync"), ("fedlesscan", "semi-async"),
                          ("fedbuff", "async")])
def test_empty_cohort_keeps_params_unchanged(strategy_name, mode):
    """Every training mode: a cohort that delivers nothing leaves the
    global model unchanged and (in barrier modes) emits the zero-delta
    aggregation record."""
    trace = TraceRecorder()
    d = _driver(strategy_name, profiles=dict(ALL_CRASH), trace=trace,
                server_opt="fedadam")
    assert d.mode == mode
    w0 = jnp.arange(4, dtype=jnp.float32)
    params, res = d.run({"w": w0}, 2)
    assert np.array_equal(np.asarray(params["w"]), np.asarray(w0))
    assert d.strategy.merger.steps == 0
    aggs = trace.select("aggregation")
    if mode != "async":                    # async: no merge event fired
        assert aggs and all(a["merged"] == 0 for a in aggs)
        assert all(a["server_opt"] == "fedadam" for a in aggs)
        assert all(a["update_norm"] == 0.0 for a in aggs)


def test_direct_empty_aggregate_per_strategy():
    h = ClientHistoryDB()
    g = {"w": jnp.ones(3)}
    for name in ("fedavg", "fedprox", "fedlesscan", "safa",
                 "fedasync", "fedbuff"):
        strat = make_strategy(name, StrategyConfig(), h)
        assert strat.aggregate([], 0, global_params=g) is g
        assert strat.aggregate([], 0) is None      # legacy callers
        assert strat.last_aggregate_count == 0


def test_legacy_aggregate_override_still_runs():
    """Pre-pipeline Strategy subclasses (aggregate without the
    global_params kwarg) keep working: the driver detects the old
    signature and calls it the old way."""
    from repro.core import FedAvg

    class OldStyle(FedAvg):
        def aggregate(self, updates, round_number, now=None):
            self.last_aggregate_count = len(updates)
            return fedavg_aggregate(list(updates)) if updates else None

    history = ClientHistoryDB()
    history.ensure(IDS)
    strategy = OldStyle(StrategyConfig(clients_per_round=3, max_rounds=10),
                        history)
    platform = SimulatedFaaSPlatform(FaaSConfig(), seed=0)
    d = TrainingDriver(strategy, MockInvoker(platform, _work_fn, {}),
                       _StubPool(IDS), history, CostMeter(),
                       round_timeout_s=60.0, eval_every=0, seed=0)
    params, res = d.run({"w": jnp.zeros(4)}, 2)
    assert len(res.rounds) == 2
    assert res.rounds[-1].aggregated_updates == 3


# ----------------------------------------------------- traces + checkpoints
def test_aggregation_records_carry_server_opt_metadata():
    trace = TraceRecorder()
    d = _driver("fedlesscan", trace=trace, server_opt="fedyogi",
                server_opt_lr=0.5)
    d.run({"w": jnp.zeros(4)}, 2)
    aggs = trace.select("aggregation")
    assert len(aggs) == 2
    for a in aggs:
        assert a["server_opt"] == "fedyogi"
        assert a["update_norm"] > 0.0
    assert [a["server_steps"] for a in aggs] == [1, 2]


def test_identity_traces_unchanged_by_pipeline():
    """The default server opt adds no fields — aggregation records keep
    the exact pre-pipeline shape (byte-compat for legacy traces)."""
    trace = TraceRecorder()
    d = _driver("fedavg", trace=trace)
    d.run({"w": jnp.zeros(4)}, 1)
    (agg,) = trace.select("aggregation")
    assert set(agg) == {"type", "time", "round", "merged", "strategy",
                        "mode"}


def _lines(recorder):
    return [json.dumps(r, sort_keys=True) for r in recorder.records]


SPAN_PROFILES = {cid: ClientProfile(slow_factor=8.0)
                 for cid in ("c0", "c1", "c2")}


def test_fedadam_resume_is_byte_identical_with_moments_in_flight(tmp_path):
    """Interrupt/resume in semi-async mode with fedadam: the checkpoint
    snapshots non-zero optimizer moments, and the resumed run replays the
    remaining timeline byte-identically (params + JSONL trace, which now
    includes update_norm diagnostics)."""
    kw = dict(profiles=dict(SPAN_PROFILES), server_opt="fedadam",
              server_opt_lr=0.7)
    ref_trace = TraceRecorder()
    ref = _driver("fedlesscan", trace=ref_trace, **kw)
    ref_params, _ = ref.run({"w": jnp.zeros(4)}, 6)

    t1 = TraceRecorder()
    first = _driver("fedlesscan", trace=t1, **kw)
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    first.run({"w": jnp.zeros(4)}, 2, checkpointer=ckpt, checkpoint_every=2)

    # the snapshot carries real moments: fedadam stepped twice by now
    state = json.loads((tmp_path / "ckpt" / "round_000002.json").read_text())
    merger_state = state["strategy_state"]["merger"]
    assert merger_state == {"name": "fedadam", "steps": 2,
                            "has_m": True, "has_v": True}
    assert {"server_opt/m", "server_opt/v"} <= set(state["array_keys"])

    t2 = TraceRecorder()
    resumed = _driver("fedlesscan", trace=t2, **kw)
    params0, next_round = ckpt.restore(resumed, {"w": jnp.zeros(4)})
    assert next_round == 2
    assert resumed.strategy.merger.steps == 2
    assert resumed.strategy.merger._m is not None
    tail_params, _ = resumed.run(params0, 6, start_round=next_round)

    assert np.array_equal(np.asarray(tail_params["w"]),
                          np.asarray(ref_params["w"]))
    assert _lines(t1) + _lines(t2) == _lines(ref_trace)


def test_async_fedbuff_resume_with_moments(tmp_path):
    """Barrier-free resume with a non-identity server opt: event-horizon
    snapshot mid-run, moments restored, byte-identical trace tail."""
    kw = dict(profiles={"c0": ClientProfile(slow_factor=8.0)},
              server_opt="fedyogi", server_opt_lr=0.4)
    ck = RoundCheckpointer(tmp_path / "ck", keep=50)
    ref_trace = TraceRecorder()
    ref = _driver("fedbuff", trace=ref_trace, **kw)
    ref_params, _ = ref.run({"w": jnp.zeros(4)}, 4,
                            checkpointer=ck, checkpoint_every=15.0)
    tags = ck.rounds()
    assert len(tags) >= 2
    tag = tags[len(tags) // 2]
    state = json.loads((tmp_path / "ck" / f"round_{tag:06d}.json")
                       .read_text())
    offset = state["trace_offset"]
    assert state["strategy_state"]["merger"]["steps"] > 0

    t2 = TraceRecorder()
    resumed = _driver("fedbuff", trace=t2, **kw)
    params0, _ = ck.restore(resumed, {"w": jnp.zeros(4)}, round_number=tag)
    tail_params, _ = resumed.run(params0, 4)
    assert np.array_equal(np.asarray(tail_params["w"]),
                          np.asarray(ref_params["w"]))
    assert _lines(t2) == _lines(ref_trace)[offset:]


def test_moment_free_checkpoint_migrates_to_fresh_optimizer(tmp_path):
    """A checkpoint written before the merge pipeline (no `merger` state)
    restores with a fresh optimizer: moments re-accumulate from the
    resume point instead of failing."""
    d = _driver("fedlesscan", server_opt="fedadam")
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    params, _ = d.run({"w": jnp.zeros(4)}, 2,
                      checkpointer=ckpt, checkpoint_every=2)
    spath = tmp_path / "ckpt" / "round_000002.json"
    state = json.loads(spath.read_text())
    del state["strategy_state"]["merger"]        # moment-free snapshot
    state["array_keys"] = [k for k in state["array_keys"]
                           if not k.startswith("server_opt/")]
    spath.write_text(json.dumps(state))

    resumed = _driver("fedlesscan", server_opt="fedadam")
    params0, next_round = ckpt.restore(resumed, {"w": jnp.zeros(4)})
    assert next_round == 2
    assert resumed.strategy.merger.steps == 0
    assert resumed.strategy.merger._m is None
    resumed.run(params0, 3, start_round=next_round)   # keeps running
    assert resumed.strategy.merger.steps == 1


def test_restore_rejects_server_opt_mismatch(tmp_path):
    d = _driver("fedlesscan", server_opt="fedadam")
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    params, _ = d.run({"w": jnp.zeros(4)}, 2,
                      checkpointer=ckpt, checkpoint_every=2)
    other = _driver("fedlesscan", server_opt="fedyogi")
    with pytest.raises(ValueError, match="server"):
        ckpt.restore(other, {"w": jnp.zeros(4)})


def test_experiment_surface_threads_server_opt(tmp_path):
    """ExperimentConfig.server_opt* reaches the strategy's pipeline and
    the exported trace."""
    from repro.data import label_sorted_shards, make_image_classification
    from repro.data.synthetic import ArrayDataset
    from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                     run_experiment)
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import make_cnn

    full = make_image_classification(200, image_size=14, n_classes=3, seed=0)
    train = ArrayDataset(full.x[:160], full.y[:160])
    parts = label_sorted_shards(train, 6, 2, seed=0)
    task = ClassificationTask(
        make_cnn(14, 1, 3, 16, "srvopt_cnn"),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    trace_path = tmp_path / "trace.jsonl"
    cfg = ExperimentConfig(
        strategy="fedavg", n_rounds=2, clients_per_round=3, eval_every=0,
        seed=0, server_opt="fedadam", server_opt_lr=0.1,
        trace_path=str(trace_path),
        scenario=ScenarioConfig(round_timeout_s=60.0, seed=0))
    res = run_experiment(task, parts, None, cfg)
    assert len(res.rounds) == 2
    from repro.faas.trace import load_jsonl
    aggs = [r for r in load_jsonl(trace_path) if r["type"] == "aggregation"]
    assert aggs and all(a["server_opt"] == "fedadam" for a in aggs)
