"""Compressed client updates + mesh-sharded merge (kernels/compress.py,
core/compress.py, fed_agg shard_map path).

Covers the tentpole guarantees:

* int8 per-chunk quantization round-trips exactly on representable
  grids and matches the numpy oracle bit-for-bit;
* top-k keeps deterministic tie order (lowest index wins) and the
  Pallas mask decode equals the scatter decode;
* error feedback telescopes: cumulative decoded + current residual
  equals the cumulative injected delta (the EF-SGD invariant), as a
  deterministic check and as a hypothesis property when available;
* compressed runs reach convergence parity with dense in all three
  training modes while cutting wire bytes ≥ 10× at top-k@1%;
* the mesh-sharded merge matches the single-device kernel (in-process
  single-device fallback + a 2-forced-device subprocess);
* trace/billing byte-parity: dense runs emit byte-identical record
  shapes (no payload fields, no egress lines), compressed runs gain
  exactly the new fields;
* error-feedback residuals ride the v2 checkpoint array store.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import ClientUpdate
from repro.core.compress import CompressionConfig, UpdateCompressor
from repro.core.history import ClientHistoryDB
from repro.core.strategies import StrategyConfig, make_strategy
from repro.data import label_sorted_shards, make_image_classification
from repro.faas.cost import CostMeter, PriceBook, egress_cost
from repro.faas.invoker import MockInvoker
from repro.faas.platform import FaaSConfig, SimulatedFaaSPlatform
from repro.faas.trace import TraceRecorder
from repro.fl.client import ClientPool
from repro.fl.controller import TrainingDriver
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.kernels import ops
from repro.kernels.ref import int8_decode_ref, int8_encode_ref, topk_ref


# ---------------------------------------------------------------- kernels
def test_int8_roundtrip_exact_on_representable_grid():
    """Integer multiples of a power-of-two scale survive the quantizer
    exactly: scale = absmax/127 is itself a power of two, so q·scale
    reproduces every input bit-for-bit."""
    rng = np.random.default_rng(0)
    scale = 2.0 ** -3
    x = (rng.integers(-127, 128, size=600).astype(np.float32) * scale)
    x[0] = 127 * scale                     # pin absmax to the grid edge
    q, s = ops.int8_encode(jnp.asarray(x), chunk=256)
    out = ops.int8_decode(q, s, x.size)
    np.testing.assert_array_equal(np.asarray(out), x)


def test_int8_matches_numpy_oracle():
    rng = np.random.default_rng(1)
    for n, chunk in ((1000, 256), (64, 16), (257, 256), (5, 8)):
        x = rng.normal(size=n).astype(np.float32) * rng.uniform(0.01, 10)
        q, s = ops.int8_encode(jnp.asarray(x), chunk=chunk)
        q_ref, s_ref = int8_encode_ref(x, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(q), q_ref)
        np.testing.assert_array_equal(np.asarray(s), s_ref)
        out = ops.int8_decode(q, s, n)
        np.testing.assert_array_equal(np.asarray(out),
                                      int8_decode_ref(q_ref, s_ref, n))


def test_int8_zero_chunk_is_safe():
    x = np.zeros(512, np.float32)
    q, s = ops.int8_encode(jnp.asarray(x), chunk=256)
    assert not np.any(np.asarray(q))
    np.testing.assert_array_equal(np.asarray(ops.int8_decode(q, s, 512)), x)


def test_topk_tie_stability_lowest_index_wins():
    """20 equal-magnitude entries, k=5: the kept set is exactly the five
    lowest indices — deterministic across runs and identical between the
    mask-kernel decode and the scatter decode."""
    x = jnp.asarray(np.tile([1.0, -1.0], 10).astype(np.float32))
    idx, vals, decoded = ops.topk_encode(x, 5)
    np.testing.assert_array_equal(np.sort(np.asarray(idx)), np.arange(5))
    want = np.zeros(20, np.float32)
    want[:5] = np.asarray(x)[:5]
    np.testing.assert_array_equal(np.asarray(decoded), want)
    np.testing.assert_array_equal(
        np.asarray(ops.topk_decode(idx, vals, 20)), want)


def test_topk_matches_numpy_oracle():
    rng = np.random.default_rng(2)
    for n, k in ((1000, 10), (4096, 41), (100, 100), (50, 80)):
        x = rng.normal(size=n).astype(np.float32)
        idx, vals, decoded = ops.topk_encode(jnp.asarray(x), k)
        _, _, ref = topk_ref(jnp.asarray(x), k)
        np.testing.assert_array_equal(np.asarray(decoded), np.asarray(ref))
        np.testing.assert_array_equal(
            np.asarray(ops.topk_decode(idx, vals, n)), np.asarray(ref))


# ------------------------------------------------------- error feedback
def _ef_telescopes(deltas, scheme, **cfg_kw):
    """EF invariant: Σ decoded_i + residual_N == Σ delta_i."""
    comp = UpdateCompressor(CompressionConfig(scheme=scheme,
                                              error_feedback=True, **cfg_kw))
    g = {"w": jnp.zeros(deltas[0].size, jnp.float32)}
    total_delta = np.zeros(deltas[0].size, np.float64)
    total_decoded = np.zeros(deltas[0].size, np.float64)
    for d in deltas:
        u = {"w": jnp.asarray(d)}
        recon, payload, dense = comp.encode("c0", u, g)
        assert payload is not None and dense == d.size * 4
        total_delta += d.astype(np.float64)
        total_decoded += np.asarray(recon["w"], np.float64)
    residual = np.asarray(comp._residuals["c0"], np.float64)
    np.testing.assert_allclose(total_decoded + residual, total_delta,
                               rtol=1e-4, atol=1e-5)


def test_error_feedback_telescopes_deterministic():
    rng = np.random.default_rng(3)
    deltas = [rng.normal(size=300).astype(np.float32) for _ in range(5)]
    _ef_telescopes(deltas, "topk", topk_ratio=0.05)
    _ef_telescopes(deltas, "int8", chunk=64)


def test_error_feedback_accumulation_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 2**32 - 1), min_size=2, max_size=6),
           st.sampled_from(["topk", "int8"]))
    def prop(seeds, scheme):
        deltas = [np.random.default_rng(s).normal(size=128)
                  .astype(np.float32) for s in seeds]
        kw = ({"topk_ratio": 0.1} if scheme == "topk" else {"chunk": 32})
        _ef_telescopes(deltas, scheme, **kw)

    prop()


def test_error_feedback_changes_second_encode():
    """With EF the dropped mass feeds back: encoding the same update
    twice yields different reconstructions; without EF it is a pure
    function of the delta."""
    rng = np.random.default_rng(4)
    g = {"w": jnp.zeros(200, jnp.float32)}
    u = {"w": jnp.asarray(rng.normal(size=200), jnp.float32)}
    for ef, expect_same in ((True, False), (False, True)):
        comp = UpdateCompressor(CompressionConfig(
            scheme="topk", topk_ratio=0.05, error_feedback=ef))
        r1, _, _ = comp.encode("a", u, g)
        r2, _, _ = comp.encode("a", u, g)
        assert bool(jnp.array_equal(r1["w"], r2["w"])) == expect_same


def test_repro_compress_env_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_COMPRESS", "0")
    comp = UpdateCompressor(CompressionConfig(scheme="topk"))
    u = {"w": jnp.ones(10)}
    recon, payload, dense = comp.encode("a", u, {"w": jnp.zeros(10)})
    assert payload is None and dense is None
    assert recon is u


# ------------------------------------------------- end-to-end parity
IMG, CLASSES, N_CLIENTS = 14, 3, 8


@pytest.fixture(scope="module")
def fl_setup():
    from repro.data.synthetic import ArrayDataset
    from repro.models.small import make_cnn
    full = make_image_classification(460, image_size=IMG,
                                     n_classes=CLASSES, seed=0)
    train = ArrayDataset(full.x[:380], full.y[:380])
    test = ArrayDataset(full.x[380:], full.y[380:])
    parts = label_sorted_shards(train, N_CLIENTS, 2, seed=0)
    # local SGD keeps client deltas heavy-tailed, which is the regime
    # top-k sparsification is built for (Adam whitens the delta spectrum
    # and makes a 1% keep-rate uninformative at this tiny scale)
    task = ClassificationTask(
        make_cnn(IMG, 1, CLASSES, 8, "compress_test_cnn"),
        TaskConfig(epochs=2, batch_size=32, optimizer="sgd",
                   learning_rate=0.05, per_sample_time_s=0.01))
    return task, parts, test


def _run_fl(fl_setup, strategy_name, compressor=None, trace=None,
            rounds=10, seed=0):
    task, parts, test = fl_setup
    history = ClientHistoryDB()
    history.ensure(parts.keys())
    strategy = make_strategy(
        strategy_name,
        StrategyConfig(clients_per_round=N_CLIENTS, max_rounds=rounds),
        history, seed=seed)
    pool = ClientPool(task, parts, None, seed=seed, compressor=compressor)
    platform = SimulatedFaaSPlatform(
        FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.3,
                   perf_variation=(0.9, 1.1), failure_rate=0.0,
                   network_jitter_s=0.4),
        seed=seed, recorder=trace)
    invoker = MockInvoker(platform, pool.work_fn, {})
    driver = TrainingDriver(strategy, invoker, pool, history,
                            CostMeter(trace=trace), round_timeout_s=90.0,
                            eval_every=0, seed=seed, trace=trace)
    params, result = driver.run(task.init_params(seed), rounds)
    _, loss = task.evaluate(params, test)
    return loss, result, driver


@pytest.mark.parametrize("strategy_name", ["fedavg", "fedlesscan",
                                           "fedbuff"])
def test_compressed_vs_dense_convergence_parity(fl_setup, strategy_name):
    """Top-k@1% with error feedback reaches the dense final loss (within
    tolerance) in every training mode — sync, semi-async, and
    barrier-free — while cutting wire bytes ≥ 10×."""
    dense_loss, _, _ = _run_fl(fl_setup, strategy_name)
    comp = UpdateCompressor(CompressionConfig(scheme="topk",
                                              topk_ratio=0.01))
    comp_loss, result, driver = _run_fl(fl_setup, strategy_name,
                                        compressor=comp)
    assert comp_loss <= dense_loss + 0.5, (
        f"{strategy_name}: compressed loss {comp_loss:.4f} vs dense "
        f"{dense_loss:.4f}")
    # ≥10× reduction at top-k@1% (analytically 50×: 8 bytes/entry kept
    # vs 4 bytes/param dense)
    res = next(iter(comp._residuals.values()))
    P = int(res.shape[0])
    k = max(1, int(round(P * 0.01)))
    assert P * 4 >= 10 * k * 8
    assert driver.cost.total > 0


def test_compressed_update_carries_wire_size(fl_setup):
    task, parts, _ = fl_setup
    comp = UpdateCompressor(CompressionConfig(scheme="int8", chunk=256))
    pool = ClientPool(task, parts, None, seed=0, compressor=comp)
    cid = pool.client_ids[0]
    g = task.init_params(0)
    update, work_s = pool.work_fn(cid, g, 0)
    P = sum(int(np.prod(np.shape(l)))
            for l in jax.tree_util.tree_leaves(g))
    assert update.dense_bytes == P * 4
    assert update.payload_bytes == P + (-(-P // 256)) * 4
    assert update.payload_bytes < update.dense_bytes
    # the record round-trip preserves the byte fields
    rec = json.loads(json.dumps({
        "client_id": update.client_id, "num_samples": update.num_samples,
        "round_number": update.round_number,
        "payload_bytes": update.payload_bytes,
        "dense_bytes": update.dense_bytes}))
    assert rec["payload_bytes"] == update.payload_bytes


# --------------------------------------------------- trace byte-parity
def test_dense_trace_shape_unchanged_compressed_gains_fields(fl_setup):
    dense_trace = TraceRecorder()
    _run_fl(fl_setup, "fedavg", trace=dense_trace, rounds=2)
    comp_trace = TraceRecorder()
    comp = UpdateCompressor(CompressionConfig(scheme="topk",
                                              topk_ratio=0.01))
    _run_fl(fl_setup, "fedavg", compressor=comp, trace=comp_trace,
            rounds=2)

    dense_recs = dense_trace.records
    comp_recs = comp_trace.records
    # dense: aggregation records keep the exact legacy key set, attempt
    # records carry no payload field, and there are no egress lines
    for r in dense_recs:
        if r["type"] == "aggregation":
            assert set(r) == {"type", "time", "round", "merged",
                              "strategy", "mode"}
        assert "payload_bytes" not in r or r["type"] != "attempt"
        if r["type"] == "billing":
            assert r["kind"] != "egress"
    # compressed: every successful attempt carries the wire size, every
    # aggregation carries the round's payload total + achieved ratio,
    # and egress billing lines appear
    agg = [r for r in comp_recs if r["type"] == "aggregation"]
    assert agg and all("payload_bytes" in r and "compression_ratio" in r
                       for r in agg)
    assert all(r["compression_ratio"] > 10 for r in agg)
    att = [r for r in comp_recs
           if r["type"] == "attempt" and r.get("status") == "ok"]
    assert att and all("payload_bytes" in r for r in att)
    egress = [r for r in comp_recs
              if r["type"] == "billing" and r["kind"] == "egress"]
    assert egress
    total_egress = sum(r["cost"] for r in egress)
    assert total_egress > 0


def test_egress_cost_math():
    assert egress_cost(2**30) == pytest.approx(0.12)
    assert egress_cost(0) == 0.0
    meter = CostMeter(prices=PriceBook())
    assert meter.charge_egress(None) == 0.0
    assert meter.invocations == 0          # dense no-op leaves no record
    c = meter.charge_egress(2**20, client_id="a", round_number=3)
    assert c == pytest.approx(0.12 / 1024)
    assert meter.by_client["a"] == pytest.approx(c)
    assert meter.rounds[3] == pytest.approx(c)


def test_transfer_time_extends_billable_duration(fl_setup):
    """A compressed update's upload rides the invocation's billable
    window: with a tiny simulated bandwidth the same seed's attempts get
    strictly longer; dense runs never see a transfer term."""
    task, parts, _ = fl_setup

    def run(compressor, bw):
        history = ClientHistoryDB()
        history.ensure(parts.keys())
        strategy = make_strategy(
            "fedavg", StrategyConfig(clients_per_round=4, max_rounds=2),
            history, seed=0)
        pool = ClientPool(task, parts, None, seed=0, compressor=compressor)
        platform = SimulatedFaaSPlatform(
            FaaSConfig(failure_rate=0.0, upload_bandwidth_bps=bw),
            seed=0)
        driver = TrainingDriver(strategy,
                                MockInvoker(platform, pool.work_fn, {}),
                                pool, history, CostMeter(),
                                round_timeout_s=600.0, eval_every=0,
                                seed=0)
        _, result = driver.run(task.init_params(0), 1)
        return result.rounds[0].duration_s

    dense_slow_bw = run(None, 1e3)
    dense_fast_bw = run(None, 1e12)
    assert dense_slow_bw == dense_fast_bw    # no payload → bw never read
    comp = lambda: UpdateCompressor(CompressionConfig(scheme="topk",
                                                      topk_ratio=0.01))
    comp_slow = run(comp(), 1e4)
    comp_fast = run(comp(), 1e12)
    assert comp_slow > comp_fast


# ------------------------------------------------------ sharded merge
def test_sharded_merge_single_device_fallback():
    """mesh.size == 1 falls back to the single-device kernel exactly."""
    from repro.launch.mesh import make_host_mesh
    rng = np.random.default_rng(5)
    upd = jnp.asarray(rng.normal(size=(4, 777)), jnp.float32)
    coeffs = jnp.asarray(rng.uniform(0.1, 0.4, size=4), jnp.float32)
    mesh = make_host_mesh()
    got = ops.fed_agg_sharded(upd, coeffs, mesh)
    want = ops.fed_agg(upd, coeffs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


MULTI_DEVICE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.kernels import ops
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(data=2)
    assert int(mesh.size) == 2
    rng = np.random.default_rng(0)
    K, P = 5, 1003                       # P not divisible by the mesh
    upd = jnp.asarray(rng.normal(size=(K, P)), jnp.float32)
    coeffs = jnp.asarray(rng.uniform(0.05, 0.4, size=K), jnp.float32)
    params = jnp.asarray(rng.normal(size=P), jnp.float32)
    m = jnp.zeros(P, jnp.float32)
    v = jnp.zeros(P, jnp.float32)
    got = ops.fed_agg_sharded(upd, coeffs, mesh)
    want = ops.fed_agg(upd, coeffs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    for opt in ("sgd", "fedavgm", "fedadam", "fedyogi", "fedadagrad"):
        gs = ops.fed_agg_apply_sharded(
            upd, coeffs, params, m, v, 0.3, 0.8, 0.9, 0.95, 1e-3,
            opt=opt, mesh=mesh)
        g1 = ops.fed_agg_apply(
            upd, coeffs, params, m, v, 0.3, 0.8, 0.9, 0.95, 1e-3,
            opt=opt)
        for a, b in zip(gs, g1):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)
    print("SHARDED-OK")
""")


def test_sharded_merge_two_device_subprocess():
    res = subprocess.run([sys.executable, "-c", MULTI_DEVICE_SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         cwd="/root/repo")
    assert "SHARDED-OK" in res.stdout, res.stdout + res.stderr


def test_merge_pipeline_mesh_dispatch_matches_default(fl_setup):
    """A single-device mesh on the MergePipeline changes nothing — the
    sharded dispatch is bitwise-inert until devices exist."""
    from repro.core.merge import MergePipeline, ServerOptConfig
    from repro.launch.mesh import make_host_mesh
    rng = np.random.default_rng(6)
    like = {"w": jnp.zeros((3, 5)), "b": jnp.zeros(4)}
    g = {k: jnp.asarray(rng.normal(size=np.shape(v)), jnp.float32)
         for k, v in like.items()}
    updates = [ClientUpdate(f"c{i}",
                            {k: jnp.asarray(rng.normal(size=np.shape(v)),
                                            jnp.float32)
                             for k, v in like.items()}, 10, 0)
               for i in range(3)]
    coeffs = rng.uniform(0.1, 0.5, size=3)
    cfg = ServerOptConfig(name="fedadam", lr=0.2)
    plain = MergePipeline(cfg).merge(dict(g), updates, coeffs, mix=0.7)
    meshed = MergePipeline(cfg, mesh=make_host_mesh()).merge(
        dict(g), updates, coeffs, mix=0.7)
    for k in like:
        np.testing.assert_array_equal(np.asarray(plain[k]),
                                      np.asarray(meshed[k]))


# -------------------------------------------------------- checkpointing
def test_compressor_state_roundtrips_through_array_store(tmp_path):
    rng = np.random.default_rng(7)
    like = {"w": jnp.zeros((4, 3)), "b": jnp.zeros(5)}
    g = {k: jnp.zeros(np.shape(v), jnp.float32) for k, v in like.items()}
    comp = UpdateCompressor(CompressionConfig(scheme="topk",
                                              topk_ratio=0.1))
    for cid in ("c1", "c0"):
        u = {k: jnp.asarray(rng.normal(size=np.shape(v)), jnp.float32)
             for k, v in like.items()}
        comp.encode(cid, u, g)
    arrays = {}
    state = comp.state_dict(arrays)
    assert state["clients"] == ["c0", "c1"]
    assert set(arrays) == {"compress/residual/c0", "compress/residual/c1"}
    # every residual tree shares the model-params structure (the v2
    # checkpoint contract) and stays fp32
    for tree in arrays.values():
        assert set(tree) == set(like)
        assert all(np.asarray(l).dtype == np.float32
                   for l in jax.tree_util.tree_leaves(tree))
    fresh = UpdateCompressor(CompressionConfig(scheme="topk",
                                               topk_ratio=0.1))
    fresh.load_state_dict(state, arrays)
    for cid in ("c0", "c1"):
        np.testing.assert_array_equal(np.asarray(fresh._residuals[cid]),
                                      np.asarray(comp._residuals[cid]))
    mismatched = UpdateCompressor(CompressionConfig(scheme="int8"))
    with pytest.raises(ValueError, match="scheme"):
        mismatched.load_state_dict(state, arrays)


def test_driver_checkpoint_carries_compressor_only_when_active(fl_setup):
    _, _, dense_driver = _run_fl(fl_setup, "fedavg", rounds=1)
    state = dense_driver.checkpoint_state({})
    assert "compressor" not in state

    comp = UpdateCompressor(CompressionConfig(scheme="topk",
                                              topk_ratio=0.01))
    _, _, driver = _run_fl(fl_setup, "fedavg", compressor=comp, rounds=1)
    arrays = {}
    state = driver.checkpoint_state(arrays)
    assert state["compressor"]["scheme"] == "topk"
    assert any(k.startswith("compress/residual/") for k in arrays)


def test_checkpoint_resume_preserves_compressed_run(fl_setup, tmp_path):
    """Interrupt/resume with compression on replays the uninterrupted
    run exactly: residuals restore from the array store, so the resumed
    encodes (and therefore the merged models) match bit-for-bit."""
    from repro.fl.checkpointing import RoundCheckpointer
    task, _, test = fl_setup

    def run(resume_dir=None, save_dir=None, rounds=4):
        comp = UpdateCompressor(CompressionConfig(scheme="topk",
                                                  topk_ratio=0.01))
        loss, result, driver = None, None, None
        history = ClientHistoryDB()
        parts = fl_setup[1]
        history.ensure(parts.keys())
        strategy = make_strategy(
            "fedavg",
            StrategyConfig(clients_per_round=N_CLIENTS, max_rounds=rounds),
            history, seed=0)
        pool = ClientPool(task, parts, None, seed=0, compressor=comp)
        platform = SimulatedFaaSPlatform(
            FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.3,
                       perf_variation=(0.9, 1.1), failure_rate=0.0,
                       network_jitter_s=0.4), seed=0)
        driver = TrainingDriver(strategy,
                                MockInvoker(platform, pool.work_fn, {}),
                                pool, history, CostMeter(),
                                round_timeout_s=90.0, eval_every=0, seed=0)
        params = task.init_params(0)
        start = 0
        ck = None
        if resume_dir is not None:
            params, start = RoundCheckpointer(resume_dir).restore(
                driver, params)
        if save_dir is not None:
            ck = RoundCheckpointer(save_dir)
        params, _ = driver.run(params, rounds, start_round=start,
                               checkpointer=ck,
                               checkpoint_every=2 if ck else 0)
        return params

    ckpt = tmp_path / "ck"
    clean = run(save_dir=str(ckpt))
    resumed = run(resume_dir=str(ckpt))
    flat_c = np.concatenate([np.asarray(l).ravel() for l in
                             jax.tree_util.tree_leaves(clean)])
    flat_r = np.concatenate([np.asarray(l).ravel() for l in
                             jax.tree_util.tree_leaves(resumed)])
    np.testing.assert_array_equal(flat_c, flat_r)


# ------------------------------------------------------- tier-2 (slow)
@pytest.mark.slow
def test_gemma_scale_compression_sweep(tmp_path):
    """gemma3-1b-scale codec cells: ≥10× at top-k@1% holds at the 1B
    parameter count, and the bench's extrapolated figures land in
    results/BENCH_compression.json (run with -m slow / --model gemma)."""
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_compression",
         "--model", "gemma", "--gemma-shards", "1"],
        capture_output=True, text=True, timeout=3600,
        cwd=str(pathlib.Path(__file__).resolve().parents[1]),
        env={**os.environ, "PYTHONPATH": "src"})
    assert res.returncode == 0, res.stdout + res.stderr
    repo = pathlib.Path(__file__).resolve().parents[1]
    grid = json.loads((repo / "results"
                       / "BENCH_compression.json").read_text())
    cells = grid["gemma3-1b"]["cells"]
    assert cells["topk@1%"]["compression_ratio"] >= 10
    assert cells["topk@1%"]["param_count"] > 5e8
