"""Tests for the barrier-free training runtime and trace export.

Covers: the mode-agnostic TrainingDriver (mode derivation, barrier API
guard), FedAsync merge-per-arrival with staleness damping, FedBuff
buffer-K flushes, crash detection + exponential backoff in the async
rotation, windowed EUR accounting, trace determinism and the
billing-record round-trip, and the telemetry-reactive routing policy.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClientHistoryDB, ClientUpdate, StrategyConfig,
                        make_strategy)
from repro.faas import (ClientProfile, CostMeter, FaaSConfig, MockInvoker,
                        SimulatedFaaSPlatform, TelemetryRoutingPolicy,
                        TraceRecorder)
from repro.fl.controller import TrainingDriver


# ---------------------------------------------------------------- helpers
def _work_fn(cid, params, rnd):
    return ClientUpdate(cid, {"w": jnp.full((4,), 1.0)}, 10, rnd), 10.0


class _StubPool:
    def __init__(self, client_ids):
        self._ids = list(client_ids)
        self.clients = {}

    @property
    def client_ids(self):
        return self._ids


def _driver(client_ids, strategy_name, profiles=None, cohort=3,
            round_timeout_s=30.0, seed=0, trace=None, jitter=0.0,
            failure_rate=0.0, mode=None, max_concurrency=None, **strat_kw):
    history = ClientHistoryDB()
    history.ensure(client_ids)
    strategy = make_strategy(
        strategy_name,
        StrategyConfig(clients_per_round=cohort, max_rounds=20, **strat_kw),
        history, seed=seed)
    platform = SimulatedFaaSPlatform(
        FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.0,
                   perf_variation=(1.0, 1.0), failure_rate=failure_rate,
                   network_jitter_s=jitter),
        seed=seed, recorder=trace)
    invoker = MockInvoker(platform, _work_fn, profiles or {})
    return TrainingDriver(strategy, invoker, _StubPool(client_ids), history,
                          CostMeter(trace=trace),
                          round_timeout_s=round_timeout_s, eval_every=0,
                          max_concurrency=max_concurrency,
                          mode=mode, trace=trace)


# ---------------------------------------------------------------- modes
def test_mode_derived_from_strategy():
    assert _driver(["a"], "fedavg").mode == "sync"
    assert _driver(["a"], "fedlesscan").mode == "semi-async"
    assert _driver(["a"], "fedasync").mode == "async"
    assert _driver(["a"], "fedbuff").mode == "async"


def test_async_mode_requires_barrier_free_strategy():
    with pytest.raises(ValueError, match="barrier"):
        _driver(["a"], "fedavg", mode="async")


def test_unknown_mode_rejected():
    with pytest.raises(ValueError, match="unknown mode"):
        _driver(["a"], "fedavg", mode="turbo")


# ---------------------------------------------------------------- fedasync
def test_fedasync_merges_every_arrival_and_reinvokes():
    d = _driver(["a", "b", "c"], "fedasync", cohort=3)
    params, res = d.run({"w": jnp.zeros(4)}, 4)
    # budget: 4 "rounds" x cohort 3 = 12 delivered updates, merged 1:1
    # (a trailing accounting window may follow, billing abandoned
    # in-flight invocations without an aggregation)
    merges = [r for r in res.rounds if r.aggregated_updates > 0]
    assert len(merges) == 12
    assert all(r.aggregated_updates == 1 for r in merges)
    assert sum(len(r.successes) for r in res.rounds) == 12
    # every window re-invoked exactly one client: EUR 1.0 throughout
    assert res.mean_eur == pytest.approx(1.0)
    # the global model moved toward the clients' w=1
    assert float(params["w"][0]) > 0.9
    assert res.mode == "async"


def test_fedasync_staleness_damps_late_updates():
    cfg = StrategyConfig(clients_per_round=2, async_alpha=0.5,
                         staleness_exponent=1.0)
    history = ClientHistoryDB()
    strat = make_strategy("fedasync", cfg, history)
    g = {"w": jnp.zeros(2)}
    upd = ClientUpdate("c", {"w": jnp.ones(2)}, 10, 0)
    fresh = strat.on_client_finish(upd, arrival_time=1.0, producing_round=5,
                                   current_round=5, global_params=g)
    stale = strat.on_client_finish(upd, arrival_time=1.0, producing_round=1,
                                   current_round=5, global_params=g)
    # staleness 0: w <- 0.5*1; staleness 4: alpha/(4+1) = 0.1
    assert float(fresh["w"][0]) == pytest.approx(0.5, abs=1e-5)
    assert float(stale["w"][0]) == pytest.approx(0.1, abs=1e-5)
    # barrier delivery (no global params) keeps the old behaviour: no merge
    assert strat.on_client_finish(upd, 1.0, 5, 5) is None


# ---------------------------------------------------------------- fedbuff
def test_fedbuff_flushes_every_k_arrivals():
    d = _driver(["a", "b", "c", "d"], "fedbuff", cohort=4, buffer_k=2)
    params, res = d.run({"w": jnp.zeros(4)}, 3)
    # 3 x 4 = 12 updates, flushed in pairs -> 6 aggregation windows
    merges = [r for r in res.rounds if r.aggregated_updates > 0]
    assert len(merges) == 6
    assert all(r.aggregated_updates == 2 for r in merges)
    # six server steps of (1-eta)*w + eta*1 from w=0: 1 - 0.3^6
    assert float(params["w"][0]) == pytest.approx(1.0 - 0.3 ** 6, abs=1e-4)


def test_fedbuff_finalize_flushes_partial_buffer():
    """A trailing buffer of < K delivered updates still reaches the final
    model (Strategy.finalize at the end of the barrier-free run)."""
    # budget 1 x 3 = 3 deliveries with K=2: one flush + one buffered update
    d = _driver(["a", "b", "c"], "fedbuff", cohort=3, buffer_k=2)
    params, res = d.run({"w": jnp.zeros(4)}, 1)
    assert sum(r.aggregated_updates for r in res.rounds) == 3
    assert sum(len(r.successes) for r in res.rounds) == 3
    # the finalize flush moved the model beyond the single K=2 merge
    one_flush = 1.0 - (1.0 - 0.7)          # eta=0.7, one merge of w=1
    assert float(params["w"][0]) > one_flush


def test_async_honors_concurrency_cap():
    from repro.faas import EventKind
    d = _driver(["a", "b", "c", "d"], "fedasync", cohort=4,
                max_concurrency=1)
    _, res = d.run({"w": jnp.zeros(4)}, 2)
    starts = sorted(ev.time for ev in d.queue.trace
                    if ev.kind is EventKind.INVOKE_START)
    finishes = sorted(ev.time for ev in d.queue.trace
                      if ev.kind is EventKind.CLIENT_FINISH)
    # one slot: invocation i+1 never starts before finish i
    for i, start in enumerate(starts[1:]):
        assert start >= finishes[i]


# ---------------------------------------------------------------- failures
def test_async_crash_detection_backoff_and_eur():
    profiles = {"dead": ClientProfile(crash=True)}
    d = _driver(["a", "b", "dead"], "fedasync", cohort=3, profiles=profiles)
    params, res = d.run({"w": jnp.zeros(4)}, 6)
    crashed = [cid for r in res.rounds for cid in r.crashed]
    assert "dead" in crashed
    # exponential backoff: the dead client is probed, penalized, and
    # re-enters only after its (doubling) cooldown — far fewer probes
    # than merge windows
    assert 0 < len(crashed) <= 4
    # EUR dips below 1 in the windows that paid for a crash probe, but
    # the run-level ratio stays high because the rotation routes around it
    assert any(r.eur < 1.0 for r in res.rounds)
    assert res.mean_eur > 0.8
    # crash probes are billed as whole-window stragglers
    assert "dead" in d.cost.by_client
    history_dead = d.history.get("dead")
    assert history_dead.failures == len(crashed)


def test_async_slow_client_merges_stale_on_arrival():
    """A slow client past its ticket deadline keeps running: a replacement
    refills the slot, and the late update merges on arrival."""
    profiles = {"slow": ClientProfile(slow_factor=5.0)}   # 2 + 50 s > 30 s
    d = _driver(["a", "b", "slow"], "fedasync", cohort=3, profiles=profiles)
    params, res = d.run({"w": jnp.zeros(4)}, 5)
    late = [cid for r in res.rounds for cid in r.late]
    arrivals = [cid for r in res.rounds for cid in r.straggler_arrivals]
    assert "slow" in late
    assert "slow" in arrivals          # it did merge, staleness-damped
    delivered = [cid for r in res.rounds for cid in r.successes]
    assert "slow" in delivered


def test_async_termination_bills_abandoned_inflight():
    """The run stops listening at the update budget, but the provider
    still bills the invocations that were already launched and left in
    flight (unfired INVOKE_STARTs at exit correctly bill nothing)."""
    trace = TraceRecorder()
    # heterogeneous speeds desynchronize finishes, so the budget-reaching
    # delivery leaves slower clients' launched invocations pending
    profiles = {"b": ClientProfile(slow_factor=1.4),
                "c": ClientProfile(slow_factor=1.9)}
    d = _driver(["a", "b", "c"], "fedasync", cohort=3, profiles=profiles,
                round_timeout_s=60.0, trace=trace)
    d.run({"w": jnp.zeros(4)}, 2)
    abandoned = [r for r in trace.select("attempt")
                 if r["status"] == "abandoned"]
    assert abandoned                          # refilled slots at exit
    billed = [r for r in trace.select("billing")
              if r["kind"] == "abandoned"]
    assert len(billed) == len(abandoned)
    # and the books still round-trip exactly
    assert trace.billed_total() == pytest.approx(d.cost.total, abs=1e-9)


# ---------------------------------------------------------------- barrier API
def test_run_round_rejects_async_mode():
    d = _driver(["a"], "fedasync")
    with pytest.raises(RuntimeError, match="barrier"):
        d.run_round({"w": jnp.zeros(4)}, 0)


def test_controller_alias_still_importable():
    from repro.fl.controller import Controller
    assert Controller is TrainingDriver


# ---------------------------------------------------------------- trace
def _run_traced(strategy_name, seed=0):
    trace = TraceRecorder()
    profiles = {"slow": ClientProfile(slow_factor=5.0),
                "dead": ClientProfile(crash=True)}
    d = _driver(["a", "b", "c", "slow", "dead"], strategy_name,
                profiles=profiles, cohort=3, trace=trace, jitter=0.5,
                failure_rate=0.0005, seed=seed)
    d.run({"w": jnp.zeros(4)}, 4)
    return trace, d


@pytest.mark.parametrize("strategy", ["fedlesscan", "fedasync", "fedbuff"])
def test_trace_billing_roundtrip(strategy):
    """Every billed attempt is reconstructible from the trace records:
    summing the billing stream reproduces CostMeter.total exactly."""
    trace, d = _run_traced(strategy)
    assert d.cost.total > 0
    assert trace.billed_total() == pytest.approx(d.cost.total, abs=1e-9)
    billing = trace.select("billing")
    assert len(billing) == d.cost.invocations
    # attempt records carry the routing decision and arrival times
    attempts = trace.select("attempt")
    assert attempts and all(a["platform"] == "sim" for a in attempts)
    assert all(a["arrival_time"] >= a["start_time"] for a in attempts)
    # aggregation events recorded once per merge window
    assert len(trace.select("aggregation")) > 0


@pytest.mark.parametrize("strategy", ["fedasync", "fedbuff", "fedlesscan"])
def test_trace_is_deterministic(strategy):
    t1, _ = _run_traced(strategy)
    t2, _ = _run_traced(strategy)
    assert t1.dumps() == t2.dumps()


def test_trace_jsonl_roundtrip(tmp_path):
    from repro.faas import load_jsonl
    trace, d = _run_traced("fedasync")
    p = trace.to_jsonl(tmp_path / "trace.jsonl")
    records = load_jsonl(p)
    assert len(records) == len(trace.records)
    total = sum(r["cost"] for r in records if r["type"] == "billing")
    assert total == pytest.approx(d.cost.total, abs=1e-9)


# ---------------------------------------------------- acceptance (EUR)
def test_async_eur_matches_or_beats_semi_async_under_stragglers():
    """30% stragglers (half slow, half crash): the barrier-free modes
    waste no round slots on stragglers after backoff kicks in, so their
    windowed EUR is at least the semi-async per-round EUR."""
    ids = [f"c{i:02d}" for i in range(20)]
    rng = np.random.default_rng(0)
    chosen = rng.choice(ids, size=6, replace=False)
    profiles = {cid: (ClientProfile(slow_factor=6.0) if i < 3
                      else ClientProfile(crash=True))
                for i, cid in enumerate(chosen)}

    def eur_of(name):
        d = _driver(ids, name, profiles=profiles, cohort=6, seed=0)
        _, res = d.run({"w": jnp.zeros(4)}, 6)
        return res.mean_eur

    semi = eur_of("fedlesscan")
    assert eur_of("fedasync") >= semi
    assert eur_of("fedbuff") >= semi


# ---------------------------------------------------------------- routing
class _PlanStub:
    def __init__(self, failure, cold):
        self.failure = failure
        self.cold = cold


def _feed_attempts(trace, platform, n_fail, n_ok, cold=False):
    # telemetry windows are fed by the platform-side on_plan hook
    for i in range(n_fail + n_ok):
        trace.on_plan(platform,
                      _PlanStub("platform" if i < n_fail else None, cold),
                      attempt=0)


def test_telemetry_routing_prefers_healthy_platform():
    trace = TraceRecorder()
    _feed_attempts(trace, "flaky", n_fail=8, n_ok=2)
    _feed_attempts(trace, "healthy", n_fail=0, n_ok=10)
    policy = TelemetryRoutingPolicy(["flaky", "healthy"], trace,
                                    default="flaky")
    assert policy.route("new-client") == "healthy"
    # sticky afterwards
    assert policy.route("new-client") == "healthy"
    # the decision was recorded in the trace stream
    routes = trace.select("route")
    assert routes[-1]["platform"] == "healthy"


def test_telemetry_routing_reroutes_degraded_assignment():
    trace = TraceRecorder()
    policy = TelemetryRoutingPolicy(["a-plat", "b-plat"], trace,
                                    assignment={"c0": "a-plat"},
                                    reroute_threshold=0.5)
    # healthy: assignment is sticky
    _feed_attempts(trace, "a-plat", n_fail=0, n_ok=10)
    assert policy.route("c0") == "a-plat"
    # outage on a-plat: observed failure rate crosses the threshold
    _feed_attempts(trace, "a-plat", n_fail=40, n_ok=0)
    _feed_attempts(trace, "b-plat", n_fail=0, n_ok=10)
    assert policy.route("c0") == "b-plat"
    assert any(r["reason"] == "reroute" for r in trace.select("route"))


def test_telemetry_routing_ignores_thin_evidence():
    trace = TraceRecorder()
    _feed_attempts(trace, "b-plat", n_fail=2, n_ok=0)   # < min_samples
    policy = TelemetryRoutingPolicy(["a-plat", "b-plat"], trace,
                                    min_samples=5)
    # no platform has enough samples: deterministic name tie-break
    assert policy.route("c0") == "a-plat"


def test_cold_start_rate_breaks_failure_ties():
    trace = TraceRecorder()
    _feed_attempts(trace, "cold-plat", n_fail=0, n_ok=10, cold=True)
    _feed_attempts(trace, "warm-plat", n_fail=0, n_ok=10, cold=False)
    policy = TelemetryRoutingPolicy(["cold-plat", "warm-plat"], trace)
    assert policy.route("c0") == "warm-plat"
