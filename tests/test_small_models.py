"""The paper's client models (§VI-A2) learn their synthetic tasks."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (make_char_lm, make_image_classification,
                        make_speech_commands)
from repro.data.synthetic import ArrayDataset
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import (SMALL_MODELS, make_char_lstm, make_cnn,
                                make_speech_cnn)


def _split(ds, n_test):
    return (ArrayDataset(ds.x[:-n_test], ds.y[:-n_test]),
            ArrayDataset(ds.x[-n_test:], ds.y[-n_test:]))


def test_registry_builds():
    for name, fn in SMALL_MODELS.items():
        model = fn()
        params = model.init(jax.random.PRNGKey(0))
        assert params, name


def test_cnn_learns_images():
    train, test = _split(make_image_classification(1200, 14, 5, seed=0), 200)
    task = ClassificationTask(make_cnn(14, 1, 5, 64),
                              TaskConfig(epochs=3, batch_size=32))
    p, _ = task.local_train(task.init_params(0), train, seed=0)
    acc, _ = task.evaluate(p, test)
    assert acc > 0.8


def test_speech_cnn_learns_keywords():
    train, test = _split(make_speech_commands(1000, 16, 16, 6, seed=0), 200)
    task = ClassificationTask(make_speech_cnn(16, 16, 6),
                              TaskConfig(epochs=4, batch_size=32))
    p, _ = task.local_train(task.init_params(0), train, seed=0)
    acc, _ = task.evaluate(p, test)
    assert acc > 0.6


def test_lstm_beats_uniform_char_prediction():
    vocab = 40
    train, test = _split(make_char_lm(1500, seq_len=20, vocab=vocab,
                                      seed=0), 300)
    task = ClassificationTask(
        make_char_lstm(vocab=vocab, embed=8, hidden=64),
        TaskConfig(epochs=3, batch_size=32, learning_rate=1e-2))
    p, _ = task.local_train(task.init_params(0), train, seed=0)
    _, loss = task.evaluate(p, test)
    assert loss < np.log(vocab) * 0.8       # clearly under uniform entropy


def test_dropout_changes_speech_output():
    model = make_speech_cnn(16, 16, 6)
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.ones((2, 16, 16, 1))
    clean = model.apply(params, x)
    noisy = model.apply(params, x, dropout_rng=jax.random.PRNGKey(1))
    assert not np.allclose(clean, noisy)
