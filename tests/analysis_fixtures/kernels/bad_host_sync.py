"""JAX001 fixture: host synchronization inside a jitted function."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def step(x):
    y = jnp.sum(x)
    bad = float(y)                      # line 10: JAX001 (float)
    arr = np.asarray(x)                 # line 11: JAX001 (np.asarray)
    val = y.item()                      # line 12: JAX001 (.item)
    return bad, arr, val


def host_side(x):
    return float(x)                     # allowed: not jitted
