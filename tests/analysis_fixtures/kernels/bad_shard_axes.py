"""JAX004 fixture: shard_map / psum axis names that no sharding/rules.py
declares (the corpus has no such module, so the vocabulary is empty)."""
import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def sharded_sum(mesh, x):
    f = shard_map(lambda a: a.sum(), mesh=mesh,
                  in_specs=(P("cohort"),),
                  out_specs=P())
    return f(x)


def cross_device_total(x):
    return jax.lax.psum(x, "workers")
