"""Oracles for the CON001 fixture kernels."""


def good_kernel_ref(x):
    return x
