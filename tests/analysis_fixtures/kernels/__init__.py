"""CON001 fixture: one export with an oracle, one orphan."""

__all__ = [
    "good_kernel",                      # has good_kernel_ref in ref.py
    "orphan_kernel",                    # line 5: CON001 (no oracle)
    "ref",                              # excluded: the oracle module
]
