"""JAX003 fixture: jax.jit constructed inside a per-round call path."""
import jax

_STEP = jax.jit(lambda b: b)            # allowed: module scope


def run_round(train_fn, batch):
    step = jax.jit(train_fn)            # line 8: JAX003
    return step(batch)
