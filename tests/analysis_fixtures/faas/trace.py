"""CON002 fixture: record literals diverging from RECORD_SCHEMAS."""

REC_EVENT = "event"

RECORD_SCHEMAS = {
    REC_EVENT: {"required": ["time", "kind"], "optional": ["detail"],
                "open": False},
}


class Recorder:
    def _append(self, rec):
        pass

    def record_event(self, t, extra):
        rec = {"type": REC_EVENT, "time": t}    # line 16: CON002 missing
        rec["surprise"] = extra                 # line 17: CON002 undeclared
        rec["detail"] = "ok"                    # allowed: declared optional
        self._append(rec)

    def record_unknown(self, t):
        self._append({"type": "mystery", "time": t})  # line 22: CON002
