"""DET002 fixture: wall-clock / entropy reads in a simulation path."""
import time
import uuid
from datetime import datetime


def stamp():
    t = time.time()                     # line 8: DET002
    u = uuid.uuid4()                    # line 9: DET002
    d = datetime.now()                  # line 10: DET002
    return t, u, d
