"""Pragma fixture: every violation suppressed on its own line."""
import random


def seed(cid):
    return hash(cid)  # repro-lint: disable=DET003


def jitter():
    return random.random()  # repro-lint: disable=unseeded-random
