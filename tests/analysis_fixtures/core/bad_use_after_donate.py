"""JAX002 fixture: buffers read after being passed at donated slots."""
import jax

from repro.kernels import fed_agg

_step = jax.jit(lambda s: s * 2.0, donate_argnums=(0,))


def run(state):
    new = _step(state)
    return state.sum() + new            # line 11: JAX002 (jit twin)


def merge(updates, coeffs):
    out = fed_agg(updates, coeffs, donate=True)
    return out + updates.mean()         # line 16: JAX002 (wrapper)


def safe(state):
    state = _step(state)                # reassignment kills the hazard
    return state.sum()
