"""DET004 fixture: hash-seed-dependent set iteration order."""


def collect(ids, skip):
    out = []
    for cid in set(ids) - set(skip):    # line 6: DET004 (for over set)
        out.append(cid)
    ordered = list({3, 1, 2})           # line 8: DET004 (list(set))
    doubled = [c * 2 for c in set(ids)]  # line 9: DET004 (comprehension)
    members = {c for c in set(ids)}     # allowed: set -> set is order-free
    safe = sorted(set(ids))             # allowed: sorted pins the order
    return out, ordered, doubled, members, safe
