"""GATE001 fixture: REPRO_* env access outside the gates registry."""
import os

FLAG = os.environ.get("REPRO_FIXTURE_FLAG", "0")    # line 4: GATE001
MODE = os.environ["REPRO_FIXTURE_MODE"]             # line 5: GATE001
OTHER = os.environ.get("UNRELATED_VAR", "")         # allowed: not REPRO_*
