"""DET001 fixture: draws from hidden global RNG streams."""
import random

import numpy as np


def pick(ids):
    winner = random.choice(ids)         # line 8: DET001 (stdlib global)
    noise = np.random.rand(4)           # line 9: DET001 (numpy legacy)
    rng = np.random.default_rng(0)      # allowed: explicit Generator
    return winner, noise, rng.random()
