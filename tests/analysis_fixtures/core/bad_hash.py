"""DET003 fixture: builtin hash() used for seed derivation."""


def client_seed(client_id):
    return hash(client_id) % 2**32      # line 5: DET003
