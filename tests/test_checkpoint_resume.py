"""Checkpoint/resume of the training driver (fl/checkpointing.py).

The core guarantee (schema v2): a checkpoint is a **full event-queue
snapshot** — pending events with their seq counter, in-flight engine
state (plans, retry counters, cached updates), warm pools, routing
telemetry, cost tallies, every RNG stream — so a resumed run replays
the remaining timeline *byte-identically* to an uninterrupted same-seed
run, in-flight stragglers included, in all three training modes.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClientHistoryDB, ClientUpdate, StrategyConfig, make_strategy
from repro.faas import CostMeter, FaaSConfig, MockInvoker, SimulatedFaaSPlatform
from repro.faas.platform import ClientProfile
from repro.faas.trace import TraceRecorder
from repro.fl.checkpointing import RoundCheckpointer
from repro.fl.controller import TrainingDriver

IDS = [f"c{i}" for i in range(8)]


def _work_fn(cid, params, rnd):
    w = params["w"] + 0.1 * (rnd + 1)
    return ClientUpdate(cid, {"w": w}, 10, rnd), 10.0


class _StubPool:
    def __init__(self, client_ids):
        self._ids = list(client_ids)
        self.clients = {}

    @property
    def client_ids(self):
        return self._ids


def _driver(strategy_name="fedlesscan", seed=0, profiles=None, trace=None,
            round_timeout_s=60.0, clients_per_round=3):
    history = ClientHistoryDB()
    history.ensure(IDS)
    strategy = make_strategy(
        strategy_name,
        StrategyConfig(clients_per_round=clients_per_round, max_rounds=10),
        history, seed=seed)
    # jitter + stochastic cold starts exercise the platform RNG stream
    platform = SimulatedFaaSPlatform(
        FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.3,
                   perf_variation=(0.9, 1.1), failure_rate=0.0,
                   network_jitter_s=0.4),
        seed=seed, recorder=trace)
    invoker = MockInvoker(platform, _work_fn, profiles or {})
    return TrainingDriver(strategy, invoker, _StubPool(IDS), history,
                          CostMeter(trace=trace),
                          round_timeout_s=round_timeout_s,
                          eval_every=0, seed=seed, trace=trace)


def _round_key(stats):
    return (stats.round_number, stats.selected, stats.successes, stats.late,
            stats.crashed, stats.duration_s, stats.eur, stats.cost)


def _lines(recorder):
    return [json.dumps(r, sort_keys=True) for r in recorder.records]


# slow enough to miss a 60 s round (10 s work × 8 + cold + jitter ≈ 83 s)
# but to finish mid-flight one or two rounds later
SPAN_PROFILES = {cid: ClientProfile(slow_factor=8.0)
                 for cid in ("c0", "c1", "c2")}


def test_resumed_run_matches_uninterrupted(tmp_path):
    # uninterrupted reference: 6 rounds straight through
    ref = _driver()
    ref_params, ref_res = ref.run({"w": jnp.zeros(4)}, 6)

    # interrupted run: 3 rounds, checkpoint, fresh driver, resume
    first = _driver()
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    mid_params, _ = first.run({"w": jnp.zeros(4)}, 3,
                              checkpointer=ckpt, checkpoint_every=3)
    assert ckpt.rounds() == [3]

    resumed = _driver()                      # no memory of the first run
    params0, next_round = ckpt.restore(resumed, {"w": jnp.zeros(4)})
    assert next_round == 3
    assert jnp.allclose(params0["w"], mid_params["w"])
    tail_params, tail_res = resumed.run(params0, 6, start_round=next_round)

    # the tail replays rounds 3..5 of the reference exactly
    assert [_round_key(r) for r in tail_res.rounds] == \
        [_round_key(r) for r in ref_res.rounds[3:]]
    assert np.array_equal(np.asarray(tail_params["w"]),
                          np.asarray(ref_params["w"]))
    # cost books line up: reference total == checkpointed + tail deltas
    assert resumed.cost.total == pytest.approx(ref.cost.total, abs=1e-12)
    # behavioural history converged to the same records
    assert resumed.history.to_payload() == ref.history.to_payload()


def _interrupt_resume_traces(tmp_path, strategy_name, profiles):
    """Run 6 rounds uninterrupted vs 2-rounds + resume; return both sides'
    artifacts for byte-level comparison."""
    ref_trace = TraceRecorder()
    ref = _driver(strategy_name, profiles=dict(profiles), trace=ref_trace)
    ref_params, ref_res = ref.run({"w": jnp.zeros(4)}, 6)

    t1 = TraceRecorder()
    first = _driver(strategy_name, profiles=dict(profiles), trace=t1)
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    _, _ = first.run({"w": jnp.zeros(4)}, 2,
                     checkpointer=ckpt, checkpoint_every=2)

    t2 = TraceRecorder()
    resumed = _driver(strategy_name, profiles=dict(profiles), trace=t2)
    params0, next_round = ckpt.restore(resumed, {"w": jnp.zeros(4)})
    assert next_round == 2
    tail_params, _ = resumed.run(params0, 6, start_round=next_round)
    state = json.loads((tmp_path / "ckpt" / "round_000002.json").read_text())
    return ref, ref_params, ref_trace, resumed, tail_params, t1, t2, state


def test_semi_async_resume_with_inflight_straggler_is_byte_identical(tmp_path):
    """The headline fix: an invocation *spanning* the checkpoint boundary
    survives the restore — its CLIENT_FINISH replays at its true virtual
    time and the JSONL traces concatenate byte-identically."""
    (ref, ref_params, ref_trace, resumed, tail_params,
     t1, t2, state) = _interrupt_resume_traces(tmp_path, "fedlesscan",
                                               SPAN_PROFILES)
    # the snapshot really did capture an in-flight straggler
    pending_kinds = {ev["kind"] for ev in state["queue"]["events"]}
    assert "client_finish" in pending_kinds
    assert state["engine"]["rounds"], "no in-flight engine state captured"

    assert np.array_equal(np.asarray(tail_params["w"]),
                          np.asarray(ref_params["w"]))
    assert _lines(t1) + _lines(t2) == _lines(ref_trace)
    assert resumed.history.to_payload() == ref.history.to_payload()
    # cost attribution: int round keys and identical per-round totals
    assert all(isinstance(k, int) for k in resumed.cost.rounds)
    assert resumed.cost.rounds == ref.cost.rounds
    assert resumed.cost.by_client == ref.cost.by_client


def test_sync_resume_with_inflight_straggler_is_byte_identical(tmp_path):
    """Sync mode discards the late update, but the event still arrives,
    is billed, and must replay identically after a resume."""
    (ref, ref_params, ref_trace, resumed, tail_params,
     t1, t2, state) = _interrupt_resume_traces(tmp_path, "fedavg",
                                               SPAN_PROFILES)
    assert np.array_equal(np.asarray(tail_params["w"]),
                          np.asarray(ref_params["w"]))
    assert _lines(t1) + _lines(t2) == _lines(ref_trace)
    assert resumed.cost.rounds == ref.cost.rounds


@pytest.mark.parametrize("strategy_name", ["fedasync", "fedbuff"])
def test_async_resume_is_byte_identical(tmp_path, strategy_name):
    """Async mode checkpoints at event horizons (checkpoint_every virtual
    seconds) and a restore continues the barrier-free timeline exactly —
    including FedBuff's partially-filled buffer."""
    profiles = {"c0": ClientProfile(slow_factor=8.0)}
    ck = RoundCheckpointer(tmp_path / "ck", keep=50)

    ref_trace = TraceRecorder()
    ref = _driver(strategy_name, profiles=dict(profiles), trace=ref_trace)
    ref_params, ref_res = ref.run({"w": jnp.zeros(4)}, 4,
                                  checkpointer=ck, checkpoint_every=15.0)
    tags = ck.rounds()
    assert len(tags) >= 2, "expected several event-horizon snapshots"

    # pick a mid-run snapshot and continue from it with a fresh driver
    tag = tags[len(tags) // 2]
    state = json.loads((tmp_path / "ck" / f"round_{tag:06d}.json")
                       .read_text())
    offset = state["trace_offset"]
    assert state["async"]["tickets"], "snapshot should hold open tickets"

    t2 = TraceRecorder()
    resumed = _driver(strategy_name, profiles=dict(profiles), trace=t2)
    params0, next_round = ck.restore(resumed, {"w": jnp.zeros(4)},
                                     round_number=tag)
    assert next_round == 0
    tail_params, tail_res = resumed.run(params0, 4)

    assert np.array_equal(np.asarray(tail_params["w"]),
                          np.asarray(ref_params["w"]))
    # the resumed trace is exactly the reference trace's tail
    assert _lines(t2) == _lines(ref_trace)[offset:]
    # the resumed result carries the pre-checkpoint windows too
    assert [_round_key(r) for r in tail_res.rounds] == \
        [_round_key(r) for r in ref_res.rounds]
    assert resumed.cost.total == pytest.approx(ref.cost.total, abs=1e-12)
    assert all(isinstance(k, int) for k in resumed.cost.rounds)
    assert resumed.cost.rounds == ref.cost.rounds


def test_async_checkpointer_is_side_effect_free(tmp_path):
    """A run that writes snapshots must be indistinguishable from one
    that doesn't (saving reads state, never mutates it)."""
    profiles = {"c0": ClientProfile(slow_factor=8.0)}
    plain = _driver("fedasync", profiles=dict(profiles))
    p1, r1 = plain.run({"w": jnp.zeros(4)}, 3)
    ck = RoundCheckpointer(tmp_path / "ck", keep=50)
    saving = _driver("fedasync", profiles=dict(profiles))
    p2, r2 = saving.run({"w": jnp.zeros(4)}, 3,
                        checkpointer=ck, checkpoint_every=10.0)
    assert np.array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))
    assert [_round_key(r) for r in r1.rounds] == \
        [_round_key(r) for r in r2.rounds]


def test_checkpointer_retention_and_latest(tmp_path):
    d = _driver()
    ckpt = RoundCheckpointer(tmp_path / "ckpt", keep=2)
    params = {"w": jnp.zeros(4)}
    for rnd in range(4):
        params, _ = d.run_round(params, rnd)
        ckpt.save(d, params, rnd + 1)
    assert ckpt.rounds() == [3, 4]           # retention pruned 1 and 2
    assert ckpt.latest_round() == 4


def test_retention_keep_best_by_history_metric(tmp_path):
    """keep_best retains the top-K tags by a RoundStats metric on top of
    the keep_last_n trailing window (long-async-study GC)."""
    d = _driver()
    ckpt = RoundCheckpointer(tmp_path / "ckpt", keep_last_n=1, keep_best=1,
                             best_metric="accuracy")
    params = {"w": jnp.zeros(4)}
    for rnd, acc in enumerate([0.2, 0.9, 0.5, 0.1]):
        params, _ = d.run_round(params, rnd)
        d._recent_stats[-1].accuracy = acc     # the history metric
        ckpt.save(d, params, rnd + 1)
    # tag 2 scored 0.9 (best), tag 4 is the trailing window
    assert ckpt.rounds() == [2, 4]
    state = json.loads((tmp_path / "ckpt" / "round_000002.json").read_text())
    assert state["score"] == pytest.approx(0.9)
    # no torn leftovers: every surviving tag has both files
    names = sorted(p.name for p in (tmp_path / "ckpt").iterdir())
    assert names == ["round_000002.json", "round_000002.npz",
                     "round_000004.json", "round_000004.npz"]
    # the best tag is still restorable
    other = _driver()
    _, next_round = ckpt.restore(other, {"w": jnp.zeros(4)}, round_number=2)
    assert next_round == 2


def test_retention_keep_best_scores_preexisting_tags_from_disk(tmp_path):
    """A fresh checkpointer GC-ing a directory written by an earlier
    process reads the persisted scores instead of discarding history."""
    d = _driver()
    writer = RoundCheckpointer(tmp_path / "ckpt", keep=10, keep_best=1,
                               best_metric="accuracy")
    params = {"w": jnp.zeros(4)}
    for rnd, acc in enumerate([0.3, 0.8, 0.4]):
        params, _ = d.run_round(params, rnd)
        d._recent_stats[-1].accuracy = acc
        writer.save(d, params, rnd + 1)
    # new process, tighter policy: trailing 1 + best 1 (tag 2, acc 0.8)
    later = RoundCheckpointer(tmp_path / "ckpt", keep_last_n=1, keep_best=1,
                              best_metric="accuracy")
    params, _ = d.run_round(params, 3)
    d._recent_stats[-1].accuracy = 0.1
    later.save(d, params, 4)
    assert later.rounds() == [2, 4]


def test_retention_best_only(tmp_path):
    """keep_last_n=0 with keep_best>0 means best-only retention (an
    empty trailing window), not the legacy keep-everything quirk."""
    d = _driver()
    ckpt = RoundCheckpointer(tmp_path / "ckpt", keep_last_n=0, keep_best=2,
                             best_metric="accuracy")
    params = {"w": jnp.zeros(4)}
    for rnd, acc in enumerate([0.2, 0.9, 0.5, 0.7]):
        params, _ = d.run_round(params, rnd)
        d._recent_stats[-1].accuracy = acc
        ckpt.save(d, params, rnd + 1)
    assert ckpt.rounds() == [2, 4]           # the two best scores


def test_gc_sweeps_orphan_json_from_crashed_gc(tmp_path):
    """A crash between _gc's npz and json unlinks leaves a lone json;
    the next GC removes it instead of letting litter accumulate."""
    d = _driver()
    ckpt = RoundCheckpointer(tmp_path / "ckpt", keep=2)
    params = {"w": jnp.zeros(4)}
    for rnd in range(2):
        params, _ = d.run_round(params, rnd)
        ckpt.save(d, params, rnd + 1)
    # simulate the crashed-GC state: tag 1's npz gone, json left behind
    (tmp_path / "ckpt" / "round_000001.npz").unlink()
    params, _ = d.run_round(params, 2)
    ckpt.save(d, params, 3)
    names = sorted(p.name for p in (tmp_path / "ckpt").iterdir())
    assert names == ["round_000002.json", "round_000002.npz",
                     "round_000003.json", "round_000003.npz"]


def test_retention_callable_metric_and_unscored_tags(tmp_path):
    """A callable best_metric scores saves directly; tags without a score
    are never retained as 'best' (only by the trailing window)."""
    d = _driver()
    scores = {1: 5.0, 2: None, 3: 7.0, 4: None}
    ckpt = RoundCheckpointer(
        tmp_path / "ckpt", keep_last_n=1, keep_best=1,
        best_metric=lambda driver, params, tag: scores[tag])
    params = {"w": jnp.zeros(4)}
    for rnd in range(4):
        params, _ = d.run_round(params, rnd)
        ckpt.save(d, params, rnd + 1)
    assert ckpt.rounds() == [3, 4]           # 3 best-scored, 4 trailing


def test_checkpoint_writes_are_atomic(tmp_path):
    d = _driver()
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    params, _ = d.run_round({"w": jnp.zeros(4)}, 0)
    ckpt.save(d, params, 1)
    # no temp litter: both files landed via os.replace
    assert sorted(p.name for p in (tmp_path / "ckpt").iterdir()) == \
        ["round_000001.json", "round_000001.npz"]


def test_restore_rejects_torn_pair(tmp_path):
    """A .json/.npz pair whose descriptors disagree (crash between the
    two replaces, or a foreign overwrite) must fail loudly, not resume."""
    d = _driver()
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    params, _ = d.run_round({"w": jnp.zeros(4)}, 0)
    ckpt.save(d, params, 1)
    spath = tmp_path / "ckpt" / "round_000001.json"
    state = json.loads(spath.read_text())
    state["pair"]["charges"] += 1            # simulate a torn pair
    spath.write_text(json.dumps(state))
    other = _driver()
    with pytest.raises(ValueError, match="pair mismatch"):
        ckpt.restore(other, {"w": jnp.zeros(4)})


def test_schema_v1_checkpoint_migrates(tmp_path):
    """PR 3 checkpoints (no schema field, params-only npz, strategy_rng
    key) still restore — with their documented round-boundary semantics."""
    from repro.checkpoint.checkpoint import save_pytree

    d = _driver()
    params, _ = d.run_round({"w": jnp.zeros(4)}, 0)
    state = {
        "mode": d.mode, "strategy": d.strategy.name,
        "scheduler_name": d.scheduler.name,
        "clock": d.queue.clock.now,
        "history": d.history.to_payload(),
        "driver_rng": d.rng.bit_generator.state,
        "strategy_rng": d.strategy.rng.bit_generator.state,
        "scheduler": d.scheduler.state_dict(),
        "cost": {"total": d.cost.total, "invocations": d.cost.invocations,
                 "by_client": dict(d.cost.by_client),
                 "rounds": {str(k): v for k, v in d.cost.rounds.items()}},
        "recent_stats": [], "next_round": 1,
    }
    ckdir = tmp_path / "ckpt"
    ckdir.mkdir()
    save_pytree(params, str(ckdir / "round_000001.npz"))
    (ckdir / "round_000001.json").write_text(json.dumps(state))

    resumed = _driver()
    params0, next_round = RoundCheckpointer(ckdir).restore(
        resumed, {"w": jnp.zeros(4)})
    assert next_round == 1
    assert np.array_equal(np.asarray(params0["w"]), np.asarray(params["w"]))
    assert len(resumed.queue) == 0           # v1: no timeline snapshot
    assert resumed.cost.total == pytest.approx(d.cost.total)
    assert all(isinstance(k, int) for k in resumed.cost.rounds)


def test_restore_rejects_strategy_mismatch(tmp_path):
    d = _driver("fedlesscan")
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    params, _ = d.run_round({"w": jnp.zeros(4)}, 0)
    ckpt.save(d, params, 1)
    other = _driver("fedavg")
    with pytest.raises(ValueError, match="strategy"):
        ckpt.restore(other, {"w": jnp.zeros(4)})


def test_restore_rejects_scheduler_mismatch(tmp_path):
    """A checkpoint written under one cohort policy must not silently
    load into a driver running another one."""
    from repro.fl.scheduler import ApodotikoScheduler
    d = _driver("fedlesscan")
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    params, _ = d.run_round({"w": jnp.zeros(4)}, 0)
    ckpt.save(d, params, 1)
    other = _driver("fedlesscan")
    other.scheduler = ApodotikoScheduler(3, seed=0)
    with pytest.raises(ValueError, match="scheduler"):
        ckpt.restore(other, {"w": jnp.zeros(4)})


def test_free_tier_allowance_survives_resume(tmp_path):
    """Free-tier billing: the remaining monthly grant is cost state — a
    resumed run must not re-grant the allowance the reference run had
    already consumed."""
    from repro.faas.cost import PriceBook

    def driver():
        history = ClientHistoryDB()
        history.ensure(IDS)
        strategy = make_strategy(
            "fedlesscan", StrategyConfig(clients_per_round=3, max_rounds=10),
            history, seed=0)
        platform = SimulatedFaaSPlatform(
            FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.0,
                       perf_variation=(1.0, 1.0), failure_rate=0.0,
                       network_jitter_s=0.0), seed=0)
        meter = CostMeter(prices=PriceBook(free_tier=True))
        return TrainingDriver(strategy, MockInvoker(platform, _work_fn, {}),
                              _StubPool(IDS), history, meter,
                              round_timeout_s=60.0, eval_every=0, seed=0)

    first = driver()
    params, _ = first.run({"w": jnp.zeros(4)}, 2)
    consumed = first.cost.allowance.vcpu_seconds
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    ckpt.save(first, params, 2)

    resumed = driver()
    ckpt.restore(resumed, {"w": jnp.zeros(4)})
    assert resumed.cost.allowance.vcpu_seconds == consumed
    assert resumed.cost.allowance.vcpu_seconds < 180_000.0


def test_experiment_resume_surface(tmp_path):
    """End-to-end: ExperimentConfig.checkpoint_dir writes round-tagged
    checkpoints and resume_from replays the remaining rounds exactly."""
    from repro.data import label_sorted_shards, make_image_classification
    from repro.data.synthetic import ArrayDataset
    from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                     run_experiment)
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import make_cnn

    full = make_image_classification(400, image_size=14, n_classes=3, seed=0)
    train = ArrayDataset(full.x[:300], full.y[:300])
    test = ArrayDataset(full.x[300:], full.y[300:])
    parts = label_sorted_shards(train, 8, 2, seed=0)
    test_parts = label_sorted_shards(test, 8, 2, seed=0)
    task = ClassificationTask(
        make_cnn(14, 1, 3, 16),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))

    def cfg(**kw):
        return ExperimentConfig(
            strategy="fedlesscan", n_rounds=4, clients_per_round=4,
            eval_every=0, seed=0,
            scenario=ScenarioConfig(round_timeout_s=60.0, seed=0), **kw)

    ref = run_experiment(task, parts, test_parts, cfg())
    ckdir = str(tmp_path / "ck")
    run_experiment(task, parts, test_parts,
                   cfg(checkpoint_dir=ckdir, checkpoint_every=3))
    tail = run_experiment(task, parts, test_parts, cfg(resume_from=ckdir))
    assert [r.round_number for r in tail.rounds] == [3]
    for got, want in zip(tail.rounds, ref.rounds[3:]):
        assert got.selected == want.selected
        assert got.successes == want.successes
        assert got.duration_s == want.duration_s
    assert tail.final_accuracy == ref.final_accuracy
