"""Checkpoint/resume of the training driver (fl/checkpointing.py).

The core guarantee: a run resumed from a round-tagged checkpoint
replays the remaining rounds *exactly* as the uninterrupted run —
same cohorts, same virtual timings, same params — because the
checkpoint captures every mutable stream (history, driver/strategy/
platform RNGs, scheduler state, cost tallies, virtual clock).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClientHistoryDB, ClientUpdate, StrategyConfig, make_strategy
from repro.faas import CostMeter, FaaSConfig, MockInvoker, SimulatedFaaSPlatform
from repro.fl.checkpointing import RoundCheckpointer
from repro.fl.controller import TrainingDriver

IDS = [f"c{i}" for i in range(8)]


def _work_fn(cid, params, rnd):
    w = params["w"] + 0.1 * (rnd + 1)
    return ClientUpdate(cid, {"w": w}, 10, rnd), 10.0


class _StubPool:
    def __init__(self, client_ids):
        self._ids = list(client_ids)
        self.clients = {}

    @property
    def client_ids(self):
        return self._ids


def _driver(strategy_name="fedlesscan", seed=0):
    history = ClientHistoryDB()
    history.ensure(IDS)
    strategy = make_strategy(
        strategy_name, StrategyConfig(clients_per_round=3, max_rounds=10),
        history, seed=seed)
    # jitter + stochastic cold starts exercise the platform RNG stream
    platform = SimulatedFaaSPlatform(
        FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.3,
                   perf_variation=(0.9, 1.1), failure_rate=0.0,
                   network_jitter_s=0.4),
        seed=seed)
    invoker = MockInvoker(platform, _work_fn, {})
    return TrainingDriver(strategy, invoker, _StubPool(IDS), history,
                          CostMeter(), round_timeout_s=60.0, eval_every=0,
                          seed=seed)


def _round_key(stats):
    return (stats.round_number, stats.selected, stats.successes, stats.late,
            stats.crashed, stats.duration_s, stats.eur, stats.cost)


def test_resumed_run_matches_uninterrupted(tmp_path):
    # uninterrupted reference: 6 rounds straight through
    ref = _driver()
    ref_params, ref_res = ref.run({"w": jnp.zeros(4)}, 6)

    # interrupted run: 3 rounds, checkpoint, fresh driver, resume
    first = _driver()
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    mid_params, _ = first.run({"w": jnp.zeros(4)}, 3,
                              checkpointer=ckpt, checkpoint_every=3)
    assert ckpt.rounds() == [3]

    resumed = _driver()                      # no memory of the first run
    params0, next_round = ckpt.restore(resumed, {"w": jnp.zeros(4)})
    assert next_round == 3
    assert jnp.allclose(params0["w"], mid_params["w"])
    tail_params, tail_res = resumed.run(params0, 6, start_round=next_round)

    # the tail replays rounds 3..5 of the reference exactly
    assert [_round_key(r) for r in tail_res.rounds] == \
        [_round_key(r) for r in ref_res.rounds[3:]]
    assert np.array_equal(np.asarray(tail_params["w"]),
                          np.asarray(ref_params["w"]))
    # cost books line up: reference total == checkpointed + tail deltas
    assert resumed.cost.total == pytest.approx(ref.cost.total, abs=1e-12)
    # behavioural history converged to the same records
    assert resumed.history.to_payload() == ref.history.to_payload()


def test_checkpointer_retention_and_latest(tmp_path):
    d = _driver()
    ckpt = RoundCheckpointer(tmp_path / "ckpt", keep=2)
    params = {"w": jnp.zeros(4)}
    for rnd in range(4):
        params, _ = d.run_round(params, rnd)
        ckpt.save(d, params, rnd + 1)
    assert ckpt.rounds() == [3, 4]           # retention pruned 1 and 2
    assert ckpt.latest_round() == 4


def test_restore_rejects_strategy_mismatch(tmp_path):
    d = _driver("fedlesscan")
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    params, _ = d.run_round({"w": jnp.zeros(4)}, 0)
    ckpt.save(d, params, 1)
    other = _driver("fedavg")
    with pytest.raises(ValueError, match="strategy"):
        ckpt.restore(other, {"w": jnp.zeros(4)})


def test_restore_rejects_scheduler_mismatch(tmp_path):
    """A checkpoint written under one cohort policy must not silently
    load into a driver running another one."""
    from repro.fl.scheduler import ApodotikoScheduler
    d = _driver("fedlesscan")
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    params, _ = d.run_round({"w": jnp.zeros(4)}, 0)
    ckpt.save(d, params, 1)
    other = _driver("fedlesscan")
    other.scheduler = ApodotikoScheduler(3, seed=0)
    with pytest.raises(ValueError, match="scheduler"):
        ckpt.restore(other, {"w": jnp.zeros(4)})


def test_free_tier_allowance_survives_resume(tmp_path):
    """Free-tier billing: the remaining monthly grant is cost state — a
    resumed run must not re-grant the allowance the reference run had
    already consumed."""
    from repro.faas.cost import PriceBook

    def driver():
        history = ClientHistoryDB()
        history.ensure(IDS)
        strategy = make_strategy(
            "fedlesscan", StrategyConfig(clients_per_round=3, max_rounds=10),
            history, seed=0)
        platform = SimulatedFaaSPlatform(
            FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.0,
                       perf_variation=(1.0, 1.0), failure_rate=0.0,
                       network_jitter_s=0.0), seed=0)
        meter = CostMeter(prices=PriceBook(free_tier=True))
        return TrainingDriver(strategy, MockInvoker(platform, _work_fn, {}),
                              _StubPool(IDS), history, meter,
                              round_timeout_s=60.0, eval_every=0, seed=0)

    first = driver()
    params, _ = first.run({"w": jnp.zeros(4)}, 2)
    consumed = first.cost.allowance.vcpu_seconds
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    ckpt.save(first, params, 2)

    resumed = driver()
    ckpt.restore(resumed, {"w": jnp.zeros(4)})
    assert resumed.cost.allowance.vcpu_seconds == consumed
    assert resumed.cost.allowance.vcpu_seconds < 180_000.0


def test_async_driver_refuses_checkpoint():
    d = _driver("fedasync")
    with pytest.raises(NotImplementedError, match="barrier"):
        d.checkpoint_state()
    with pytest.raises(ValueError, match="barrier"):
        d.run({"w": jnp.zeros(4)}, 1, start_round=1)


def test_experiment_resume_surface(tmp_path):
    """End-to-end: ExperimentConfig.checkpoint_dir writes round-tagged
    checkpoints and resume_from replays the remaining rounds exactly."""
    from repro.data import label_sorted_shards, make_image_classification
    from repro.data.synthetic import ArrayDataset
    from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                     run_experiment)
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import make_cnn

    full = make_image_classification(400, image_size=14, n_classes=3, seed=0)
    train = ArrayDataset(full.x[:300], full.y[:300])
    test = ArrayDataset(full.x[300:], full.y[300:])
    parts = label_sorted_shards(train, 8, 2, seed=0)
    test_parts = label_sorted_shards(test, 8, 2, seed=0)
    task = ClassificationTask(
        make_cnn(14, 1, 3, 16),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))

    def cfg(**kw):
        return ExperimentConfig(
            strategy="fedlesscan", n_rounds=4, clients_per_round=4,
            eval_every=0, seed=0,
            scenario=ScenarioConfig(round_timeout_s=60.0, seed=0), **kw)

    ref = run_experiment(task, parts, test_parts, cfg())
    ckdir = str(tmp_path / "ck")
    run_experiment(task, parts, test_parts,
                   cfg(checkpoint_dir=ckdir, checkpoint_every=3))
    tail = run_experiment(task, parts, test_parts, cfg(resume_from=ckdir))
    assert [r.round_number for r in tail.rounds] == [3]
    for got, want in zip(tail.rounds, ref.rounds[3:]):
        assert got.selected == want.selected
        assert got.successes == want.successes
        assert got.duration_s == want.duration_s
    assert tail.final_accuracy == ref.final_accuracy
