"""Device-resident round pipeline (core/device_batch.py, PR 8).

The contract under test: with ``REPRO_DEVICE_PIPELINE`` enabled (the
default) the vectorized executor hands downstream consumers a zero-copy
``DeviceUpdateBatch`` view of its stacked (K, P) update matrix — and
every observable output (golden traces, round stats, final params) is
**byte-identical** to the legacy per-client materialize path, across all
three training modes, with and without compression, through checkpoint/
resume with in-flight updates.  Plus the riding satellites: the
vectorized ``_batch_indices`` is draw-for-draw equal to the old loop,
losses sync host-side in one batched transfer, and the recompile counter
stays flat across rounds whose cohorts share a power-of-two bucket.
"""
import hashlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from fleet_parity_common import GOLDEN_DIR, run_scenario

from repro.core import (ClientHistoryDB, ClientUpdate, DeviceUpdateBatch,
                        StrategyConfig, make_strategy, pipeline_enabled,
                        reset_transfer_stats, transfer_stats)
from repro.core.aggregation import (aggregate, aggregate_reference,
                                    fedavg_coefficients, flat_update_matrix)
from repro.core.compress import CompressionConfig, UpdateCompressor
from repro.core.merge import MergePipeline, ServerOptConfig
from repro.data import make_image_classification
from repro.data.synthetic import ArrayDataset
from repro.faas import CostMeter, FaaSConfig, MockInvoker, SimulatedFaaSPlatform
from repro.faas.platform import ClientProfile
from repro.faas.trace import TraceRecorder
from repro.fl.checkpointing import RoundCheckpointer
from repro.fl.client import ClientPool
from repro.fl.controller import TrainingDriver
from repro.fl.executor import VectorizedExecutor, _batch_indices
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import make_cnn


# ----------------------------------------------------------------------
# shared real-task fixture: 8 clients, equal shards, tiny CNN
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    full = make_image_classification(360, image_size=14, n_classes=5,
                                     seed=0)
    x, y = np.asarray(full.x), np.asarray(full.y)
    parts = {f"c{i}": ArrayDataset(x[i * 40:(i + 1) * 40],
                                   y[i * 40:(i + 1) * 40])
             for i in range(8)}
    model = make_cnn(14, 1, 5, 16, "tiny")
    task = ClassificationTask(
        model, TaskConfig(epochs=1, batch_size=16, per_sample_time_s=0.05))
    return task, parts


def _driver(task, parts, strategy_name, mode, seed=0, compress=None,
            server_opt="sgd", trace=None, profiles=None,
            round_timeout_s=30.0):
    history = ClientHistoryDB()
    history.ensure(parts.keys())
    strategy = make_strategy(
        strategy_name,
        StrategyConfig(clients_per_round=4, max_rounds=10, buffer_k=3,
                       server_opt=server_opt),
        history, seed=seed)
    compressor = None
    if compress:
        compressor = UpdateCompressor(CompressionConfig(
            scheme=compress, topk_ratio=0.05))
    pool = ClientPool(task, parts, None, proximal_mu=strategy.proximal_mu(),
                      seed=seed, compressor=compressor)
    platform = SimulatedFaaSPlatform(
        FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.3,
                   perf_variation=(0.9, 1.1), failure_rate=0.0,
                   network_jitter_s=0.4),
        seed=seed, recorder=trace)
    invoker = MockInvoker(platform, pool.work_fn, profiles or {})
    return TrainingDriver(strategy, invoker, pool, history,
                          CostMeter(trace=trace),
                          round_timeout_s=round_timeout_s, eval_every=0,
                          seed=seed, vectorized=True, mode=mode,
                          trace=trace)


def _digest(params) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(params):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def _round_key(stats):
    return (stats.round_number, stats.selected, stats.successes, stats.late,
            stats.crashed, stats.duration_s, stats.eur, stats.cost)


def _run(task, parts, strategy_name, mode, n_rounds=3, **kw):
    trace = TraceRecorder()
    drv = _driver(task, parts, strategy_name, mode, trace=trace, **kw)
    params, res = drv.run(task.init_params(0), n_rounds)
    return _digest(params), [_round_key(r) for r in res.rounds], \
        trace.dumps().encode()


# ----------------------------------------------------------------------
# satellite: vectorized _batch_indices is draw-for-draw identical
# ----------------------------------------------------------------------
def _batch_indices_legacy(n, batch_size, epochs, rng):
    """The pre-PR-8 per-epoch/per-batch Python loop, verbatim."""
    idx_rows, mask_rows = [], []
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n, batch_size):
            chunk = order[i:i + batch_size]
            pad = batch_size - len(chunk)
            mask = np.ones(batch_size, dtype=np.float32)
            if pad:
                chunk = np.concatenate(
                    [chunk, np.zeros(pad, dtype=chunk.dtype)])
                mask[batch_size - pad:] = 0.0
            idx_rows.append(chunk)
            mask_rows.append(mask)
    return np.stack(idx_rows), np.stack(mask_rows)


@pytest.mark.parametrize("n,bs,epochs", [
    (10, 4, 3), (32, 32, 2), (7, 8, 1), (100, 16, 4), (1, 4, 2),
    (40, 16, 1), (33, 8, 5),
])
def test_batch_indices_vectorized_parity(n, bs, epochs):
    idx_a, mask_a = _batch_indices(n, bs, epochs, np.random.default_rng(7))
    idx_b, mask_b = _batch_indices_legacy(n, bs, epochs,
                                          np.random.default_rng(7))
    assert idx_a.dtype == idx_b.dtype
    assert np.array_equal(idx_a, idx_b)
    assert np.array_equal(mask_a, mask_b)


# ----------------------------------------------------------------------
# golden traces: toggling the pipeline changes nothing, any mode
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", ["sync_fedavg_apodotiko",
                                  "semiasync_fedlesscan",
                                  "async_fedbuff_rotation"])
def test_golden_traces_pipeline_toggle(name, monkeypatch):
    golden = (GOLDEN_DIR / f"{name}.jsonl").read_bytes()
    monkeypatch.setenv("REPRO_DEVICE_PIPELINE", "1")
    on_trace, on_digest = run_scenario(name)
    monkeypatch.setenv("REPRO_DEVICE_PIPELINE", "0")
    off_trace, off_digest = run_scenario(name)
    assert on_trace == golden
    assert off_trace == golden
    assert on_digest == off_digest


# ----------------------------------------------------------------------
# real-task byte parity: enabled vs disabled, three modes, compression
# ----------------------------------------------------------------------
@pytest.mark.parametrize("strategy,mode,compress,server_opt", [
    ("fedavg", "sync", None, "sgd"),
    ("fedavg", "sync", None, "fedadam"),       # fused-kernel merge path
    ("fedlesscan", "semi-async", "topk", "sgd"),
    ("fedbuff", "async", None, "sgd"),
])
def test_pipeline_parity_real_task(setup, strategy, mode, compress,
                                   server_opt, monkeypatch):
    task, parts = setup
    monkeypatch.setenv("REPRO_DEVICE_PIPELINE", "1")
    on = _run(task, parts, strategy, mode, compress=compress,
              server_opt=server_opt)
    monkeypatch.setenv("REPRO_DEVICE_PIPELINE", "0")
    off = _run(task, parts, strategy, mode, compress=compress,
               server_opt=server_opt)
    assert on[0] == off[0], "final params diverged"
    assert on[1] == off[1], "round stats diverged"
    assert on[2] == off[2], "trace diverged"


# ----------------------------------------------------------------------
# lazy materialization + batched loss sync
# ----------------------------------------------------------------------
def test_device_batch_lazy_materialization(setup, monkeypatch):
    task, parts = setup
    monkeypatch.setenv("REPRO_DEVICE_PIPELINE", "1")
    pool = ClientPool(task, parts, None, seed=0)
    cids = ["c0", "c1", "c2"]
    gp = task.init_params(0)
    reset_transfer_stats()
    results = pool.batch_work_fn(cids, gp, 0)
    assert transfer_stats()["materialize_rows"] == 0, \
        "packaging must not materialize per-client trees"
    updates = [results[c][0] for c in cids]
    batch = updates[0].batch
    assert isinstance(batch, DeviceUpdateBatch)
    assert all(u.batch is batch for u in updates)

    # materializing one row == the legacy per-client slice, bit for bit
    ex = pool.executor
    datasets = [pool.clients[c].dataset for c in cids]
    seeds = [pool.client_seed(c, 0) for c in cids]
    legacy = ex.run_group(cids, datasets, gp, pool.proximal_mu, seeds)
    for i, cid in enumerate(cids):
        lazy_tree = updates[i].params         # triggers materialization
        for a, b in zip(jax.tree_util.tree_leaves(lazy_tree),
                        jax.tree_util.tree_leaves(legacy[cid][0])):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    assert transfer_stats()["materialize_rows"] == len(cids)

    # the whole loss vector crosses the host boundary exactly once
    reset_transfer_stats()
    b2 = ex.run_group_batch(cids, datasets, gp, pool.proximal_mu, seeds)
    for i, cid in enumerate(cids):
        assert b2.loss(i) == legacy[cid][1]
    assert transfer_stats()["loss_syncs"] == 1


def test_flat_update_matrix_gather_matches_ravel(setup, monkeypatch):
    task, parts = setup
    monkeypatch.setenv("REPRO_DEVICE_PIPELINE", "1")
    pool = ClientPool(task, parts, None, seed=0)
    cids = ["c0", "c1", "c2"]
    gp = task.init_params(0)
    results = pool.batch_work_fn(cids, gp, 0)
    updates = [results[c][0] for c in cids]
    mat, unravel = flat_update_matrix(updates)
    assert mat.shape[0] == len(cids)
    for i, u in enumerate(updates):
        ref = jax.flatten_util.ravel_pytree(u.params)[0]
        assert np.array_equal(np.asarray(mat[i]), np.asarray(ref))
    # gather returns a fresh array — mutating consumers (donation) can
    # never invalidate the batch matrix rows
    assert mat is not updates[0].batch.mat


# ----------------------------------------------------------------------
# compression on flat rows == compression on trees
# ----------------------------------------------------------------------
@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_encode_flat_matches_encode(scheme):
    rng = np.random.default_rng(3)
    gp = {"a": jnp.asarray(rng.normal(size=(9, 5)), jnp.float32),
          "b": jnp.asarray(rng.normal(size=(17,)), jnp.float32)}
    cfg = CompressionConfig(scheme=scheme, topk_ratio=0.2, chunk=16)
    tree_c, flat_c = UpdateCompressor(cfg), UpdateCompressor(cfg)
    for step in range(3):                     # residuals accumulate
        upd = jax.tree_util.tree_map(
            lambda l: l + jnp.asarray(rng.normal(size=l.shape) * 0.1,
                                      jnp.float32), gp)
        flat_u = jax.flatten_util.ravel_pytree(upd)[0]
        recon, pb, db = tree_c.encode("c0", upd, gp)
        row, pb2, db2 = flat_c.encode_flat("c0", flat_u, gp)
        assert (pb, db) == (pb2, db2)
        ref = jax.flatten_util.ravel_pytree(recon)[0]
        assert np.array_equal(np.asarray(row), np.asarray(ref)), \
            f"step {step}: flat reconstruction diverged"
    ra = np.asarray(tree_c._residuals["c0"])
    rb = np.asarray(flat_c._residuals["c0"])
    assert np.array_equal(ra, rb)


# ----------------------------------------------------------------------
# donation safety: retained global params survive donated merges
# ----------------------------------------------------------------------
def test_donation_safety_retained_global_params():
    rng = np.random.default_rng(0)
    gp = {"w": jnp.asarray(rng.normal(size=(1031,)), jnp.float32)}
    gp_before = np.asarray(gp["w"]).copy()
    updates = [ClientUpdate(f"c{i}",
                            {"w": jnp.asarray(rng.normal(size=(1031,)),
                                              jnp.float32)},
                            10, 0) for i in range(4)]
    coeffs = fedavg_coefficients(updates)
    merger = MergePipeline(ServerOptConfig(name="fedadam", lr=0.1))
    out1 = merger.merge(gp, updates, coeffs)
    # the strategy retains gp across the merge: donation must never take
    # the params buffer, so gp stays readable and bit-identical
    assert np.array_equal(np.asarray(gp["w"]), gp_before)
    out2 = merger.merge(gp, updates, coeffs)   # moments donated + rebuilt
    assert np.all(np.isfinite(np.asarray(out2["w"])))
    assert merger.steps == 2
    # the plain weighted sum with a donated matrix matches the reference
    agg = aggregate(updates, coeffs)
    ref = aggregate_reference(updates, coeffs)
    np.testing.assert_allclose(np.asarray(agg["w"]), np.asarray(ref["w"]),
                               rtol=1e-6, atol=1e-6)
    assert out1 is not None


# ----------------------------------------------------------------------
# checkpoint/resume with in-flight batch-backed updates + compression
# ----------------------------------------------------------------------
def test_resume_with_inflight_batch_updates(setup, tmp_path, monkeypatch):
    """A slow client's batch-backed update spans the checkpoint boundary:
    the engine snapshot materializes it lazily (invoker state_dict), the
    compressor residuals ride along, and the resumed run replays the
    tail byte-identically."""
    task, parts = setup
    monkeypatch.setenv("REPRO_DEVICE_PIPELINE", "1")
    profiles = {"c0": ClientProfile(slow_factor=8.0)}
    kw = dict(compress="topk", profiles=profiles, round_timeout_s=8.0)

    ref = _driver(task, parts, "fedlesscan", "semi-async", **kw)
    ref_params, ref_res = ref.run(task.init_params(0), 4)

    first = _driver(task, parts, "fedlesscan", "semi-async", **kw)
    ckpt = RoundCheckpointer(tmp_path / "ckpt")
    first.run(task.init_params(0), 2, checkpointer=ckpt, checkpoint_every=2)

    resumed = _driver(task, parts, "fedlesscan", "semi-async", **kw)
    params0, next_round = ckpt.restore(resumed, task.init_params(0))
    assert next_round == 2
    tail_params, tail_res = resumed.run(params0, 4, start_round=next_round)

    assert [_round_key(r) for r in tail_res.rounds] == \
        [_round_key(r) for r in ref_res.rounds[2:]]
    assert _digest(tail_params) == _digest(ref_params)


# ----------------------------------------------------------------------
# recompile-free rounds within one power-of-two bucket
# ----------------------------------------------------------------------
def test_recompile_counter_flat_within_bucket(setup, monkeypatch):
    task, parts = setup
    monkeypatch.setenv("REPRO_DEVICE_PIPELINE", "1")
    pool = ClientPool(task, parts, None, seed=0)
    ex = VectorizedExecutor(task)
    gp = task.init_params(0)
    ids = pool.client_ids
    # warm-up compiles the bucket-4 dispatch up front …
    ex.warmup(pool, ids[:4], gp)
    compiled = ex.compile_count
    assert compiled >= 1
    # … then 5 rounds with cohort sizes all in the 4-bucket: 0 new
    # compiles (equal shards ⇒ one group; 3 and 4 both pad to K=4)
    for rnd, size in enumerate([3, 4, 3, 4, 3], start=1):
        ex.run_clients(pool, ids[:size], gp, rnd)
        assert ex.compile_count == compiled, \
            f"round {rnd} (cohort {size}) recompiled"
    # a bucket jump (5 → K=8) is a legitimate new compile
    ex.run_clients(pool, ids[:5], gp, 9)
    assert ex.compile_count == compiled + 1


def test_client_update_batch_semantics():
    mat = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    unravel = lambda flat: {"w": flat}
    batch = DeviceUpdateBatch(mat, ["a", "b"], unravel,
                              losses=jnp.asarray([0.5, 0.25, 0.0, 0.0]))
    u = ClientUpdate("a", num_samples=10, round_number=1,
                     batch=batch, batch_row=0)
    assert np.array_equal(np.asarray(u.flat_params()), [0.0, 1.0, 2.0])
    assert np.array_equal(np.asarray(u.params["w"]), [0.0, 1.0, 2.0])
    # set_row invalidates the cached tree; assignment detaches the batch
    batch.set_row(0, jnp.asarray([9.0, 9.0, 9.0]))
    u2 = ClientUpdate("a2", batch=batch, batch_row=0)
    assert np.array_equal(np.asarray(u2.params["w"]), [9.0, 9.0, 9.0])
    u2.params = {"w": jnp.zeros(3)}
    assert u2.batch is None and u2.batch_row == -1
    with pytest.raises(ValueError):
        ClientUpdate("c")                     # neither params nor batch
    with pytest.raises(IndexError):
        batch.row(2)                          # padding rows unaddressable
    assert batch.loss(1) == 0.25
    assert pipeline_enabled() in (True, False)
