"""Unit tests: staleness-aware aggregation (paper Eq. 3)."""
import jax.numpy as jnp
import numpy as np

from repro.core import (ClientUpdate, UpdateStore, fedavg_aggregate,
                        staleness_aggregate, staleness_coefficients)


def _upd(cid, value, n, rnd):
    return ClientUpdate(cid, {"w": jnp.full((4,), float(value))}, n, rnd)


def test_fresh_updates_reduce_to_fedavg():
    """t_k = t ⇒ Eq. 3 becomes FedAvg exactly."""
    ups = [_upd("a", 1.0, 10, 7), _upd("b", 3.0, 30, 7)]
    got = staleness_aggregate(ups, current_round=7, tau=2)
    want = fedavg_aggregate(ups)
    np.testing.assert_allclose(got["w"], want["w"], rtol=1e-6)
    np.testing.assert_allclose(got["w"], np.full(4, 2.5), rtol=1e-6)


def test_stale_updates_dampened():
    fresh = [_upd("a", 1.0, 10, 5)]
    stale = [_upd("a", 1.0, 10, 4)]
    g_fresh = staleness_aggregate(fresh, 5, tau=3)
    g_stale = staleness_aggregate(stale, 5, tau=3)
    assert float(g_stale["w"][0]) < float(g_fresh["w"][0])
    # damping factor is (t_k+1)/(t+1) = 5/6
    np.testing.assert_allclose(g_stale["w"], np.full(4, 5.0 / 6.0), rtol=1e-6)


def test_tau_discards_obsolete():
    ups = [_upd("a", 1.0, 10, 2), _upd("b", 5.0, 10, 5)]
    got = staleness_aggregate(ups, 5, tau=2)   # age 3 ≥ τ → dropped
    np.testing.assert_allclose(got["w"], np.full(4, 5.0), rtol=1e-6)
    assert staleness_aggregate([_upd("a", 1.0, 10, 0)], 5, tau=2) is None


def test_coefficients_sum_below_one_with_staleness():
    ups = [_upd("a", 1.0, 10, 4), _upd("b", 1.0, 10, 5)]
    c = staleness_coefficients(ups, 5)
    assert c.sum() <= 1.0 + 1e-9
    assert np.all(c >= 0)


def test_update_store_semantics():
    store = UpdateStore(tau=2)
    store.push(_upd("late", 1.0, 10, 3))
    store.push(_upd("older", 1.0, 10, 1))
    fresh = store.pop_for_round(4)
    assert [u.client_id for u in fresh] == ["late"]
    assert len(store) == 0                      # popped clears


def test_fedavg_weighting_by_cardinality():
    ups = [_upd("a", 0.0, 90, 0), _upd("b", 10.0, 10, 0)]
    got = fedavg_aggregate(ups)
    np.testing.assert_allclose(got["w"], np.full(4, 1.0), rtol=1e-6)


def test_update_store_arrival_times():
    """In-flight updates stay queued until their arrival time; aged-out
    ones are dropped when finally visible."""
    store = UpdateStore(tau=2)
    store.push(_upd("fast", 1.0, 10, 3), arrival_time=100.0)
    store.push(_upd("slow", 2.0, 10, 3), arrival_time=999.0)
    got = store.pop_for_round(4, now=150.0)
    assert [u.client_id for u in got] == ["fast"]
    assert len(store) == 1                      # slow still in flight
    # by the time 'slow' arrives, it has aged out (round 8, age 5 >= tau)
    got = store.pop_for_round(8, now=1000.0)
    assert got == [] and len(store) == 0
