"""Fleet-scale acceptance gates (store parity, interning, streaming
traces, array-state round-trips) + slow-marked 10⁵ propose checks.

The byte-parity tests are the contract of the array-backed store
refactor: on seeded 20-client runs across all three training modes, the
flat NumPy `ClientHistoryDB` + vectorized schedulers must reproduce the
PR 5 dict implementation's traces and final params byte-for-byte (the
goldens under tests/golden/ were generated on the dict code).
"""
import json

import numpy as np
import pytest

from fleet_parity_common import GOLDEN_DIR, SCENARIOS, run_scenario
from repro.core.history import ClientHistoryDB
from repro.core.interning import ClientInterner
from repro.core.selection import select_clients
from repro.faas.trace import TraceRecorder


# ---------------------------------------------------------------------------
# store parity vs the dict-backed goldens (all three training modes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [s[0] for s in SCENARIOS])
def test_store_parity_byte_identical(name):
    trace_bytes, params_digest = run_scenario(name)
    golden = (GOLDEN_DIR / f"{name}.jsonl").read_bytes()
    digests = json.loads((GOLDEN_DIR / "params_digests.json").read_text())
    assert trace_bytes == golden, f"{name}: trace diverged from golden"
    assert params_digest == digests[name], f"{name}: final params diverged"


# ---------------------------------------------------------------------------
# interning table under register / miss / crash churn
# ---------------------------------------------------------------------------

def test_interner_indices_stable_under_churn():
    rng = np.random.default_rng(0)
    interner = ClientInterner()
    first_seen = {}
    for _ in range(200):
        batch = [f"c{int(i):04d}" for i in rng.integers(0, 500, size=20)]
        idx = interner.intern_many(batch)
        for cid, i in zip(batch, idx):
            assert first_seen.setdefault(cid, int(i)) == int(i), \
                "an interned id changed index"
    # dense, bijective, registration-ordered
    assert sorted(first_seen.values()) == list(range(len(first_seen)))
    for cid, i in first_seen.items():
        assert interner.index_of(cid) == i
        assert interner.id_of(i) == cid


def test_interner_lex_ranks_match_id_order():
    rng = np.random.default_rng(1)
    ids = [f"client-{int(i):05d}" for i in rng.permutation(300)]
    interner = ClientInterner(ids)
    ranks = interner.lex_ranks()
    by_rank = sorted(range(len(ids)), key=lambda i: ranks[i])
    assert [interner.id_of(i) for i in by_rank] == sorted(ids)
    # cache invalidates on growth
    interner.intern("aaa-sorts-first")
    ranks2 = interner.lex_ranks()
    assert ranks2.size == len(ids) + 1
    assert ranks2[interner.index_of("aaa-sorts-first")] == 0


def test_interner_pool_memo_identity_and_invalidation():
    interner = ClientInterner([f"c{i}" for i in range(10)])
    pool = [f"c{i}" for i in range(10)]
    a = interner.indices_for(pool)
    assert interner.indices_for(pool) is a          # memo hit by identity
    pool.append("c10")                              # length change → miss
    b = interner.indices_for(pool)
    assert b.size == 11
    np.testing.assert_array_equal(b[:10], a)        # stable prefix


def test_interner_state_roundtrip():
    interner = ClientInterner([f"c{i}" for i in range(25)])
    clone = ClientInterner()
    clone.load_state_dict(interner.state_dict())
    assert len(clone) == 25
    assert all(clone.index_of(f"c{i}") == i for i in range(25))


def test_interner_property_churn():
    hypothesis = pytest.importorskip("hypothesis")
    given, settings, st = (hypothesis.given, hypothesis.settings,
                           hypothesis.strategies)

    @settings(deadline=None, max_examples=50)
    @given(st.lists(st.lists(st.integers(0, 99), min_size=1, max_size=10),
                    max_size=20))
    def run(batches):
        interner = ClientInterner()
        mirror = {}
        for batch in batches:
            ids = [f"c{i}" for i in batch]
            idx = interner.intern_many(ids)
            for cid, j in zip(ids, idx):
                assert mirror.setdefault(cid, int(j)) == int(j)
        assert sorted(mirror.values()) == list(range(len(mirror)))
        assert len(interner) == len(mirror)

    run()


# ---------------------------------------------------------------------------
# array-backed history: behavioural churn + checkpoint round-trip
# ---------------------------------------------------------------------------

def _churned_db(n=40, rounds=12, seed=3):
    """Mixed register / success / miss / crash / late-report history."""
    rng = np.random.default_rng(seed)
    ids = [f"c{i:03d}" for i in range(n)]
    db = ClientHistoryDB()
    db.ensure(ids[: n // 2])
    for r in range(1, rounds + 1):
        if r == 4:
            db.ensure(ids)                          # late registrations
        known = ids if r >= 4 else ids[: n // 2]
        cohort = rng.choice(known, size=min(8, len(known)), replace=False)
        for cid in cohort:
            roll = rng.random()
            if roll < 0.25:                         # miss / crash
                db.get(cid).apply_miss(r)
            elif roll < 0.35:                       # late report for r-1
                db.client_report(cid, max(1, r - 1),
                                 float(5.0 + 10.0 * rng.random()))
            else:
                db.mark_success(cid, r)
                db.client_report(cid, r,
                                 float(5.0 + 10.0 * rng.random()))
    return db, ids


def test_history_payload_roundtrip_rebuilds_array_state():
    db, ids = _churned_db()
    db2 = ClientHistoryDB()
    db2.load_payload(db.to_payload())
    idx = db.indices_for(ids)
    idx2 = db2.indices_for(ids)
    for name in ("_t_ema", "_t_ema32", "_t_max", "_tier", "_cooldown",
                 "_n_times", "_n_missed", "_invocations"):
        a, b = getattr(db, name), getattr(db2, name)
        np.testing.assert_array_equal(
            a[idx], b[idx2], err_msg=f"{name} diverged after round-trip")
    # derived mirrors really are derived, not stale copies
    np.testing.assert_array_equal(
        db2._t_ema32[idx2], db2._t_ema[idx2].astype(np.float32))
    # selection is identical on the restored store
    plan_a = select_clients(db, ids, 13, 20, 6,
                            np.random.default_rng(99))
    plan_b = select_clients(db2, ids, 13, 20, 6,
                            np.random.default_rng(99))
    assert plan_a.selected == plan_b.selected
    assert plan_a.rookies == plan_b.rookies
    assert plan_a.straggler_clients == plan_b.straggler_clients


def test_record_view_matches_array_columns():
    db, ids = _churned_db(n=12, rounds=6, seed=5)
    idx = db.indices_for(ids)
    for cid, i in zip(ids, idx):
        rec = db.get(cid)
        times = rec.training_times
        assert db._n_times[i] == len(times)
        if times:
            assert db._t_max[i] == max(times)
        assert db._n_missed[i] == len(rec.missed_rounds)


def test_apodotiko_state_roundtrip_rebuilds_f32_mirrors():
    from repro.fl.scheduler import ApodotikoScheduler
    ids = [f"c{i:02d}" for i in range(30)]
    sched = ApodotikoScheduler(6, seed=0)
    rng = np.random.default_rng(2)
    for r in range(1, 8):
        picked = sched.propose(ids, 6, float(r), r)
        for cid in picked:
            if rng.random() < 0.3:
                sched.notify_miss(cid, float(r))
            else:
                sched.notify_finish(cid, float(r),
                                    duration_s=float(rng.random() * 9),
                                    cold=bool(rng.random() < 0.4))
    clone = ApodotikoScheduler(6, seed=0)
    clone.load_state_dict(sched.state_dict())
    # the clone re-interns from the state dict, so compare per client id
    # (the f32 score mirrors must be rebuilt, not left at init zeros)
    for cid in ids:
        i = sched._interner.lookup(cid)
        j = clone._interner.lookup(cid)
        if j < 0:
            assert sched._dur32[i] == 0.0 and not sched._seen[i]
            continue
        assert clone._dur32[j] == sched._dur32[i], cid
        assert clone._rate_succ[j] == sched._rate_succ[i], cid
        assert clone._rate_cold[j] == sched._rate_cold[i], cid
    assert clone.propose(ids, 6, 8.0, 8) == sched.propose(ids, 6, 8.0, 8)


# ---------------------------------------------------------------------------
# streaming / sharded TraceRecorder
# ---------------------------------------------------------------------------

def _emit_mixed_records(rec, n):
    for i in range(n):
        rec.attempt(client_id=f"c{i % 7}", platform="sim", round_number=i,
                    attempt=0, start_time=float(i), arrival_time=i + 0.5,
                    cold=(i % 3 == 0), cold_start_s=0.2, billed_s=1.5,
                    status="ok" if i % 5 else "crash")
        rec.billing(cost=0.001 * i, duration_s=1.5, kind="invocation",
                    client_id=f"c{i % 7}", round_number=i)
        if i % 4 == 0:
            rec.scheduling(time=float(i), round_number=i, scheduler="t",
                           mode="sync", want=2, selected=["a", "b"],
                           pool_size=7)


def test_streaming_trace_bytes_identical(tmp_path):
    buffered = TraceRecorder()
    streamed = TraceRecorder(stream_path=tmp_path / "t.jsonl",
                             flush_every=16)
    _emit_mixed_records(buffered, 100)
    _emit_mixed_records(streamed, 100)
    assert streamed._flushed > 0                    # actually streamed
    assert streamed.dumps() == buffered.dumps()
    assert streamed.record_count == buffered.record_count == 225
    assert abs(streamed.billed_total() - buffered.billed_total()) == 0.0


def test_streaming_trace_shard_rotation(tmp_path):
    rec = TraceRecorder(stream_path=tmp_path / "t.jsonl",
                        flush_every=8, shard_records=50)
    _emit_mixed_records(rec, 100)
    rec.flush()
    shards = rec.shard_paths()
    assert len(shards) > 1                          # rotated
    assert all(p.name.startswith("t.") for p in shards)
    per_shard = [sum(1 for _ in p.open()) for p in shards]
    assert all(c <= 50 for c in per_shard)
    assert sum(per_shard) == rec.record_count
    # read-back surface spans every shard, in emission order
    ref = TraceRecorder()
    _emit_mixed_records(ref, 100)
    assert rec.dumps() == ref.dumps()
    assert rec.select("billing") == ref.select("billing")


def test_streaming_to_jsonl_export_matches(tmp_path):
    rec = TraceRecorder(stream_path=tmp_path / "s.jsonl", flush_every=4,
                        shard_records=20)
    _emit_mixed_records(rec, 30)
    out = rec.to_jsonl(tmp_path / "export.jsonl")
    ref = TraceRecorder()
    _emit_mixed_records(ref, 30)
    assert out.read_text() == ref.dumps()


# ---------------------------------------------------------------------------
# fleet-scale smoke (tier-2: excluded from tier-1 by the `slow` marker)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("policy",
                         ["random", "fedlesscan", "apodotiko", "rotation"])
def test_propose_at_100k_under_budget(policy):
    import time

    import benchmarks.bench_fleet_scale as B
    db, ids = B.seed_history(100_000, seed=7)
    sched = B.make_scheduler(policy, db, ids, 256, seed=7)
    sched.propose(ids, 256, 1.0, 1)                 # warmup
    times = []
    for r in range(2, 7):
        t0 = time.perf_counter()
        cohort = sched.propose(ids, 256, float(r), r)
        times.append(time.perf_counter() - t0)
        assert len(cohort) == 256
    assert sorted(times)[len(times) // 2] < 0.05    # the 10⁶ gate, at 10⁵
