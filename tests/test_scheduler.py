"""Tests for the unified scheduling subsystem (fl/scheduler.py).

Covers the Scheduler protocol and the shipped policies (random, full,
fedlesscan, apodotiko, adaptive, rotation), the Strategy.select
compatibility shim, the driver integration in barrier and barrier-free
modes (scheduling trace records, feedback hooks), and scheduler
overrides through ExperimentConfig.
"""
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClientHistoryDB, ClientUpdate, StrategyConfig,
                        make_strategy, select_clients, select_random)
from repro.faas import (CostMeter, FaaSConfig, MockInvoker,
                        SimulatedFaaSPlatform, TraceRecorder)
from repro.fl.controller import TrainingDriver
from repro.fl.scheduler import (SCHEDULERS, AdaptiveScheduler,
                                ApodotikoScheduler, RandomScheduler,
                                RotationScheduler, make_scheduler)

IDS = [f"c{i}" for i in range(8)]


def _stats(eur, selected=6, late=0, crashed=0):
    return SimpleNamespace(eur=eur, selected=["x"] * selected,
                           late=["x"] * late, crashed=["x"] * crashed)


# ---------------------------------------------------------------- factory
def test_factory_registry_and_errors():
    assert set(SCHEDULERS) == {"random", "full", "fedlesscan", "apodotiko",
                               "adaptive", "rotation"}
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("greedy", 4)
    with pytest.raises(ValueError, match="history"):
        make_scheduler("fedlesscan", 4)


def test_random_scheduler_matches_select_random():
    sched = RandomScheduler(4, seed=7)
    want = select_random(IDS, 4, np.random.default_rng(7))
    assert sched.propose(IDS, 4, 0.0, 0) == want


# ---------------------------------------------------------------- shim
def test_strategy_select_shim_preserves_behaviour():
    """Strategy.select delegates to its scheduler and reproduces the
    pre-scheduler selection stream exactly (same rng, same draws)."""
    history = ClientHistoryDB()
    history.ensure(IDS)
    cfg = StrategyConfig(clients_per_round=3, max_rounds=10)
    fedavg = make_strategy("fedavg", cfg, history, seed=3)
    assert fedavg.select(IDS, 0) == select_random(
        IDS, 3, np.random.default_rng(3))

    for i in range(5):
        history.mark_success(f"c{i}", 0)
        history.client_report(f"c{i}", 0, 10.0 + i)
    fls = make_strategy("fedlesscan", cfg, history, seed=3)
    want = select_clients(history, IDS, 2, 10, 3,
                          np.random.default_rng(3), ema_alpha=cfg.ema_alpha)
    assert fls.select(IDS, 2) == want.selected
    assert fls.last_plan is not None          # plan still surfaced
    assert fls.last_plan.selected == want.selected

    safa = make_strategy("safa", cfg, history, seed=3)
    assert safa.select(IDS, 0) == list(IDS)


# ---------------------------------------------------------------- rotation
def test_rotation_deterministic_cycle_and_eligibility():
    sched = RotationScheduler(3, IDS, timeout_s=10.0)
    assert sched.propose(IDS, 3, 0.0, 0) == ["c0", "c1", "c2"]
    # in-flight exclusion: the driver passes only eligible clients
    assert sched.propose([c for c in IDS if c not in {"c3", "c4"}],
                         2, 0.0, 0) == ["c5", "c6"]


def test_rotation_backoff_doubles_and_resets():
    sched = RotationScheduler(1, ["a", "b"], timeout_s=10.0)
    sched.notify_miss("a", now=0.0)           # cooldown until 10
    assert sched.propose(["a", "b"], 1, 5.0, 0) == ["b"]
    sched.notify_miss("a", now=20.0)          # streak 2: until 20 + 20
    assert sched.propose(["a"], 1, 30.0, 0) == ["a"]   # fallback probe
    assert sched.propose(["a", "b"], 1, 30.0, 0) == ["b"]
    sched.notify_finish("a", now=50.0)        # arrival clears the backoff
    assert sched._cooldown_until.get("a") is None
    assert sched._fail_streak["a"] == 0


# ---------------------------------------------------------------- apodotiko
def test_apodotiko_explores_rookies_then_avoids_stragglers():
    sched = ApodotikoScheduler(2, seed=0)
    first = sched.propose(IDS, 8, 0.0, 0)
    assert sorted(first) == sorted(IDS)       # all rookies explored
    # feedback: c0/c1 reliable and fast, c7 crashes every time
    for rnd in range(12):
        sched.notify_finish("c0", rnd, duration_s=5.0)
        sched.notify_finish("c1", rnd, duration_s=6.0)
        sched.notify_miss("c7", rnd)
        for cid in IDS[2:7]:
            sched.notify_finish(cid, rnd, duration_s=20.0)
    picks = [cid for rnd in range(10, 40)
             for cid in sched.propose(IDS, 2, 0.0, rnd)]
    assert picks.count("c7") < picks.count("c0")
    assert picks.count("c7") < picks.count("c1")


def test_apodotiko_deterministic_and_staleness_boosts_ignored():
    a = ApodotikoScheduler(3, seed=5)
    b = ApodotikoScheduler(3, seed=5)
    for rnd in range(3):
        assert a.propose(IDS, 3, 0.0, rnd) == b.propose(IDS, 3, 0.0, rnd)
    # staleness: a long-ignored reliable client outscores an equally
    # reliable recently-picked one
    sched = ApodotikoScheduler(1, seed=0)
    for cid in ("c0", "c1"):
        sched.notify_finish(cid, 0.0, duration_s=10.0)
    sched._last_selected["c0"] = 9
    sched._last_selected["c1"] = 0
    scores = sched._scores(["c0", "c1"], 10)
    assert scores[1] > scores[0]


def test_apodotiko_late_arrival_counts_one_observation():
    """A late-but-alive invocation is reported twice by the driver
    (notify_miss at the deadline, notify_finish(late=True) on arrival)
    but must count as ONE resolved invocation — otherwise productive
    stragglers' success rates are deflated twice."""
    sched = ApodotikoScheduler(2, seed=0)
    sched.notify_miss("c0", 30.0, crashed=False)      # deadline
    sched.notify_finish("c0", 45.0, duration_s=40.0, late=True)
    assert sched._observations["c0"] == 1
    assert sched._successes.get("c0", 0) == 0
    assert sched._duration_ema["c0"] == 40.0          # data still recorded
    # 1 on-time + 1 late -> success rate 1/2, not 1/3
    sched.notify_finish("c0", 60.0, duration_s=10.0)
    assert sched._successes["c0"] / sched._observations["c0"] == 0.5


def test_apodotiko_state_roundtrip():
    a = ApodotikoScheduler(2, seed=1)
    a.propose(IDS, 2, 0.0, 0)
    a.notify_finish("c0", 1.0, duration_s=4.0, cold=True)
    a.notify_miss("c3", 1.0)
    b = ApodotikoScheduler(2, seed=99)
    b.load_state_dict(a.state_dict())
    for rnd in range(1, 4):
        assert a.propose(IDS, 2, 0.0, rnd) == b.propose(IDS, 2, 0.0, rnd)


# ---------------------------------------------------------------- adaptive
def test_adaptive_cohort_grows_and_shrinks_with_eur():
    sched = AdaptiveScheduler(6, seed=0, min_cohort=2, max_cohort=10)
    assert sched.cohort_size(0, []) == 6
    for _ in range(3):
        sched.cohort_size(1, [_stats(1.0)] * 3)
    assert sched.cohort_size(4, [_stats(1.0)] * 3) > 6      # healthy: grow
    for _ in range(12):
        sched.cohort_size(5, [_stats(0.3, late=2, crashed=2)] * 3)
    assert sched.cohort_size(9, [_stats(0.3, late=2, crashed=2)] * 3) == 2
    assert sched._size >= sched.min_cohort


def test_adaptive_delegates_selection_to_inner():
    inner = RandomScheduler(6, seed=4)
    sched = AdaptiveScheduler(6, inner=inner)
    want = select_random(IDS, 4, np.random.default_rng(4))
    assert sched.propose(IDS, 4, 0.0, 0) == want


# ---------------------------------------------------------------- driver
def _work_fn(cid, params, rnd):
    return ClientUpdate(cid, {"w": jnp.full((4,), 1.0)}, 10, rnd), 10.0


class _StubPool:
    def __init__(self, client_ids):
        self._ids = list(client_ids)
        self.clients = {}

    @property
    def client_ids(self):
        return self._ids


def _driver(client_ids, strategy_name, profiles=None, cohort=3,
            round_timeout_s=30.0, seed=0, trace=None, scheduler=None,
            **strat_kw):
    history = ClientHistoryDB()
    history.ensure(client_ids)
    strategy = make_strategy(
        strategy_name,
        StrategyConfig(clients_per_round=cohort, max_rounds=20, **strat_kw),
        history, seed=seed)
    platform = SimulatedFaaSPlatform(
        FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.0,
                   perf_variation=(1.0, 1.0), failure_rate=0.0,
                   network_jitter_s=0.0),
        seed=seed, recorder=trace)
    invoker = MockInvoker(platform, _work_fn, profiles or {})
    return TrainingDriver(strategy, invoker, _StubPool(client_ids), history,
                          CostMeter(trace=trace),
                          round_timeout_s=round_timeout_s, eval_every=0,
                          trace=trace, scheduler=scheduler)


def test_driver_emits_scheduling_records_sync():
    trace = TraceRecorder()
    d = _driver(IDS, "fedlesscan", cohort=3, trace=trace)
    d.run({"w": jnp.zeros(4)}, 3)
    recs = trace.select("scheduling")
    assert len(recs) == 3
    for rnd, rec in enumerate(recs):
        assert rec["round"] == rnd
        assert rec["scheduler"] == "fedlesscan"
        assert rec["mode"] == "semi-async"
        assert len(rec["selected"]) == 3
        assert rec["pool_size"] == len(IDS)


def test_driver_emits_scheduling_records_async():
    trace = TraceRecorder()
    d = _driver(IDS, "fedasync", cohort=3, trace=trace)
    d.run({"w": jnp.zeros(4)}, 2)
    recs = trace.select("scheduling")
    # initial cohort + one refill per delivered update
    assert recs[0]["scheduler"] == "rotation"
    assert recs[0]["want"] == 3 and len(recs[0]["selected"]) == 3
    assert len(recs) >= 1 + 6
    # every selected client was eligible (never in flight twice)
    for rec in recs:
        assert len(rec["selected"]) <= rec["pool_size"]


def test_legacy_select_override_still_drives_cohorts():
    """A pre-scheduler Strategy subclass overriding `select` directly is
    wrapped in StrategySelectScheduler — its policy picks the cohorts."""
    from repro.core.strategies import FedAvg

    class FirstK(FedAvg):
        name = "first-k"

        def select(self, client_ids, round_number):
            return list(client_ids)[:self.config.clients_per_round]

    history = ClientHistoryDB()
    history.ensure(IDS)
    strategy = FirstK(StrategyConfig(clients_per_round=3, max_rounds=20),
                      history, seed=0)
    platform = SimulatedFaaSPlatform(
        FaaSConfig(cold_start_median_s=2.0, cold_start_sigma=0.0,
                   perf_variation=(1.0, 1.0), failure_rate=0.0,
                   network_jitter_s=0.0), seed=0)
    d = TrainingDriver(strategy, MockInvoker(platform, _work_fn, {}),
                       _StubPool(IDS), history, CostMeter(),
                       round_timeout_s=30.0, eval_every=0)
    assert d.scheduler.name == "strategy-select"
    _, res = d.run({"w": jnp.zeros(4)}, 2)
    assert all(r.selected == IDS[:3] for r in res.rounds)


def test_driver_accepts_scheduler_override_in_barrier_mode():
    trace = TraceRecorder()
    sched = ApodotikoScheduler(3, seed=0)
    d = _driver(IDS, "fedavg", cohort=3, trace=trace, scheduler=sched)
    _, res = d.run({"w": jnp.zeros(4)}, 4)
    assert len(res.rounds) == 4
    assert all(r["scheduler"] == "apodotiko"
               for r in trace.select("scheduling"))
    # feedback reached the scheduler: every finishing client observed
    assert sum(sched._observations.values()) > 0


def test_driver_adaptive_scheduler_resizes_cohorts():
    sched = AdaptiveScheduler(4, seed=0, min_cohort=2, max_cohort=6,
                              window=2)
    d = _driver(IDS, "fedavg", cohort=4, scheduler=sched)
    _, res = d.run({"w": jnp.zeros(4)}, 5)
    sizes = [len(r.selected) for r in res.rounds]
    assert sizes[0] == 4
    assert max(sizes) > 4                    # healthy pool → cohort grew


def test_experiment_config_scheduler_override_and_trace(tmp_path):
    from repro.data import label_sorted_shards, make_image_classification
    from repro.data.synthetic import ArrayDataset
    from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                     run_experiment)
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import make_cnn

    full = make_image_classification(400, image_size=14, n_classes=3, seed=0)
    train = ArrayDataset(full.x[:300], full.y[:300])
    parts = label_sorted_shards(train, 8, 2, seed=0)
    task = ClassificationTask(
        make_cnn(14, 1, 3, 16),
        TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    trace = tmp_path / "trace.jsonl"
    cfg = ExperimentConfig(
        strategy="fedlesscan", scheduler="apodotiko", n_rounds=3,
        clients_per_round=4, eval_every=0, seed=0, trace_path=str(trace),
        scenario=ScenarioConfig(round_timeout_s=30.0, seed=0))
    res = run_experiment(task, parts, None, cfg)
    assert len(res.rounds) == 3
    from repro.faas import load_jsonl
    scheds = [r for r in load_jsonl(trace) if r["type"] == "scheduling"]
    assert len(scheds) == 3
    assert all(r["scheduler"] == "apodotiko" for r in scheds)
