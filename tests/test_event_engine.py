"""Tests for the discrete-event simulation engine.

Covers: event-queue determinism, event-driven warm expiry, retry-path
billing, concurrency caps, timeout billing clamp, vmapped-executor parity
with the per-client loop, and the acceptance scenario — a straggler's
update from round t arriving and aggregating at its true virtual arrival
time during round t+1.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClientHistoryDB, ClientUpdate, StrategyConfig,
                        make_strategy)
from repro.faas import (ClientProfile, CostMeter, EventKind, EventQueue,
                        FaaSConfig, InvocationEngine, MockInvoker,
                        PlatformFleet, RoutingPolicy, SimulatedFaaSPlatform,
                        VirtualClock)
from repro.fl.controller import Controller


# ---------------------------------------------------------------- helpers
def _platform(**kw):
    defaults = dict(cold_start_median_s=2.0, cold_start_sigma=0.0,
                    perf_variation=(1.0, 1.0), failure_rate=0.0,
                    network_jitter_s=0.0)
    defaults.update(kw)
    return SimulatedFaaSPlatform(FaaSConfig(**defaults), seed=0)


def _work_fn(cid, params, rnd):
    return ClientUpdate(cid, {"w": jnp.full((4,), 1.0)}, 10, rnd), 10.0


class _StubPool:
    """Minimal ClientPool stand-in: ids only, no real training."""

    def __init__(self, client_ids):
        self._ids = list(client_ids)
        self.clients = {}

    @property
    def client_ids(self):
        return self._ids


def _controller(client_ids, strategy_name="fedlesscan", profiles=None,
                round_timeout_s=30.0, platform=None, **ctl_kw):
    history = ClientHistoryDB()
    history.ensure(client_ids)
    strategy = make_strategy(
        strategy_name,
        StrategyConfig(clients_per_round=len(client_ids), max_rounds=10),
        history, seed=0)
    platform = platform or _platform()
    invoker = MockInvoker(platform, _work_fn, profiles or {})
    return Controller(strategy, invoker, _StubPool(client_ids), history,
                      CostMeter(), round_timeout_s=round_timeout_s,
                      eval_every=0, **ctl_kw)


# ---------------------------------------------------------------- queue
def test_event_queue_orders_by_time_then_seq():
    q = EventQueue(VirtualClock())
    e3 = q.schedule(3.0, EventKind.CLIENT_FINISH, client_id="c")
    e1a = q.schedule(1.0, EventKind.INVOKE_START, client_id="a")
    e1b = q.schedule(1.0, EventKind.INVOKE_START, client_id="b")
    assert [q.pop() for _ in range(3)] == [e1a, e1b, e3]
    assert q.clock.now == 3.0
    assert q.pop() is None


def test_event_queue_cancel_skips_and_preserves_len():
    q = EventQueue(VirtualClock())
    keep = q.schedule(2.0, EventKind.ROUND_DEADLINE)
    drop = q.schedule(1.0, EventKind.CLIENT_FINISH, client_id="x")
    drop.cancel()
    assert len(q) == 1
    assert q.pop() is keep
    # cancelled event never advanced the clock
    assert q.clock.now == 2.0


def test_event_queue_len_stays_exact_under_heavy_cancellation():
    """`len`/`bool` are O(1) counters maintained by schedule/cancel/pop;
    compaction under tombstone-heavy loads must not disturb ordering."""
    q = EventQueue(VirtualClock())
    events = [q.schedule(float(i), EventKind.CLIENT_FINISH,
                         client_id=f"c{i}") for i in range(300)]
    for ev in events[::2]:
        ev.cancel()                       # 150 tombstones → compaction
    assert len(q) == 150
    ev = events[1]
    ev.cancel()
    ev.cancel()                           # double-cancel counted once
    assert len(q) == 149
    assert bool(q)
    popped = []
    while q:
        popped.append(q.pop())
    assert len(popped) == 149
    assert q.pop() is None
    assert len(q) == 0 and not q
    assert popped == sorted(popped, key=lambda e: (e.time, e.seq))


def test_cancel_after_pop_does_not_corrupt_len():
    """Handles to already-delivered events get cancelled on ordinary
    paths (a fired async deadline at late arrival, close_round over a
    resolved lifecycle's COLD_START_DONE) — that must not decrement the
    live counter a second time."""
    q = EventQueue(VirtualClock())
    fired = q.schedule(1.0, EventKind.ROUND_DEADLINE)
    pending = q.schedule(2.0, EventKind.CLIENT_FINISH, client_id="c")
    assert q.pop() is fired
    fired.cancel()                        # stale handle, already delivered
    fired.cancel()
    assert len(q) == 1 and bool(q)
    assert q.pop() is pending
    assert len(q) == 0


def test_event_queue_snapshot_roundtrip():
    """state_dict/load_state_dict replay the pending timeline with the
    original seqs, skip cancelled events, and keep counting seqs past
    the old counter."""
    q = EventQueue(VirtualClock())
    a = q.schedule(5.0, EventKind.CLIENT_FINISH, client_id="a",
                   round_number=3)
    b = q.schedule(1.0, EventKind.WARM_EXPIRY, client_id="b",
                   platform="gcf-gen2")
    dropped = q.schedule(2.0, EventKind.ROUND_DEADLINE)
    dropped.cancel()
    state = q.state_dict()

    q2 = EventQueue(VirtualClock())
    by_seq = q2.load_state_dict(json.loads(json.dumps(state)))
    assert set(by_seq) == {a.seq, b.seq}
    assert len(q2) == 2
    first = q2.pop()
    assert (first.seq, first.kind, first.data) == \
        (b.seq, EventKind.WARM_EXPIRY, {"platform": "gcf-gen2"})
    nxt = q2.schedule(9.0, EventKind.ROUND_DEADLINE)
    assert nxt.seq == dropped.seq + 1     # counter continued, not reset
    last = q2.pop()
    assert (last.seq, last.round_number) == (a.seq, 3)


# ---------------------------------------------------------------- warm pool
def test_warm_expiry_is_event_driven():
    p = _platform(warm_idle_timeout_s=50.0)
    q = EventQueue(p.clock)
    engine = InvocationEngine(MockInvoker(p, _work_fn))
    engine.open_round(q, ["c"], {}, 0, 0.0)
    finish = None
    while True:
        ev = q.pop()
        if ev is None:
            break
        engine.handle(q, ev)
        if ev.kind is EventKind.CLIENT_FINISH:
            finish = ev.time
            assert p.warm_instance_count() == 1
        if ev.kind is EventKind.WARM_EXPIRY:
            assert ev.time == pytest.approx(finish + 50.0)
    assert p.warm_instance_count() == 0          # scaled to zero by event


def test_stale_warm_expiry_is_noop_after_rellease():
    p = _platform(warm_idle_timeout_s=50.0)
    p.invoke("c", 10.0, 0.0)                     # lease until finish+50
    first_lease = p._warm["c"].warm_until
    p.invoke("c", 10.0, 20.0)                    # warm re-invoke, new lease
    assert not p.expire_warm("c", first_lease)   # stale event: no-op
    assert p.warm_instance_count() == 1


# ---------------------------------------------------------------- billing
def test_timeout_kill_bills_at_most_the_timeout():
    p = _platform(function_timeout_s=50.0)
    out = p.invoke("c", 500.0, 0.0)
    assert out.crashed
    assert out.duration_s == pytest.approx(50.0)


def test_retry_bills_both_attempts():
    profiles = {"c": ClientProfile(fail_attempts=1)}
    ctl = _controller(["c"], profiles=profiles, round_timeout_s=100.0,
                      max_retries=1)
    _, stats = ctl.run_round({"w": jnp.zeros(4)}, 0)
    # first attempt failed (billed), retry succeeded (billed)
    assert stats.successes == ["c"]
    assert stats.retries == 1
    assert ctl.cost.invocations == 2
    # the retried round costs more than a clean single-attempt round
    clean = _controller(["c"], round_timeout_s=100.0)
    _, clean_stats = clean.run_round({"w": jnp.zeros(4)}, 0)
    assert stats.cost > clean_stats.cost


def test_retries_are_bounded():
    profiles = {"c": ClientProfile(fail_attempts=10)}
    ctl = _controller(["c"], profiles=profiles, round_timeout_s=500.0,
                      max_retries=2)
    _, stats = ctl.run_round({"w": jnp.zeros(4)}, 0)
    assert stats.successes == []
    assert stats.crashed == ["c"]
    assert ctl.platform.invocations == 3         # initial + 2 retries


def test_quorum_unreachable_closes_at_last_observable_outcome():
    """SAFA: when every client has resolved observably and the k-th
    success can never come, the round closes immediately instead of
    burning the full timeout."""
    profiles = {"broken": ClientProfile(fail_attempts=99)}
    ctl = _controller(["a", "b", "broken"], strategy_name="safa",
                      profiles=profiles, round_timeout_s=500.0,
                      max_retries=1)
    _, stats = ctl.run_round({"w": jnp.zeros(4)}, 0)
    assert sorted(stats.successes) == ["a", "b"]
    assert stats.crashed == ["broken"]
    assert stats.duration_s < 100.0              # not the 500 s timeout


# ---------------------------------------------------------------- capacity
def test_concurrency_cap_serialises_invocations():
    ctl = _controller(["a", "b"], round_timeout_s=200.0, max_concurrency=1)
    _, stats = ctl.run_round({"w": jnp.zeros(4)}, 0)
    assert sorted(stats.successes) == ["a", "b"]
    starts = [ev for ev in ctl.queue.trace
              if ev.kind is EventKind.INVOKE_START]
    finishes = [ev for ev in ctl.queue.trace
                if ev.kind is EventKind.CLIENT_FINISH]
    # the second invocation fires exactly when the first one finishes
    assert starts[1].time == pytest.approx(finishes[0].time)


# ---------------------------------------------------------------- determinism
def test_same_seed_runs_are_identical():
    def run_once():
        profiles = {"slow": ClientProfile(slow_factor=6.0),
                    "dead": ClientProfile(crash=True)}
        ctl = _controller(["a", "b", "slow", "dead"], profiles=profiles,
                          round_timeout_s=30.0)
        params = {"w": jnp.zeros(4)}
        rounds = []
        for rnd in range(3):
            params, stats = ctl.run_round(params, rnd)
            rounds.append(stats)
        trace = [(ev.time, ev.kind.value, ev.client_id)
                 for ev in ctl.queue.trace]
        return rounds, trace

    rounds1, trace1 = run_once()
    rounds2, trace2 = run_once()
    assert trace1 == trace2                      # identical event order
    for s1, s2 in zip(rounds1, rounds2):
        assert s1.successes == s2.successes
        assert s1.late == s2.late
        assert s1.crashed == s2.crashed
        assert s1.duration_s == pytest.approx(s2.duration_s)
        assert s1.cost == pytest.approx(s2.cost)


# ------------------------------------------------------- overlapping rounds
def test_straggler_update_arrives_during_next_round():
    """Acceptance: with jitter/failures off and deterministic cold starts,
    a slow client selected in round 0 finishes during round 1; its update
    must arrive at its true virtual arrival time (round 1's event stream)
    and be aggregated at round 1's close with a staleness-damped weight."""
    profiles = {"slow": ClientProfile(slow_factor=4.0)}
    # fast clients: 2 (cold) + 10 = 12 s; slow: 2 + 40 = 42 s
    ctl = _controller(["a", "b", "slow"], profiles=profiles,
                      round_timeout_s=30.0)
    params = {"w": jnp.zeros(4)}

    params, r0 = ctl.run_round(params, 0)
    assert sorted(r0.successes) == ["a", "b"]
    assert r0.late == ["slow"]
    assert r0.aggregated_updates == 2
    assert len(ctl.strategy.update_store) == 0   # nothing cached yet!

    params, r1 = ctl.run_round(params, 1)
    # the update physically arrived mid-round-1 …
    assert r1.straggler_arrivals == ["slow"]
    arrival = next(ev for ev in ctl.queue.trace
                   if ev.kind is EventKind.CLIENT_FINISH
                   and ev.client_id == "slow")
    assert 30.0 < arrival.time < 30.0 + r1.duration_s
    # … and was merged into round 1's aggregation (successes + straggler)
    assert r1.aggregated_updates == len(r1.successes) + 1
    assert len(ctl.strategy.update_store) == 0


def test_straggler_beyond_next_round_stays_in_flight():
    """A very slow client's finish lands after round 1 closes: round 1
    must NOT aggregate it (in-flight), a later round does (or τ drops it)."""
    profiles = {"slow": ClientProfile(slow_factor=10.0)}   # 2+100 = 102 s
    ctl = _controller(["a", "b", "slow"], profiles=profiles,
                      round_timeout_s=30.0)
    params = {"w": jnp.zeros(4)}
    params, r0 = ctl.run_round(params, 0)
    assert r0.late == ["slow"]
    params, r1 = ctl.run_round(params, 1)
    assert r1.straggler_arrivals == []
    assert r1.aggregated_updates == len(r1.successes)
    # rounds 0+1 span ≤ 60s; the slow finish (≈102 s) arrives later
    params, r2 = ctl.run_round(params, 2)
    params, r3 = ctl.run_round(params, 3)
    arrivals = r2.straggler_arrivals + r3.straggler_arrivals
    assert arrivals == ["slow"]


# ---------------------------------------------------------------- executor
def test_vectorized_executor_matches_per_client_loop():
    from repro.data import make_image_classification
    from repro.data.synthetic import ArrayDataset
    from repro.fl.client import ClientPool
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import make_cnn

    full = make_image_classification(130, image_size=14, n_classes=3, seed=0)
    # unequal shard sizes: 40/40 share one vmap group, 50 its own;
    # 40 % 16 != 0 exercises the padded-batch mask path
    parts = {"c0": ArrayDataset(full.x[:40], full.y[:40]),
             "c1": ArrayDataset(full.x[40:80], full.y[40:80]),
             "c2": ArrayDataset(full.x[80:], full.y[80:])}
    task = ClassificationTask(make_cnn(14, 1, 3, 16),
                              TaskConfig(epochs=2, batch_size=16))
    pool = ClientPool(task, parts, proximal_mu=0.001, seed=3)
    params = task.init_params(0)

    vec = pool.batch_work_fn(list(parts), params, round_number=1)
    for cid in parts:
        ref_update, ref_nominal = pool.work_fn(cid, params, 1)
        vec_update, vec_nominal = vec[cid]
        assert vec_nominal == pytest.approx(ref_nominal)
        assert vec_update.num_samples == ref_update.num_samples
        ref_leaves = jnp.concatenate(
            [l.ravel() for l in jax.tree_util.tree_leaves(ref_update.params)])
        vec_leaves = jnp.concatenate(
            [l.ravel() for l in jax.tree_util.tree_leaves(vec_update.params)])
        np.testing.assert_allclose(np.asarray(vec_leaves),
                                   np.asarray(ref_leaves),
                                   rtol=2e-4, atol=2e-5)


def test_vectorized_experiment_matches_eager():
    """End-to-end: the same experiment with vectorized client execution
    produces the same round outcomes and learning as the eager loop."""
    from repro.data import label_sorted_shards, make_image_classification
    from repro.data.synthetic import ArrayDataset
    from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                     run_experiment)
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import make_cnn

    full = make_image_classification(700, image_size=14, n_classes=4, seed=0)
    train = ArrayDataset(full.x[:600], full.y[:600])
    test = ArrayDataset(full.x[600:], full.y[600:])
    parts = label_sorted_shards(train, 8, 2, seed=0)
    test_parts = label_sorted_shards(test, 8, 2, seed=0)
    task = ClassificationTask(make_cnn(14, 1, 4, 16),
                              TaskConfig(epochs=1, batch_size=32,
                                         per_sample_time_s=0.05))

    results = {}
    for vec in (True, False):
        cfg = ExperimentConfig(strategy="fedlesscan", n_rounds=3,
                               clients_per_round=4, eval_every=0, seed=0,
                               vectorized=vec,
                               scenario=ScenarioConfig(
                                   straggler_fraction=0.25,
                                   round_timeout_s=30.0, seed=0))
        results[vec] = run_experiment(task, parts, test_parts, cfg)
    for rv, re_ in zip(results[True].rounds, results[False].rounds):
        assert rv.successes == re_.successes
        assert rv.duration_s == pytest.approx(re_.duration_s)
    assert results[True].final_accuracy == pytest.approx(
        results[False].final_accuracy, abs=0.05)


# ---------------------------------------------------------------- fleet
def test_fleet_round_robin_routing_is_sticky_and_balanced():
    fleet = PlatformFleet.from_profiles(
        routing=RoutingPolicy(["gcf-gen2", "aws-lambda", "openfaas"],
                              mode="round-robin"))
    names = [fleet.name_of(f"c{i}") for i in range(6)]
    assert names == ["gcf-gen2", "aws-lambda", "openfaas"] * 2
    # sticky: a second lookup routes identically
    assert fleet.name_of("c0") == "gcf-gen2"
    clocks = {id(p.clock) for p in fleet.platforms.values()}
    assert len(clocks) == 1


def test_fleet_outage_fails_invocations_and_recovers():
    fleet = PlatformFleet.from_profiles()
    fleet.set_platform_down("aws-lambda")
    p = fleet.platforms["aws-lambda"]
    out = p.invoke("c", 1.0, 0.0)
    assert out.crashed
    fleet.set_platform_down("aws-lambda", down=False)
    assert p.config.failure_rate < 1.0
