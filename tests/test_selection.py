"""Unit tests: Algorithm 2 client selection."""
import numpy as np

from repro.core import ClientHistoryDB, select_clients


def _db_with(n_rookies=0, n_participants=0, n_stragglers=0, rounds=5):
    db = ClientHistoryDB()
    ids = []
    for i in range(n_rookies):
        cid = f"rook{i}"
        db.ensure([cid])
        ids.append(cid)
    for i in range(n_participants):
        cid = f"part{i}"
        for r in range(rounds):
            db.mark_success(cid, r)
            db.client_report(cid, r, 10.0 + i)
        ids.append(cid)
    for i in range(n_stragglers):
        cid = f"strag{i}"
        db.mark_miss(cid, rounds - 1)
        ids.append(cid)
    return db, ids


def test_rookies_first():
    db, ids = _db_with(n_rookies=20, n_participants=5)
    plan = select_clients(db, ids, 1, 50, 8, np.random.default_rng(0))
    assert len(plan.selected) == 8
    assert all(c.startswith("rook") for c in plan.selected)


def test_stragglers_only_when_needed():
    db, ids = _db_with(n_participants=10, n_stragglers=5)
    rng = np.random.default_rng(0)
    plan = select_clients(db, ids, 6, 50, 8, rng)
    # 10 participants cover the demand: no stragglers selected
    assert not any(c.startswith("strag") for c in plan.selected)
    plan2 = select_clients(db, ids, 6, 50, 13, rng)
    # now 3 stragglers are required to fill the round
    assert sum(c.startswith("strag") for c in plan2.selected) == 3


def test_selection_size_and_uniqueness():
    db, ids = _db_with(n_rookies=3, n_participants=9, n_stragglers=4)
    for rnd in (1, 10, 49):
        plan = select_clients(db, ids, rnd, 50, 10,
                              np.random.default_rng(rnd))
        assert len(plan.selected) == 10
        assert len(set(plan.selected)) == 10
        assert set(plan.selected) <= set(ids)


def test_selection_caps_at_population():
    db, ids = _db_with(n_participants=4)
    plan = select_clients(db, ids, 2, 50, 10, np.random.default_rng(0))
    assert sorted(plan.selected) == sorted(ids)


def test_least_invoked_preferred_within_cluster():
    """Paper §VI-B: FedLesScan prioritises clients with the fewest
    invocations inside a selected cluster."""
    db = ClientHistoryDB()
    ids = [f"c{i}" for i in range(6)]
    for r in range(4):
        for cid in ids:
            db.mark_success(cid, r)
            db.client_report(cid, r, 10.0)     # identical behaviour
    # give c0..c2 extra invocations
    for cid in ids[:3]:
        db.get(cid).invocations += 5
    plan = select_clients(db, ids, 5, 50, 3, np.random.default_rng(0))
    assert sorted(plan.selected) == ["c3", "c4", "c5"]
