"""Unit tests: Algorithm 2 client selection."""
import numpy as np

from repro.core import ClientHistoryDB, select_clients


def _db_with(n_rookies=0, n_participants=0, n_stragglers=0, rounds=5):
    db = ClientHistoryDB()
    ids = []
    for i in range(n_rookies):
        cid = f"rook{i}"
        db.ensure([cid])
        ids.append(cid)
    for i in range(n_participants):
        cid = f"part{i}"
        for r in range(rounds):
            db.mark_success(cid, r)
            db.client_report(cid, r, 10.0 + i)
        ids.append(cid)
    for i in range(n_stragglers):
        cid = f"strag{i}"
        db.mark_miss(cid, rounds - 1)
        ids.append(cid)
    return db, ids


def test_rookies_first():
    db, ids = _db_with(n_rookies=20, n_participants=5)
    plan = select_clients(db, ids, 1, 50, 8, np.random.default_rng(0))
    assert len(plan.selected) == 8
    assert all(c.startswith("rook") for c in plan.selected)


def test_stragglers_only_when_needed():
    db, ids = _db_with(n_participants=10, n_stragglers=5)
    rng = np.random.default_rng(0)
    plan = select_clients(db, ids, 6, 50, 8, rng)
    # 10 participants cover the demand: no stragglers selected
    assert not any(c.startswith("strag") for c in plan.selected)
    plan2 = select_clients(db, ids, 6, 50, 13, rng)
    # now 3 stragglers are required to fill the round
    assert sum(c.startswith("strag") for c in plan2.selected) == 3


def test_selection_size_and_uniqueness():
    db, ids = _db_with(n_rookies=3, n_participants=9, n_stragglers=4)
    for rnd in (1, 10, 49):
        plan = select_clients(db, ids, rnd, 50, 10,
                              np.random.default_rng(rnd))
        assert len(plan.selected) == 10
        assert len(set(plan.selected)) == 10
        assert set(plan.selected) <= set(ids)


def test_selection_caps_at_population():
    db, ids = _db_with(n_participants=4)
    plan = select_clients(db, ids, 2, 50, 10, np.random.default_rng(0))
    assert sorted(plan.selected) == sorted(ids)


def test_all_rookie_pool_smaller_than_cohort():
    """Edge case: every client is a rookie and the cohort wants more
    than the pool holds — everyone is selected, once."""
    db, ids = _db_with(n_rookies=4)
    plan = select_clients(db, ids, 0, 50, 10, np.random.default_rng(0))
    assert sorted(plan.selected) == sorted(ids)
    assert sorted(plan.rookies) == sorted(ids)
    assert plan.cluster_clients == [] and plan.straggler_clients == []


def test_cohort_exceeds_mixed_tier_population():
    """clients_per_round > len(pool) with all three tiers present: the
    whole population is selected exactly once, tier priority intact."""
    db, ids = _db_with(n_rookies=2, n_participants=3, n_stragglers=2)
    plan = select_clients(db, ids, 6, 50, 20, np.random.default_rng(1))
    assert sorted(plan.selected) == sorted(ids)
    assert len(set(plan.selected)) == len(ids)
    assert len(plan.rookies) == 2
    assert len(plan.cluster_clients) == 3
    assert len(plan.straggler_clients) == 2


def test_empty_participant_tier_falls_through_to_stragglers():
    """No participants at all: after the rookies, demand is met from
    the straggler tier without entering the clustering path."""
    db, ids = _db_with(n_rookies=2, n_stragglers=6)
    plan = select_clients(db, ids, 6, 50, 5, np.random.default_rng(0))
    assert len(plan.selected) == 5
    assert len(plan.rookies) == 2
    assert plan.cluster_clients == []        # nothing to cluster
    assert len(plan.straggler_clients) == 3
    assert plan.n_clusters == 0


def test_single_participant_cluster_path():
    """One participant forces the single-client clustering branch (CH
    undefined) inside Algorithm 2 — it must still be selectable."""
    db, ids = _db_with(n_rookies=1, n_participants=1)
    plan = select_clients(db, ids, 3, 50, 2, np.random.default_rng(0))
    assert sorted(plan.selected) == sorted(ids)
    assert plan.cluster_clients == ["part0"]
    assert plan.n_clusters <= 1


def test_least_invoked_preferred_within_cluster():
    """Paper §VI-B: FedLesScan prioritises clients with the fewest
    invocations inside a selected cluster."""
    db = ClientHistoryDB()
    ids = [f"c{i}" for i in range(6)]
    for r in range(4):
        for cid in ids:
            db.mark_success(cid, r)
            db.client_report(cid, r, 10.0)     # identical behaviour
    # give c0..c2 extra invocations
    for cid in ids[:3]:
        db.get(cid).invocations += 5
    plan = select_clients(db, ids, 5, 50, 3, np.random.default_rng(0))
    assert sorted(plan.selected) == ["c3", "c4", "c5"]
