"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (ClientHistoryDB, ClientRecord, ClientUpdate, ema,
                        missed_round_ema, select_clients,
                        staleness_aggregate, staleness_coefficients)
from repro.core.clustering import dbscan
from repro.faas.cost import FunctionShape, invocation_cost

SETTINGS = dict(max_examples=40, deadline=None)


# ------------------------------------------------------------- Eq. 1
@given(st.lists(st.booleans(), min_size=1, max_size=30))
@settings(**SETTINGS)
def test_cooldown_invariants(events):
    """cooldown is 0 after success; after k consecutive misses it is
    2^(k-1); it never goes negative."""
    rec = ClientRecord("c")
    consecutive = 0
    for rnd, missed in enumerate(events):
        if missed:
            rec.apply_miss(rnd)
            consecutive += 1
            assert rec.cooldown == 2 ** (consecutive - 1)
        else:
            rec.apply_success()
            consecutive = 0
            assert rec.cooldown == 0
        assert rec.cooldown >= 0


# ------------------------------------------------------------- EMA
@given(st.lists(st.floats(0.1, 1000.0), min_size=1, max_size=50),
       st.floats(0.05, 0.95))
@settings(**SETTINGS)
def test_ema_bounded_by_extremes(values, alpha):
    e = ema(values, alpha)
    assert min(values) - 1e-6 <= e <= max(values) + 1e-6


@given(st.lists(st.integers(0, 30), min_size=0, max_size=10, unique=True),
       st.integers(31, 100))
@settings(**SETTINGS)
def test_missed_round_ema_in_unit_interval(missed, current):
    rec = ClientRecord("c", missed_rounds=list(missed))
    v = missed_round_ema(rec, current)
    assert 0.0 <= v <= 1.0


@given(st.integers(0, 25), st.integers(40, 200))
@settings(**SETTINGS)
def test_missed_round_penalty_decays(m, later):
    """The same missed round weighs less as training progresses."""
    rec = ClientRecord("c", missed_rounds=[m])
    assert (missed_round_ema(rec, later)
            <= missed_round_ema(rec, m + 1) + 1e-9)


# ------------------------------------------------------------- Eq. 3
@given(st.lists(
    st.tuples(st.floats(-5, 5), st.integers(1, 500), st.integers(0, 10)),
    min_size=1, max_size=8),
    st.integers(10, 20), st.integers(1, 5))
@settings(**SETTINGS)
def test_staleness_coefficients_simplex_like(specs, current, tau):
    ups = [ClientUpdate(f"c{i}", {"w": jnp.full((3,), v)}, n, current - age)
           for i, (v, n, age) in enumerate(specs)]
    fresh = [u for u in ups if current - u.round_number < tau]
    if not fresh:
        assert staleness_aggregate(ups, current, tau) is None
        return
    coeffs = staleness_coefficients(fresh, current)
    assert np.all(coeffs >= 0)
    assert coeffs.sum() <= 1.0 + 1e-9
    agg = staleness_aggregate(ups, current, tau)
    vals = np.array([float(u.params["w"][0]) for u in fresh])
    lo = min(0.0, vals.min()) - 1e-6
    hi = max(0.0, vals.max()) + 1e-6
    assert lo <= float(agg["w"][0]) <= hi   # sub-convex combination


# ------------------------------------------------------------- Alg. 2
@given(st.integers(0, 10), st.integers(0, 10), st.integers(0, 10),
       st.integers(1, 12), st.integers(1, 40))
@settings(**SETTINGS)
def test_selection_invariants(nr, np_, ns, per_round, rnd):
    db = ClientHistoryDB()
    ids = []
    for i in range(nr):
        db.ensure([f"r{i}"]); ids.append(f"r{i}")
    for i in range(np_):
        cid = f"p{i}"
        db.mark_success(cid, 0)
        db.client_report(cid, 0, 5.0 + i)
        ids.append(cid)
    for i in range(ns):
        cid = f"s{i}"
        db.mark_miss(cid, 0)
        ids.append(cid)
    if not ids:
        return
    plan = select_clients(db, ids, rnd, 50, per_round,
                          np.random.default_rng(rnd))
    assert len(plan.selected) == min(per_round, len(ids))
    assert len(set(plan.selected)) == len(plan.selected)
    assert set(plan.selected) <= set(ids)
    # stragglers appear only if rookies+participants can't fill the round
    if nr + np_ >= per_round:
        assert not any(c.startswith("s") for c in plan.selected)


# ------------------------------------------------------------- DBSCAN
@given(st.integers(2, 25), st.floats(0.05, 5.0), st.integers(2, 4),
       st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_dbscan_label_invariants(n, eps, min_samples, seed):
    x = np.random.default_rng(seed).normal(size=(n, 2))
    labels = dbscan(x, eps, min_samples)
    assert labels.shape == (n,)
    uniq = set(labels.tolist()) - {-1}
    if uniq:
        assert uniq == set(range(len(uniq)))   # contiguous cluster ids
    # every non-noise cluster has at least min_samples members (core+border
    # can be smaller only if border points were claimed by another cluster;
    # with our BFS a cluster always contains its core point's neighbourhood)
    for lab in uniq:
        assert (labels == lab).sum() >= 1


# ------------------------------------------------------------- cost
@given(st.floats(0.01, 5000.0), st.integers(128, 16384))
@settings(**SETTINGS)
def test_cost_monotone_in_duration_and_memory(dur, mem):
    shape = FunctionShape(memory_mb=mem)
    c1 = invocation_cost(dur, shape)
    c2 = invocation_cost(dur * 2, shape)
    c3 = invocation_cost(dur, FunctionShape(memory_mb=mem * 2))
    assert c2 >= c1 > 0
    assert c3 >= c1
