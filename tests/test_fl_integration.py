"""Integration: end-to-end FL experiments on the simulated platform.

These are the system-level behaviour tests: FedLesScan must beat the
random-selection baselines on EUR / duration under stragglers (paper
Tables II-IV directionally), and the model must actually learn.
"""
import numpy as np
import pytest

from repro.data import label_sorted_shards, make_image_classification
from repro.data.synthetic import ArrayDataset
from repro.fl.experiment import (ExperimentConfig, ScenarioConfig,
                                 run_experiment)
from repro.fl.tasks import ClassificationTask, TaskConfig
from repro.models.small import make_cnn


@pytest.fixture(scope="module")
def setup():
    full = make_image_classification(2400, image_size=14, n_classes=5,
                                     seed=0)
    train = ArrayDataset(full.x[:2000], full.y[:2000])
    test = ArrayDataset(full.x[2000:], full.y[2000:])
    parts = label_sorted_shards(train, 20, 2, seed=0)
    test_parts = label_sorted_shards(test, 20, 2, seed=0)
    model = make_cnn(14, 1, 5, 32, "tiny")
    task = ClassificationTask(
        model, TaskConfig(epochs=1, batch_size=32, per_sample_time_s=0.05))
    return task, parts, test_parts


def _run(setup, strategy, straggler_fraction, n_rounds=6, seed=0):
    task, parts, test_parts = setup
    cfg = ExperimentConfig(
        strategy=strategy, n_rounds=n_rounds, clients_per_round=5,
        eval_every=0, seed=seed,
        scenario=ScenarioConfig(straggler_fraction=straggler_fraction,
                                round_timeout_s=30.0, seed=seed))
    return run_experiment(task, parts, test_parts, cfg)


def test_standard_scenario_learns(setup):
    res = _run(setup, "fedavg", 0.0, n_rounds=8)
    assert res.final_accuracy > 0.5          # well above 0.2 chance
    assert res.mean_eur > 0.9                # healthy clients succeed


def test_fedlesscan_improves_eur_under_stragglers(setup):
    base = _run(setup, "fedavg", 0.3)
    ours = _run(setup, "fedlesscan", 0.3)
    assert ours.mean_eur > base.mean_eur


def test_fedlesscan_cheaper_and_faster_under_stragglers(setup):
    base = _run(setup, "fedavg", 0.3)
    ours = _run(setup, "fedlesscan", 0.3)
    assert ours.total_cost < base.total_cost
    assert ours.total_duration_s <= base.total_duration_s + 1e-6


def test_fedprox_runs_with_proximal_term(setup):
    res = _run(setup, "fedprox", 0.1, n_rounds=4)
    assert res.final_accuracy > 0.3
    assert res.strategy == "fedprox"


def test_selection_counts_are_respected(setup):
    res = _run(setup, "fedlesscan", 0.5, n_rounds=5)
    for r in res.rounds:
        assert len(r.selected) == 5
        assert len(r.successes) + len(r.late) + len(r.crashed) == 5


def test_history_drives_adaptation(setup):
    """After a few rounds, crashing clients should be selected less often
    than reliable ones (paper Fig. 3c: bias toward reliable clients)."""
    task, parts, test_parts = setup
    cfg = ExperimentConfig(
        strategy="fedlesscan", n_rounds=12, clients_per_round=8,
        eval_every=0, seed=1,
        scenario=ScenarioConfig(straggler_fraction=0.4, slow_share=0.0,
                                round_timeout_s=30.0, seed=1))
    res = run_experiment(task, parts, test_parts, cfg)
    counts = res.invocation_counts()
    from repro.fl.experiment import make_straggler_profiles
    profiles = make_straggler_profiles(sorted(parts), cfg.scenario)
    crashed_ids = {cid for cid, p in profiles.items() if p.crash}
    ok_ids = set(parts) - crashed_ids
    mean_crashed = np.mean([counts.get(c, 0) for c in crashed_ids])
    mean_ok = np.mean([counts.get(c, 0) for c in ok_ids])
    assert mean_ok > mean_crashed


def test_safa_tradeoff(setup):
    """SAFA (paper §III-B): fastest rounds (k-th-fastest quorum) but far
    more invocations and higher cost than FedLesScan — the trade-off the
    paper criticises."""
    safa = _run(setup, "safa", 0.3)
    ours = _run(setup, "fedlesscan", 0.3)
    assert safa.total_duration_s < ours.total_duration_s
    safa_inv = sum(safa.invocation_counts().values())
    ours_inv = sum(ours.invocation_counts().values())
    assert safa_inv > 2 * ours_inv
    assert safa.total_cost > ours.total_cost
