"""Unit tests: client history / cooldown (paper Eq. 1, Alg. 1)."""

from repro.core import ClientHistoryDB, ClientRecord


def test_cooldown_eq1_sequence():
    rec = ClientRecord("c")
    assert rec.cooldown == 0
    rec.apply_miss(2)
    assert rec.cooldown == 1            # first miss: 0 → 1
    rec.apply_miss(4)
    assert rec.cooldown == 2            # then ×2
    rec.apply_miss(5)
    assert rec.cooldown == 4
    rec.apply_success()
    assert rec.cooldown == 0            # completed in time → 0


def test_tier_partition():
    db = ClientHistoryDB()
    db.ensure(["rookie", "part", "strag"])
    db.mark_success("part", 0)
    db.client_report("part", 0, 5.0)
    db.mark_miss("strag", 0)
    rookies, participants, stragglers = db.partition(
        ["rookie", "part", "strag"])
    assert [r.client_id for r in rookies] == ["rookie"]
    assert [p.client_id for p in participants] == ["part"]
    assert [s.client_id for s in stragglers] == ["strag"]


def test_slow_client_corrects_missed_round():
    """Alg. 1 lines 24-26: distinguishing slow from crashed happens on the
    client side, by deleting the current round from missed rounds."""
    db = ClientHistoryDB()
    db.mark_miss("c", 3)                 # controller assumed crash
    assert 3 in db.get("c").missed_rounds
    db.client_report("c", 3, 42.0)       # client finished late
    rec = db.get("c")
    assert 3 not in rec.missed_rounds
    assert rec.training_times == [42.0]
    # cooldown is a controller-side attribute and stays until a success
    assert rec.cooldown == 1


def test_persistence_roundtrip(tmp_path):
    db = ClientHistoryDB()
    db.mark_success("a", 0)
    db.client_report("a", 0, 1.5)
    db.mark_miss("b", 0)
    p = tmp_path / "hist.json"
    db.save(str(p))
    db2 = ClientHistoryDB(str(p))
    assert db2.get("a").training_times == [1.5]
    assert db2.get("b").cooldown == 1


def test_rookie_definition():
    db = ClientHistoryDB()
    rec = db.get("x")
    assert rec.is_rookie and not rec.is_participant and not rec.is_straggler
    db.mark_miss("x", 0)
    assert db.get("x").is_straggler      # behavioural data now exists
