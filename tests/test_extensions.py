"""Tests for the FedLess-faithful extensions: running-average aggregation,
multi-platform invocation, and the pretraining driver path."""
import jax.numpy as jnp
import numpy as np

from repro.core import (ClientUpdate, RunningAggregator,
                        staleness_aggregate)
from repro.faas import (PLATFORM_PROFILES, MultiPlatformInvoker,
                        make_platform)


def _upd(cid, value, n, rnd):
    return ClientUpdate(cid, {"w": jnp.full((8,), float(value))}, n, rnd)


# ---------------------------------------------------- running aggregation
def test_running_aggregator_equals_batch_eq3():
    ups = [_upd("a", 1.0, 10, 5), _upd("b", 3.0, 30, 4),
           _upd("c", -2.0, 5, 5), _upd("old", 9.0, 50, 2)]
    agg = RunningAggregator(current_round=5, tau=2)
    for u in ups:
        agg.add(u)
    got = agg.finalize()
    want = staleness_aggregate(ups, 5, tau=2)
    np.testing.assert_allclose(got["w"], want["w"], rtol=1e-6)
    assert agg.accepted == 3 and agg.rejected == 1


def test_running_aggregator_all_stale():
    agg = RunningAggregator(current_round=9, tau=2)
    assert not agg.add(_upd("x", 1.0, 10, 3))
    assert agg.finalize() is None


def test_running_aggregator_single_fresh_is_identity():
    agg = RunningAggregator(current_round=4, tau=2)
    agg.add(_upd("a", 7.5, 42, 4))
    np.testing.assert_allclose(agg.finalize()["w"], np.full(8, 7.5),
                               rtol=1e-6)


# ---------------------------------------------------- multi-platform
def test_platform_profiles_distinct():
    assert set(PLATFORM_PROFILES) == {"gcf-gen2", "aws-lambda", "openfaas"}
    lam = make_platform("aws-lambda", seed=0)
    ofs = make_platform("openfaas", seed=0)
    # provider cold-start characteristics differ (lambda ≪ openfaas)
    lam_cold = np.median([lam._cold_start_latency() for _ in range(200)])
    ofs_cold = np.median([ofs._cold_start_latency() for _ in range(200)])
    assert lam_cold < ofs_cold


def test_multi_platform_invoker_routes_and_shares_clock():
    calls = []

    def work_fn(cid, params, rnd):
        calls.append(cid)
        return ClientUpdate(cid, {"w": jnp.zeros(2)}, 10, rnd), 5.0

    inv = MultiPlatformInvoker(
        work_fn,
        assignment={"a": "aws-lambda", "b": "openfaas"},
        default="gcf-gen2", seed=0)
    res = inv.invoke_clients(["a", "b", "c"], {"w": jnp.zeros(2)}, 0, 0.0)
    assert len(res) == 3 and calls == ["a", "b", "c"]
    assert inv.platform_of("a") is inv.platforms["aws-lambda"]
    assert inv.platform_of("c") is inv.platforms["gcf-gen2"]
    # one shared virtual clock across providers
    clocks = {id(p.clock) for p in inv.platforms.values()}
    assert len(clocks) == 1


def test_multi_platform_end_to_end_round():
    """Controller runs unchanged on top of the multi-platform invoker."""
    from repro.core import ClientHistoryDB, StrategyConfig, make_strategy
    from repro.data import make_image_classification, partition_by_sizes
    from repro.data.partition import lognormal_sizes
    from repro.fl.client import ClientPool
    from repro.fl.controller import Controller
    from repro.fl.tasks import ClassificationTask, TaskConfig
    from repro.models.small import make_cnn

    ds = make_image_classification(400, 14, 4, seed=0)
    parts = partition_by_sizes(ds, lognormal_sizes(8, 50, seed=0), seed=0)
    task = ClassificationTask(make_cnn(14, 1, 4, 32),
                              TaskConfig(epochs=1, batch_size=32))
    history = ClientHistoryDB()
    history.ensure(parts.keys())
    strategy = make_strategy("fedlesscan",
                             StrategyConfig(clients_per_round=4,
                                            max_rounds=3), history)
    pool = ClientPool(task, parts)
    assignment = {cid: name for cid, name in
                  zip(sorted(parts), ["aws-lambda", "openfaas"] * 4)}
    inv = MultiPlatformInvoker(pool.work_fn, assignment, seed=0)
    ctl = Controller(strategy, inv, pool, history,
                     round_timeout_s=60.0, eval_every=0)
    params = task.init_params(0)
    for rnd in range(2):
        params, stats = ctl.run_round(params, rnd)
        assert len(stats.selected) == 4
