"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates a REDUCED variant of the same
family (≤1 pattern period of layers, d_model ≤ 256, ≤4 experts) and runs
one forward + one train step + one decode step on CPU, asserting output
shapes and no NaNs.  Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_configs, get_config, list_architectures
from repro.models import (decode_step, forward, init_cache, init_params,
                          make_train_step, prefill)
from repro.optim import make_optimizer

ARCHS = list_architectures()


def _batch(cfg, B=2, S=16, with_labels=True):
    shape = (B, cfg.n_codebooks, S) if cfg.n_codebooks else (B, S)
    batch = {"tokens": jnp.ones(shape, jnp.int32)}
    if with_labels:
        batch["labels"] = jnp.ones(shape, jnp.int32)
    if cfg.n_patches:
        batch["image_embeds"] = jnp.ones((B, cfg.n_patches, cfg.d_model),
                                         jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_constraints(arch):
    r = get_config(arch).reduced()
    assert r.d_model <= 512
    assert r.n_layers <= max(2, r.period)
    assert r.n_experts <= 4
    assert r.vocab <= 512


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = forward(cfg, params, batch)
    B, S = 2, 16
    v_out = cfg.vocab * max(1, cfg.n_codebooks)
    assert logits.shape == (B, S, v_out)
    assert not bool(jnp.isnan(logits).any())

    train_step, _ = make_train_step(cfg)
    opt = make_optimizer(cfg.optimizer, cfg.learning_rate)
    state = {"params": params, "opt": opt.init(params)}
    state2, loss = jax.jit(train_step)(state, batch)
    assert not bool(jnp.isnan(loss))
    # params must actually change
    changed = jax.tree_util.tree_map(
        lambda a, b: bool(jnp.any(a != b)), state["params"],
        state2["params"])
    assert any(jax.tree_util.tree_leaves(changed))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, ctx = 2, 16
    cache = init_cache(cfg, B, ctx, jnp.float32)
    tok = jnp.ones((B, cfg.n_codebooks, 1) if cfg.n_codebooks else (B, 1),
                   jnp.int32)
    logits, cache2 = decode_step(cfg, params, cache, tok,
                                 jnp.zeros((B,), jnp.int32))
    assert logits.shape[0] == B
    assert not bool(jnp.isnan(logits).any())
    # cache structure is preserved
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(cache2))


@pytest.mark.parametrize("arch", ["gemma2-2b", "zamba2-1.2b", "mamba2-130m",
                                  "chatglm3-6b", "musicgen-medium",
                                  "llama-3.2-vision-11b", "gemma3-1b",
                                  "internlm2-20b", "arctic-480b",
                                  "llama4-maverick-400b-a17b"])
def test_prefill_decode_consistency(arch):
    """prefill(S) + decode(S) must equal forward(S+1) at the last position —
    validates KV/ring/SSM/cross cache layouts end to end."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    key = jax.random.PRNGKey(1)
    shape = (B, cfg.n_codebooks, S + 1) if cfg.n_codebooks else (B, S + 1)
    tok_ext = jax.random.randint(key, shape, 0, cfg.vocab)
    batch_ext = {"tokens": tok_ext}
    batch = {"tokens": tok_ext[..., :S]}
    if cfg.n_patches:
        img = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.n_patches, cfg.d_model)) * 0.1
        batch["image_embeds"] = img
        batch_ext["image_embeds"] = img
    want = forward(cfg, params, batch_ext)[:, -1, :]
    _, cache = prefill(cfg, params, batch, cache_len=S + 1,
                       cache_dtype=jnp.float32)
    got, _ = decode_step(cfg, params, cache, tok_ext[..., -1:],
                         jnp.full((B,), S, jnp.int32))
    err = float(jnp.max(jnp.abs(got[:, 0, :] - want)))
    assert err < 1e-4, f"{arch}: {err}"


def test_long_context_variants():
    """Archs with long_500k support expose a sub-quadratic variant."""
    expected = {"zamba2-1.2b", "gemma2-2b", "gemma3-1b", "mamba2-130m"}
    supported = {a for a, c in all_configs().items()
                 if c.supports_long_context}
    assert supported == expected
    for a in expected:
        lc = get_config(a).long_context()
        assert all(k in ("local", "mamba", "shared_attn")
                   for k in lc.pattern)


def test_param_counts_match_assignment():
    """Analytic totals must land near the architecture names."""
    expect = {"llama4-maverick-400b-a17b": (360e9, 440e9),
              "arctic-480b": (430e9, 530e9),
              "internlm2-20b": (17e9, 23e9),
              "chatglm3-6b": (5e9, 8e9),
              "gemma2-2b": (2e9, 3.3e9),
              "mamba2-130m": (0.1e9, 0.16e9)}
    from repro.models.config import param_count
    for arch, (lo, hi) in expect.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B"
