"""Hypothesis property tests on the model zoo's structural invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import forward, init_params

SETTINGS = dict(max_examples=8, deadline=None)

_CFG = get_config("gemma2-2b").reduced()
_PARAMS = init_params(_CFG, jax.random.PRNGKey(0))
_MAMBA_CFG = get_config("mamba2-130m").reduced()
_MAMBA_PARAMS = init_params(_MAMBA_CFG, jax.random.PRNGKey(0))


@given(st.integers(0, 2 ** 31 - 1), st.integers(4, 20))
@settings(**SETTINGS)
def test_causality_attention(seed, split):
    """Changing tokens at positions ≥ t must not change logits < t."""
    key = jax.random.PRNGKey(seed)
    S = 24
    tok = jax.random.randint(key, (1, S), 0, _CFG.vocab)
    split = min(split, S - 1)
    tok2 = tok.at[:, split:].set((tok[:, split:] + 7) % _CFG.vocab)
    a = forward(_CFG, _PARAMS, {"tokens": tok})
    b = forward(_CFG, _PARAMS, {"tokens": tok2})
    np.testing.assert_allclose(a[:, :split], b[:, :split],
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2 ** 31 - 1), st.integers(4, 20))
@settings(**SETTINGS)
def test_causality_ssm(seed, split):
    """The SSM recurrence is causal by construction — verify end to end."""
    key = jax.random.PRNGKey(seed)
    S = 24
    tok = jax.random.randint(key, (1, S), 0, _MAMBA_CFG.vocab)
    split = min(split, S - 1)
    tok2 = tok.at[:, split:].set((tok[:, split:] + 3) % _MAMBA_CFG.vocab)
    a = forward(_MAMBA_CFG, _MAMBA_PARAMS, {"tokens": tok})
    b = forward(_MAMBA_CFG, _MAMBA_PARAMS, {"tokens": tok2})
    np.testing.assert_allclose(a[:, :split], b[:, :split],
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_batch_order_equivariance(seed):
    """Permuting sequences in the batch permutes logits identically."""
    key = jax.random.PRNGKey(seed)
    tok = jax.random.randint(key, (3, 16), 0, _CFG.vocab)
    perm = jnp.asarray([2, 0, 1])
    a = forward(_CFG, _PARAMS, {"tokens": tok})[perm]
    b = forward(_CFG, _PARAMS, {"tokens": tok[perm]})
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2 ** 31 - 1))
@settings(**SETTINGS)
def test_logits_finite(seed):
    key = jax.random.PRNGKey(seed)
    tok = jax.random.randint(key, (2, 16), 0, _CFG.vocab)
    out = forward(_CFG, _PARAMS, {"tokens": tok})
    assert bool(jnp.isfinite(out).all())
