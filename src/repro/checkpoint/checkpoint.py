"""Pytree checkpointing (npz-based; no orbax in this environment).

Flattens a pytree of arrays to path-keyed npz entries plus a JSON treedef
descriptor; restores exactly.  Used by the FL parameter server (round-
tagged global models) and the pretraining driver.
"""
from __future__ import annotations

import re
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any
_SEP = "|"


def _flatten_with_paths(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return f"#{entry.idx}"
    return str(entry)


def save_pytree(tree: Pytree, path: str) -> None:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    np.savez(p, **flat)


def load_pytree(path: str, like: Pytree) -> Pytree:
    """Restore into the structure of `like` (shape/dtype-checked)."""
    data = np.load(path, allow_pickle=False)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, leaf in flat_like:
        key = _SEP.join(_path_str(p) for p in kp)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Step-tagged checkpoints with retention. Files: <dir>/step_%08d.npz"""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    def save(self, tree: Pytree, step: int) -> Path:
        path = self.dir / f"step_{step:08d}.npz"
        save_pytree(tree, str(path))
        self._gc()
        return path

    def latest_step(self) -> Optional[int]:
        steps = sorted(self.steps())
        return steps[-1] if steps else None

    def steps(self):
        out = []
        for f in self.dir.glob("step_*.npz"):
            m = re.match(r"step_(\d+)\.npz", f.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, like: Pytree, step: Optional[int] = None) -> Pytree:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return load_pytree(str(self.dir / f"step_{step:08d}.npz"), like)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            (self.dir / f"step_{s:08d}.npz").unlink(missing_ok=True)
