"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens,
4 codebooks with summed embeddings and per-codebook output heads
[arXiv:2306.05284].  The EnCodec frontend is a stub: input_specs provides
codebook token ids directly (DESIGN.md carve-out)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", arch_type="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab=2048,
    pattern=("attn",),
    n_codebooks=4,
    tie_embeddings=True,        # logits via codebook embeddings
    source="arXiv:2306.05284",
)
