"""Architecture registry.

Each assigned architecture lives in ``src/repro/configs/<id>.py`` (file
named exactly after the assignment id, loaded via importlib since ids
contain dashes/dots) and defines a module-level ``CONFIG: ArchConfig``.
"""
from __future__ import annotations

import importlib.util
from pathlib import Path
from typing import Dict, List

from ..models.config import ArchConfig

_DIR = Path(__file__).parent
_SKIP = {"__init__.py", "registry.py", "shapes.py"}


def _load_file(path: Path) -> ArchConfig:
    spec = importlib.util.spec_from_file_location(
        "repro_config_" + path.stem.replace("-", "_").replace(".", "_"), path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.CONFIG


def list_architectures() -> List[str]:
    return sorted(p.stem for p in _DIR.glob("*.py") if p.name not in _SKIP)


def get_config(arch_id: str) -> ArchConfig:
    path = _DIR / f"{arch_id}.py"
    if not path.exists():
        raise KeyError(f"unknown architecture {arch_id!r}; "
                       f"available: {list_architectures()}")
    return _load_file(path)


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in list_architectures()}
