"""internlm2-20b [dense] — plain GQA decoder [arXiv:2403.17297]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", arch_type="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92544,
    pattern=("attn",),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="arXiv:2403.17297",
)
