"""gemma2-2b [dense] — alternating local(4096)/global attention with
attn/final logit soft-capping (50/30) [arXiv:2408.00118]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b", arch_type="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
    d_ff=9216, vocab=256000, head_dim=256,
    pattern=("local", "attn"),
    window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    long_context_window=4096,
    act="gelu", tie_embeddings=True,
    source="arXiv:2408.00118",
)
