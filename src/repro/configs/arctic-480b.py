"""arctic-480b [moe] — 128-expert top-2 MoE in parallel with a dense
residual FFN (Arctic dense-MoE hybrid) [hf:Snowflake/snowflake-arctic-base]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", arch_type="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    pattern=("attn",),
    n_experts=128, top_k=2, parallel_dense_mlp=True,
    tie_embeddings=False,
    source="hf:Snowflake/snowflake-arctic-base",
)
