"""chatglm3-6b [dense] — GQA kv=2, 2d RoPE (rotary on half the head dim)
[arXiv:2406.12793]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", arch_type="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=65024,
    pattern=("attn",),
    rope_fraction=0.5,
    tie_embeddings=False,
    source="arXiv:2406.12793",
)
