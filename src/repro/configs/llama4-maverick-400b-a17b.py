"""llama4-maverick-400b-a17b [moe] — 128-expert top-1 MoE with a parallel
shared expert on alternating layers (interleaved dense/MoE, Llama-4
design), early fusion [hf:meta-llama/Llama-4-Scout-17B-16E].
24 MoE layers × 128 experts + 24 dense layers ≈ 400B total / 17B active."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", arch_type="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048,
    pattern=("attn", "attn"),
    moe_pattern=(False, True),
    n_experts=128, top_k=1, parallel_dense_mlp=True,
    rope_theta=500_000.0,
    tie_embeddings=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
