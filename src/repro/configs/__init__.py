from .registry import all_configs, get_config, list_architectures
from .shapes import INPUT_SHAPES, InputShape

__all__ = ["all_configs", "get_config", "list_architectures",
           "INPUT_SHAPES", "InputShape"]
