"""mamba2-130m [ssm] — pure SSD (state-space duality), attention-free
[arXiv:2405.21060].  d_inner=1536, 24 SSD heads of dim 64, state 128."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", arch_type="ssm",
    n_layers=24, d_model=768, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    pattern=("mamba",),
    ssm_state=128,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
