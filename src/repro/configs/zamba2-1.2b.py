"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared full-attention block
applied every 6 SSM layers (weight-tied, Zamba design) [arXiv:2411.15242].
38 Mamba2 layers; 6 shared-attn injections (38//6) + 2 trailing SSM layers.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", arch_type="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "mamba",
             "shared_attn"),
    ssm_state=64,
    long_context_window=4096,   # shared attn switches to window at 500k
    tie_embeddings=True,
    source="arXiv:2411.15242",
)
