"""llama-3.2-vision-11b [vlm] — 40-layer text decoder with gated
cross-attention blocks every 5th layer [hf:meta-llama/Llama-3.2-11B-Vision].
The ViT vision encoder + projector is a stub: input_specs provides
precomputed patch embeddings (B, 1024, d_model) (DESIGN.md carve-out)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", arch_type="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    pattern=("attn", "attn", "attn", "attn", "cross"),
    rope_theta=500_000.0,
    n_patches=1024,
    tie_embeddings=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
