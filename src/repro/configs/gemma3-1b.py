"""gemma3-1b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt].  26 layers arranged as two scanned superblocks
of 13 (11 local + 2 global each ⇒ 22L/4G total, matching the 5:1 layout
with globals at depth 6/12/19/25).  head_dim 256; local window 512."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", arch_type="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab=262144, head_dim=256,
    pattern=("local", "local", "local", "local", "local", "attn",
             "local", "local", "local", "local", "local", "attn", "local"),
    window=512, rope_theta=1_000_000.0,
    long_context_window=4096,
    act="gelu", tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
