from .flash_decode import reference_decode_attention, sharded_decode_attention
from .rules import (DEFAULT_OPTIONS, ShardingOptions, batch_specs,
                    cache_specs, data_axes, logits_spec, opt_specs,
                    param_spec_for, param_specs, to_named)

__all__ = ["DEFAULT_OPTIONS", "ShardingOptions", "batch_specs",
           "cache_specs", "data_axes", "logits_spec", "opt_specs",
           "param_spec_for", "param_specs", "to_named",
           "reference_decode_attention", "sharded_decode_attention"]
