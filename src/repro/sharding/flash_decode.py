"""Flash-decoding over a sequence-sharded KV cache (beyond-paper §Perf).

At decode time the KV cache dominates memory; sharding its *sequence* dim
over the `model` axis divides it 16-way, but naive jnp attention then
forces XLA to all-gather the cache every step.  This module computes
attention WITHOUT gathering: each shard produces a partial softmax
(local max, local sum-exp, local weighted values) over its KV slice and
the shards combine with two tiny collectives (pmax + psum of (B,H,hd)) —
the TPU analogue of flash-decoding / paged attention.

Wire cost per step: psum of o_partial (B,H,hd) + scalars, vs all-gather
of the cache (B,K,S,hd) — a ~S/hd reduction in collective bytes.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

NEG = -1e30


def _partial_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       valid: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Local partial softmax over this shard's KV slice.

    q: (B, K, G, hd); k/v: (B, K, S_loc, hd); valid: (B, S_loc) bool.
    Returns (o_partial (B,K,G,hd) — exp-weighted values, m (B,K,G),
    l (B,K,G) — local sum-exp)."""
    hd = q.shape[-1]
    s = jnp.einsum("bkgh,bksh->bkgs", q, k) / jnp.sqrt(hd).astype(q.dtype)
    s = jnp.where(valid[:, None, None, :], s.astype(jnp.float32), NEG)
    m = jnp.max(s, axis=-1)                                   # (B,K,G)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bksh->bkgh", p.astype(v.dtype), v)
    return o.astype(jnp.float32), m, l


def sharded_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                             v_cache: jnp.ndarray, pos: jnp.ndarray,
                             mesh: Mesh, seq_axis: str = "model",
                             batch_axis: Optional[str] = "data"
                             ) -> jnp.ndarray:
    """q: (B, H, hd); k/v_cache: (B, K, S, hd) with S sharded over
    `seq_axis`; pos: (B,) current positions.  → (B, H, hd).

    Each shard sees S/n contiguous slots; validity is computed from the
    global slot index (cache is linear layout: slot t ≤ pos is valid).
    """
    B, H, hd = q.shape
    K = k_cache.shape[1]
    S = k_cache.shape[2]
    G = H // K
    n_shards = mesh.shape[seq_axis]
    s_loc = S // n_shards

    baxis = batch_axis if (batch_axis in mesh.shape.keys()
                           and B % mesh.shape[batch_axis] == 0) else None
    qspec = P(baxis, None, None, None)
    cspec = P(baxis, None, seq_axis, None)
    pspec = P(baxis)

    @partial(shard_map, mesh=mesh,
             in_specs=(qspec, cspec, cspec, pspec),
             out_specs=P(baxis, None, None, None),
             check_rep=False)
    def body(qg, k, v, p_):
        shard = jax.lax.axis_index(seq_axis)
        base = shard * s_loc
        idx = base + jnp.arange(s_loc)
        valid = idx[None, :] <= p_[:, None]                    # (B_loc, s_loc)
        o, m, l = _partial_attention(qg, k, v, valid)
        # combine partial softmaxes across shards (flash-decoding merge)
        m_g = jax.lax.pmax(m, seq_axis)                        # (B,K,G)
        scale = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * scale, seq_axis)
        o_g = jax.lax.psum(o * scale[..., None], seq_axis)
        return (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(qg.dtype)

    qg = q.reshape(B, K, G, hd)
    out = body(qg, k_cache, v_cache, pos)
    return out.reshape(B, H, hd)


def reference_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                               v_cache: jnp.ndarray,
                               pos: jnp.ndarray) -> jnp.ndarray:
    """Unsharded oracle for the combine math."""
    B, H, hd = q.shape
    K = k_cache.shape[1]
    S = k_cache.shape[2]
    qg = q.reshape(B, K, H // K, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k_cache) / jnp.sqrt(hd)
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s.astype(jnp.float32), NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksh->bkgh", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, H, hd)
