"""Logical-axis sharding rules with divisibility fallback.

Strategy (FSDP + TP, MaxText-flavoured):
  * every weight gets a 'model' (tensor-parallel) dim — heads / ff /
    experts / vocab — picked from an ordered candidate list, skipping
    candidates whose size does not divide the mesh axis;
  * a second dim is sharded over the data axis (FSDP); in multi-pod mode
    the FSDP axis is ('pod','data') so parameters/optimizer state scale
    down with the full 512-chip fleet;
  * activations shard batch over ('pod','data') and model dims follow the
    weights;
  * decode KV caches shard the *sequence* dim over 'model' (the flash-
    decoding layout) and batch over data when divisible.

Everything is best-effort: a dim that doesn't divide falls through to the
next candidate or stays replicated — XLA SPMD remains correct either way,
and the roofline analysis (§Perf) is where bad choices get caught.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


Pytree = Any

# The declared mesh-axis vocabulary.  Every mesh this repo constructs
# (launch/mesh.py) names its axes from this tuple, and repro-lint's
# JAX004 rule flags shard_map / psum call sites whose *literal* axis
# names are not declared here — an undeclared axis is either a typo or
# a mesh the rest of the stack (merge_spec, cohort_spec, batch_specs)
# knows nothing about.
#   pod / data / model : the production FSDP+TP mesh (make_production_mesh)
#   clients            : the FL cohort (K) axis the vectorized executor
#                        shards local training over (fl/executor.py)
CLIENT_AXIS = "clients"
MESH_AXES: Tuple[str, ...] = ("pod", "data", "model", CLIENT_AXIS)


@dataclass(frozen=True)
class ShardingOptions:
    """Hillclimb knobs for the sharding strategy (§Perf variants).

    use_model_axis   : False → pure data parallelism; params are only
                       FSDP-sharded over the data axes (right for models
                       whose optimizer state fits per chip — e.g. a 130M
                       Mamba2 gains nothing from 16-way TP).
    attn_model       : False → attention projections are NOT model-sharded
                       (avoids hd-dim resharding ping-pong for archs with
                       few heads, e.g. gemma3's 4 q / 1 kv heads).
    batch_over_model : also shard the batch dim over 'model' (pure-DP mode
                       turns the whole mesh into one big data axis).
    """
    use_model_axis: bool = True
    attn_model: bool = True
    batch_over_model: bool = False
    # fully replicate parameters (pure DP for models that fit per chip —
    # avoids the FSDP-gather-vs-batch-axis conflict dp-only exposed)
    replicate_params: bool = False


DEFAULT_OPTIONS = ShardingOptions()


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The (outer) data-parallel axes: ('pod','data') when multi-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape.keys())


def merge_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes the flat server-merge shards over: ALL of them.

    The merge operates on a raveled (P,) view with no tensor structure
    left, so FSDP-vs-TP distinctions are moot — the P dim simply splits
    across every device (kernels/fed_agg.fed_agg_apply_sharded)."""
    return tuple(mesh.shape.keys())


def merge_spec(mesh: Mesh) -> P:
    """PartitionSpec for a flat (P,) merge vector on ``mesh``."""
    axes = merge_axes(mesh)
    if not axes:
        return P()
    return P(axes if len(axes) > 1 else axes[0])


def cohort_spec() -> P:
    """PartitionSpec splitting a leading cohort (K) dim over the
    ``clients`` axis — per-client training stacks, Adam states and the
    executor's (K, P) update matrix all shard with this prefix spec
    (fl/executor.py)."""
    return P(CLIENT_AXIS)


def _pick_spec(shape: Sequence[int], mesh: Mesh,
               model_cands: Sequence[int], data_cands: Sequence[int],
               model_axis: str = "model") -> P:
    """Assign 'model' to the first divisible candidate dim (negative
    indices from the end), then the FSDP axes to another dim."""
    spec: list = [None] * len(shape)
    msize = _axis_size(mesh, model_axis)
    for d in model_cands:
        i = d % len(shape)
        if shape[i] > 0 and shape[i] % msize == 0 and spec[i] is None:
            spec[i] = model_axis
            break
    daxes = data_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    for d in data_cands:
        i = d % len(shape)
        if shape[i] > 0 and shape[i] % dsize == 0 and spec[i] is None:
            spec[i] = daxes if len(daxes) > 1 else daxes[0]
            break
    return P(*spec)


def _path_names(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


# ------------------------------------------------------------------ params
def param_spec_for(path_names: Sequence[str], shape: Sequence[int],
                   mesh: Mesh,
                   opts: ShardingOptions = DEFAULT_OPTIONS) -> P:
    """Sharding for one parameter leaf, by name + context + shape."""
    name = path_names[-1]
    ctx = set(path_names)

    if name in ("ln1", "ln2", "lnx", "final_norm", "norm", "conv_b",
                "xgate", "A_log", "dt_bias", "D", "count"):
        return P()
    if opts.replicate_params:
        return P()
    if not opts.use_model_axis:
        # pure-DP / FSDP-only: shard a trailing dim over data (never the
        # leading stacked-layer dim — it is the scan axis)
        return _pick_spec(shape, mesh, model_cands=(),
                          data_cands=tuple(range(-1, -len(shape), -1))
                          or (-1,))
    if not opts.attn_model and name in ("wq", "wk", "wv", "wo"):
        return _pick_spec(
            shape, mesh, model_cands=(),
            data_cands=(-3,) if name != "wo" else (-1,))
    if name == "embed":
        return _pick_spec(shape, mesh, model_cands=(-2,), data_cands=(-1,))
    if name == "head":
        return _pick_spec(shape, mesh, model_cands=(-1,), data_cands=(-2,))
    if name == "router":
        return _pick_spec(shape, mesh, model_cands=(-1,), data_cands=(-2,))
    if name in ("wq", "wk", "wv"):          # (..., D, H, hd)
        return _pick_spec(shape, mesh, model_cands=(-2, -1),
                          data_cands=(-3,))
    if name == "wo":                         # (..., H, hd, D)
        return _pick_spec(shape, mesh, model_cands=(-3, -2),
                          data_cands=(-1,))
    if name in ("wg", "wu"):
        if "moe" in ctx:                     # (..., E, D, F)
            return _pick_spec(shape, mesh, model_cands=(-3,),
                              data_cands=(-1,))
        return _pick_spec(shape, mesh, model_cands=(-1,), data_cands=(-2,))
    if name == "wd":
        if "moe" in ctx:                     # (..., E, F, D)
            return _pick_spec(shape, mesh, model_cands=(-3,),
                              data_cands=(-2,))
        return _pick_spec(shape, mesh, model_cands=(-2,), data_cands=(-1,))
    if name == "in_proj":                    # (..., D, d_in_proj)
        return _pick_spec(shape, mesh, model_cands=(-1,), data_cands=(-2,))
    if name == "out_proj":                   # (..., d_inner, D)
        return _pick_spec(shape, mesh, model_cands=(-2,), data_cands=(-1,))
    if name == "conv_w":                     # (..., conv_dim, K)
        return _pick_spec(shape, mesh, model_cands=(-2,), data_cands=())
    # fallback: replicate
    return P()


def param_specs(tree: Pytree, mesh: Mesh,
                opts: ShardingOptions = DEFAULT_OPTIONS) -> Pytree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = [param_spec_for(_path_names(path), np.shape(leaf), mesh, opts)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(opt_state: Pytree, params_specs_tree: Pytree,
              mesh: Mesh,
              opts: ShardingOptions = DEFAULT_OPTIONS) -> Pytree:
    """Optimizer state mirrors param sharding (m/v); scalars replicate."""
    def one(path, leaf):
        names = _path_names(path)
        if names and names[0] in ("m", "v"):
            return param_spec_for(names[1:], np.shape(leaf), mesh, opts)
        return P()
    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_state)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


# ------------------------------------------------------------------ batch
def batch_specs(batch: Pytree, mesh: Mesh,
                opts: ShardingOptions = DEFAULT_OPTIONS) -> Pytree:
    """Shard batch dims over ('pod','data'); everything else replicated."""
    daxes = data_axes(mesh)
    if opts.batch_over_model:
        daxes = daxes + ("model",)

    def one(leaf):
        shape = np.shape(leaf)
        if not shape:
            return P()
        # largest prefix of the data axes that divides the batch dim
        # (e.g. batch 256 on a 512-chip pure-DP mesh shards 32-way over
        # ('pod','data') instead of falling back to full replication)
        axes = list(daxes)
        while axes and shape[0] % _axis_size(mesh, tuple(axes)) != 0:
            axes.pop()
        if not axes:
            return P(*([None] * len(shape)))
        dspec = tuple(axes) if len(axes) > 1 else axes[0]
        return P(dspec, *([None] * (len(shape) - 1)))
    return jax.tree_util.tree_map(one, batch)


# ------------------------------------------------------------------ cache
def cache_spec_for(path_names: Sequence[str], shape: Sequence[int],
                   mesh: Mesh) -> P:
    """Decode-cache sharding: KV seq over 'model' (flash-decode layout),
    batch over data when divisible; SSM states shard heads/P over model."""
    name = path_names[-1]
    daxes = data_axes(mesh)
    dsize = _axis_size(mesh, daxes)
    dspec = daxes if len(daxes) > 1 else daxes[0]
    msize = _axis_size(mesh, "model")
    spec: list = [None] * len(shape)

    if name in ("k", "v"):       # (L, B, K, S, hd) or (B, K, S, hd)
        b, s = len(shape) - 4, len(shape) - 2
        if shape[b] % dsize == 0:
            spec[b] = dspec
        if shape[s] % msize == 0:
            spec[s] = "model"
        return P(*spec)
    if name in ("ck", "cv"):     # (L, B, P, K, hd)
        b = len(shape) - 4
        if shape[b] % dsize == 0:
            spec[b] = dspec
        return P(*spec)
    if name == "conv":           # (L, B, K-1, conv_dim)
        b, c = len(shape) - 3, len(shape) - 1
        if shape[b] % dsize == 0:
            spec[b] = dspec
        if shape[c] % msize == 0:
            spec[c] = "model"
        return P(*spec)
    if name == "ssm":            # (L, B, H, P, N)
        b, h, p = len(shape) - 4, len(shape) - 3, len(shape) - 2
        if shape[b] % dsize == 0:
            spec[b] = dspec
        if shape[h] % msize == 0:
            spec[h] = "model"
        elif shape[p] % msize == 0:
            spec[p] = "model"
        return P(*spec)
    return P()


def cache_specs(cache: Pytree, mesh: Mesh,
                opts: ShardingOptions = DEFAULT_OPTIONS) -> Pytree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = [cache_spec_for(_path_names(path), np.shape(leaf), mesh)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ------------------------------------------------------------------ logits
def logits_spec(mesh: Mesh) -> P:
    daxes = data_axes(mesh)
    dspec = daxes if len(daxes) > 1 else daxes[0]
    return P(dspec, None, "model")


def to_named(tree_specs: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
