"""Simulated FaaS platform with a virtual clock.

Models the serverless characteristics the paper identifies as the reason
stragglers behave differently in FaaS (§II, §III-C):

  * cold starts — a function instance that is not warm pays a sampled
    cold-start latency before useful work begins;
  * scale-to-zero — warm instances expire after an idle timeout;
  * performance variation — each fresh instance lands on an unknown VM and
    gets a sampled speed factor (Wang et al. [29]);
  * weak reliability — invocations fail with (1 − SLO) probability
    (GCF SLO: 99.95% uptime);
  * function timeout — invocations are killed at the platform limit.

Everything runs on a virtual clock: `invoke()` returns the *would-be*
finish time instead of sleeping, so a full FL experiment with hundreds of
clients simulates in milliseconds while preserving the timing structure
the scheduling strategy reacts to.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from .cost import FunctionShape


@dataclass(frozen=True)
class FaaSConfig:
    cold_start_median_s: float = 3.0     # GCF gen-2 cold start, median
    cold_start_sigma: float = 0.5        # lognormal spread
    warm_idle_timeout_s: float = 900.0   # scale-to-zero after 15 min idle
    perf_variation: tuple = (0.85, 1.35) # per-instance speed multiplier
    failure_rate: float = 0.0005         # 1 − SLO(99.95%)
    network_jitter_s: float = 0.5        # invocation + result upload jitter
    function_timeout_s: float = 540.0    # platform kill limit (paper config)


@dataclass
class WarmInstance:
    speed_factor: float
    warm_until: float


@dataclass
class InvocationOutcome:
    client_id: str
    start_time: float
    cold_start_s: float
    compute_s: float            # scaled work time on the landed instance
    crashed: bool               # platform-level failure or timeout kill
    finish_time: float          # = start + cold + compute + jitter (inf if crashed)
    cold: bool

    @property
    def duration_s(self) -> float:
        """Billable duration (platform bills until kill on timeout)."""
        if self.crashed:
            return self.cold_start_s + self.compute_s
        return self.finish_time - self.start_time


@dataclass
class ClientProfile:
    """Per-client behaviour injected by the experiment scenario.

    `slow_factor` > 1 models resource heterogeneity (weak VM / big data);
    `crash` models the paper's failure-type stragglers (never respond).
    """
    slow_factor: float = 1.0
    crash: bool = False


class VirtualClock:
    def __init__(self):
        self.now = 0.0

    def advance_to(self, t: float) -> None:
        self.now = max(self.now, t)


class SimulatedFaaSPlatform:
    """One deployment target for client functions (e.g. 'GCF gen2')."""

    def __init__(self, config: FaaSConfig = FaaSConfig(),
                 shape: FunctionShape = FunctionShape(), seed: int = 0):
        self.config = config
        self.shape = shape
        self.rng = np.random.default_rng(seed)
        self._warm: Dict[str, WarmInstance] = {}
        self.clock = VirtualClock()
        self.cold_starts = 0
        self.invocations = 0

    # ------------------------------------------------------------------
    def _cold_start_latency(self) -> float:
        c = self.config
        return float(self.rng.lognormal(np.log(c.cold_start_median_s),
                                        c.cold_start_sigma))

    def _instance(self, client_id: str, now: float) -> tuple:
        """Return (speed_factor, cold_start_s, was_cold) for this invocation,
        respecting the warm pool / scale-to-zero."""
        inst = self._warm.get(client_id)
        if inst is not None and inst.warm_until >= now:
            return inst.speed_factor, 0.0, False
        lo, hi = self.config.perf_variation
        speed = float(self.rng.uniform(lo, hi))
        self.cold_starts += 1
        return speed, self._cold_start_latency(), True

    # ------------------------------------------------------------------
    def invoke(self, client_id: str, nominal_work_s: float,
               start_time: float,
               profile: Optional[ClientProfile] = None) -> InvocationOutcome:
        """Simulate one client-function invocation starting at `start_time`.

        `nominal_work_s` is the client's ideal training time (data size ×
        epochs × per-sample cost); the platform scales it by the landed
        instance's speed factor and the client's heterogeneity profile.
        """
        profile = profile or ClientProfile()
        self.invocations += 1
        speed, cold_s, was_cold = self._instance(client_id, start_time)

        compute = nominal_work_s * speed * profile.slow_factor
        jitter = float(abs(self.rng.normal(0.0, self.config.network_jitter_s)))
        total = cold_s + compute + jitter

        failed = (profile.crash
                  or self.rng.random() < self.config.failure_rate
                  or total > self.config.function_timeout_s)

        finish = float("inf") if failed else start_time + total
        if not failed:
            # keep/refresh the warm instance
            self._warm[client_id] = WarmInstance(
                speed_factor=speed,
                warm_until=finish + self.config.warm_idle_timeout_s)
        else:
            self._warm.pop(client_id, None)

        return InvocationOutcome(
            client_id=client_id, start_time=start_time, cold_start_s=cold_s,
            compute_s=compute if not profile.crash else 0.0,
            crashed=failed, finish_time=finish, cold=was_cold)
