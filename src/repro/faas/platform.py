"""Simulated FaaS platform with a virtual clock.

Models the serverless characteristics the paper identifies as the reason
stragglers behave differently in FaaS (§II, §III-C):

  * cold starts — a function instance that is not warm pays a sampled
    cold-start latency before useful work begins;
  * scale-to-zero — warm instances expire after an idle timeout;
  * performance variation — each fresh instance lands on an unknown VM and
    gets a sampled speed factor (Wang et al. [29]);
  * weak reliability — invocations fail with (1 − SLO) probability
    (GCF SLO: 99.95% uptime);
  * function timeout — invocations are killed at the platform limit.

Everything runs on a virtual clock.  The platform does not sleep or
block: `plan_invocation()` samples the full timing of one invocation
(cold start, landed-instance speed, jitter, failure mode) and returns an
`InvocationPlan` the event engine turns into INVOKE_START /
COLD_START_DONE / CLIENT_FINISH / PLATFORM_FAILURE / WARM_EXPIRY events,
so a full FL experiment with hundreds of clients simulates in
milliseconds while preserving the timing structure the scheduling
strategy reacts to.  `invoke()` remains as the one-shot convenience
wrapper (plan + outcome in one call) for direct platform tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from .cost import FunctionShape


@dataclass(frozen=True)
class FaaSConfig:
    cold_start_median_s: float = 3.0     # GCF gen-2 cold start, median
    cold_start_sigma: float = 0.5        # lognormal spread
    warm_idle_timeout_s: float = 900.0   # scale-to-zero after 15 min idle
    perf_variation: tuple = (0.85, 1.35) # per-instance speed multiplier
    failure_rate: float = 0.0005         # 1 − SLO(99.95%)
    network_jitter_s: float = 0.5        # invocation + result upload jitter
    function_timeout_s: float = 540.0    # platform kill limit (paper config)
    # client→server update-upload bandwidth; only consulted when an update
    # carries a simulated wire size (compression on), so dense runs never
    # see a transfer term and stay byte-identical
    upload_bandwidth_bps: float = 16e6   # ~16 MB/s function egress


@dataclass
class WarmInstance:
    speed_factor: float
    warm_until: float


@dataclass
class InvocationOutcome:
    client_id: str
    start_time: float
    cold_start_s: float
    compute_s: float            # scaled work time on the landed instance
    crashed: bool               # platform-level failure or timeout kill
    finish_time: float          # = start + cold + compute + jitter (inf if crashed)
    cold: bool
    function_timeout_s: float = float("inf")

    @property
    def duration_s(self) -> float:
        """Billable duration.  The platform kills the instance at
        `function_timeout_s`, so a timeout-killed invocation can never be
        billed past it — the billable window is clamped to the kill."""
        if self.crashed:
            return min(self.cold_start_s + self.compute_s,
                       self.function_timeout_s)
        return self.finish_time - self.start_time


# failure taxonomy used by InvocationPlan.failure
FAIL_CRASH = "crash"        # client never responds (paper's failure straggler)
FAIL_PLATFORM = "platform"  # transient invocation error (1 − SLO) — retryable
FAIL_TIMEOUT = "timeout"    # killed at function_timeout_s


@dataclass
class InvocationPlan:
    """Sampled timing of one invocation attempt, before it 'happens'.

    The event engine consumes this: a plan with `failure is None` yields
    CLIENT_FINISH at `finish_time` (+ a WARM_EXPIRY lease), a retryable
    failure yields PLATFORM_FAILURE at `fail_time`, and a crash yields no
    event at all — the client is only discovered dead at the round
    deadline, exactly like a real non-responding function.
    """
    client_id: str
    start_time: float
    cold_start_s: float
    compute_s: float
    jitter_s: float
    cold: bool
    speed_factor: float
    failure: Optional[str]           # None | FAIL_CRASH/PLATFORM/TIMEOUT
    function_timeout_s: float
    warm_until: float                # 0.0 when the attempt failed

    @property
    def finish_time(self) -> float:
        if self.failure is not None:
            return float("inf")
        return (self.start_time + self.cold_start_s + self.compute_s
                + self.jitter_s)

    @property
    def fail_time(self) -> float:
        """Virtual time the failure becomes observable to the invoker.

        A platform error surfaces when the (doomed) invocation returns; a
        timeout kill at exactly `function_timeout_s`; a crashed client
        never reports (inf — the round deadline discovers it).
        """
        if self.failure == FAIL_PLATFORM:
            return (self.start_time + self.cold_start_s + self.compute_s
                    + self.jitter_s)
        if self.failure == FAIL_TIMEOUT:
            return self.start_time + self.function_timeout_s
        return float("inf")

    def to_outcome(self) -> InvocationOutcome:
        return InvocationOutcome(
            client_id=self.client_id, start_time=self.start_time,
            cold_start_s=self.cold_start_s,
            compute_s=0.0 if self.failure == FAIL_CRASH else self.compute_s,
            crashed=self.failure is not None,
            finish_time=self.finish_time, cold=self.cold,
            function_timeout_s=self.function_timeout_s)


@dataclass
class ClientProfile:
    """Per-client behaviour injected by the experiment scenario.

    `slow_factor` > 1 models resource heterogeneity (weak VM / big data);
    `crash` models the paper's failure-type stragglers (never respond);
    `fail_attempts` injects N deterministic transient platform failures
    before the first successful attempt (exercises the retry path).
    """
    slow_factor: float = 1.0
    crash: bool = False
    fail_attempts: int = 0


class VirtualClock:
    def __init__(self):
        self.now = 0.0

    def advance_to(self, t: float) -> None:
        self.now = max(self.now, t)


class SimulatedFaaSPlatform:
    """One deployment target for client functions (e.g. 'GCF gen2')."""

    def __init__(self, config: Optional[FaaSConfig] = None,
                 shape: Optional[FunctionShape] = None, seed: int = 0,
                 name: str = "sim", recorder=None):
        self.config = config if config is not None else FaaSConfig()
        self.shape = shape if shape is not None else FunctionShape()
        self.name = name
        self.rng = np.random.default_rng(seed)
        self._warm: Dict[str, WarmInstance] = {}
        self.clock = VirtualClock()
        self.cold_starts = 0
        self.invocations = 0
        # optional TraceRecorder (faas/trace.py): every sampled plan feeds
        # the per-platform cold-start/failure telemetry window — including
        # crash plans that never surface as events
        self.recorder = recorder

    # ------------------------------------------------------------------
    def _cold_start_latency(self) -> float:
        c = self.config
        return float(self.rng.lognormal(np.log(c.cold_start_median_s),
                                        c.cold_start_sigma))

    def _instance(self, client_id: str, now: float) -> tuple:
        """Return (speed_factor, cold_start_s, was_cold) for this invocation,
        respecting the warm pool / scale-to-zero."""
        inst = self._warm.get(client_id)
        if inst is not None and inst.warm_until >= now:
            return inst.speed_factor, 0.0, False
        lo, hi = self.config.perf_variation
        speed = float(self.rng.uniform(lo, hi))
        self.cold_starts += 1
        return speed, self._cold_start_latency(), True

    # ------------------------------------------------------------------
    def plan_invocation(self, client_id: str, nominal_work_s: float,
                        start_time: float,
                        profile: Optional[ClientProfile] = None,
                        attempt: int = 0) -> InvocationPlan:
        """Sample one invocation attempt starting at `start_time`.

        `nominal_work_s` is the client's ideal training time (data size ×
        epochs × per-sample cost); the platform scales it by the landed
        instance's speed factor and the client's heterogeneity profile.
        `attempt` counts retries of the same logical invocation.
        """
        profile = profile or ClientProfile()
        self.invocations += 1
        speed, cold_s, was_cold = self._instance(client_id, start_time)

        compute = nominal_work_s * speed * profile.slow_factor
        jitter = float(abs(self.rng.normal(0.0, self.config.network_jitter_s)))
        total = cold_s + compute + jitter

        if profile.crash:
            failure: Optional[str] = FAIL_CRASH
        else:
            transient = (attempt < profile.fail_attempts
                         or self.rng.random() < self.config.failure_rate)
            if transient:
                failure = FAIL_PLATFORM
            elif total > self.config.function_timeout_s:
                failure = FAIL_TIMEOUT
            else:
                failure = None

        warm_until = 0.0
        if failure is None:
            # keep/refresh the warm instance lease
            finish = start_time + total
            warm_until = finish + self.config.warm_idle_timeout_s
            self._warm[client_id] = WarmInstance(speed_factor=speed,
                                                warm_until=warm_until)
        else:
            self._warm.pop(client_id, None)

        plan = InvocationPlan(
            client_id=client_id, start_time=start_time, cold_start_s=cold_s,
            compute_s=compute, jitter_s=jitter, cold=was_cold,
            speed_factor=speed, failure=failure,
            function_timeout_s=self.config.function_timeout_s,
            warm_until=warm_until)
        if self.recorder is not None:
            self.recorder.on_plan(self.name, plan, attempt)
        return plan

    # ---- checkpoint surface (fl/checkpointing.py) --------------------
    def state_dict(self) -> dict:
        """JSON-ready snapshot of the platform's mutable state (RNG
        stream, warm pool, counters).  The virtual clock is owned by the
        training driver's snapshot — it is shared with the event queue."""
        return {
            "rng": self.rng.bit_generator.state,
            "warm": {cid: [inst.speed_factor, inst.warm_until]
                     for cid, inst in self._warm.items()},
            "cold_starts": self.cold_starts,
            "invocations": self.invocations,
        }

    def load_state_dict(self, state: dict) -> None:
        self.rng.bit_generator.state = state["rng"]
        self._warm = {cid: WarmInstance(speed_factor=sf, warm_until=until)
                      for cid, (sf, until) in state.get("warm", {}).items()}
        self.cold_starts = int(state.get("cold_starts", 0))
        self.invocations = int(state.get("invocations", 0))

    def expire_warm(self, client_id: str, now: float) -> bool:
        """Event-driven scale-to-zero: evict iff the lease truly lapsed.

        A WARM_EXPIRY event scheduled for an old lease is stale once the
        instance was re-leased by a later invocation — the lease-time
        check makes stale events harmless no-ops.
        """
        inst = self._warm.get(client_id)
        if inst is not None and inst.warm_until <= now:
            del self._warm[client_id]
            return True
        return False

    def warm_instance_count(self) -> int:
        return len(self._warm)

    # ------------------------------------------------------------------
    def invoke(self, client_id: str, nominal_work_s: float,
               start_time: float,
               profile: Optional[ClientProfile] = None) -> InvocationOutcome:
        """One-shot convenience path: plan the attempt and collapse it to
        its outcome (the pre-event-engine API, kept for direct tests)."""
        return self.plan_invocation(client_id, nominal_work_s, start_time,
                                    profile).to_outcome()
