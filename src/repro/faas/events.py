"""Discrete-event core of the serverless simulation.

The seed simulated each round as "invoke everyone at t0, compute every
finish time eagerly, filter at the deadline".  That shape cannot express
the behaviours the paper's claims rest on: retries (FedLess re-invokes
failed clients), per-round concurrency limits, warm instances expiring
*between* invocations, or a straggler's update physically arriving while
a *later* round is already running (Apodotiko-style true event ordering).

This module provides the deterministic event queue those behaviours hang
off: a binary heap keyed by ``(time, seq)`` over the existing
`VirtualClock`, where ``seq`` is a monotone schedule counter.  Two runs
with the same seeds schedule the same events in the same order and
therefore replay identically — determinism is a property of the key, not
of wall-clock luck.

Event kinds model the lifecycle of one serverless invocation:

    INVOKE_START      the invoker fires the HTTP request (or a retry)
    COLD_START_DONE   a cold instance finished booting (telemetry)
    CLIENT_FINISH     the client function returned its update
    PLATFORM_FAILURE  the platform reported an error / timeout kill
    WARM_EXPIRY       an idle warm instance scales to zero
    ROUND_DEADLINE    the controller's round timer fired
"""
from __future__ import annotations

import enum
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .platform import VirtualClock


class EventKind(enum.Enum):
    INVOKE_START = "invoke_start"
    COLD_START_DONE = "cold_start_done"
    CLIENT_FINISH = "client_finish"
    PLATFORM_FAILURE = "platform_failure"
    WARM_EXPIRY = "warm_expiry"
    ROUND_DEADLINE = "round_deadline"


@dataclass
class Event:
    time: float
    seq: int                       # schedule order — deterministic tiebreak
    kind: EventKind
    client_id: Optional[str] = None
    round_number: Optional[int] = None
    data: Dict[str, Any] = field(default_factory=dict)
    cancelled: bool = False

    def cancel(self) -> None:
        """Lazy cancellation: the heap entry stays, `pop` skips it."""
        self.cancelled = True


class EventQueue:
    """Deterministic future-event list on a shared `VirtualClock`.

    `pop` advances the clock to the popped event's time, so virtual time
    only ever moves at event boundaries and every consumer observes the
    same timeline.  Popped events are appended to `trace` — tests assert
    on it and it doubles as a simulation log.
    """

    def __init__(self, clock: Optional[VirtualClock] = None, recorder=None):
        self.clock = clock or VirtualClock()
        self._heap: List[tuple] = []
        self._seq = itertools.count()
        self.trace: List[Event] = []
        # optional TraceRecorder (faas/trace.py): notified of every popped
        # event for opt-in event-stream export
        self.recorder = recorder

    # ------------------------------------------------------------------
    def schedule(self, time: float, kind: EventKind,
                 client_id: Optional[str] = None,
                 round_number: Optional[int] = None, **data: Any) -> Event:
        ev = Event(time=float(time), seq=next(self._seq), kind=kind,
                   client_id=client_id, round_number=round_number, data=data)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    def pop(self) -> Optional[Event]:
        """Next live event (clock advances to it), or None when drained."""
        while self._heap:
            _, _, ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.clock.advance_to(ev.time)
            self.trace.append(ev)
            if self.recorder is not None:
                self.recorder.on_event(ev)
            return ev
        return None

    def peek_time(self) -> Optional[float]:
        while self._heap and self._heap[0][2].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return sum(1 for _, _, ev in self._heap if not ev.cancelled)

    def __bool__(self) -> bool:
        return len(self) > 0
