"""Discrete-event core of the serverless simulation.

The seed simulated each round as "invoke everyone at t0, compute every
finish time eagerly, filter at the deadline".  That shape cannot express
the behaviours the paper's claims rest on: retries (FedLess re-invokes
failed clients), per-round concurrency limits, warm instances expiring
*between* invocations, or a straggler's update physically arriving while
a *later* round is already running (Apodotiko-style true event ordering).

This module provides the deterministic event queue those behaviours hang
off: a binary heap keyed by ``(time, seq)`` over the existing
`VirtualClock`, where ``seq`` is a monotone schedule counter.  Two runs
with the same seeds schedule the same events in the same order and
therefore replay identically — determinism is a property of the key, not
of wall-clock luck.

Event kinds model the lifecycle of one serverless invocation:

    INVOKE_START      the invoker fires the HTTP request (or a retry)
    COLD_START_DONE   a cold instance finished booting (telemetry)
    CLIENT_FINISH     the client function returned its update
    PLATFORM_FAILURE  the platform reported an error / timeout kill
    WARM_EXPIRY       an idle warm instance scales to zero
    ROUND_DEADLINE    the controller's round timer fired

The queue is also the checkpoint substrate (fl/checkpointing.py): every
``data`` payload an event carries must be a plain JSON-serializable
record — platform references travel by *name*, never as live objects —
so ``state_dict``/``load_state_dict`` can snapshot the pending timeline
and a restored run replays the remaining events exactly, in-flight
stragglers included.  Restored events keep their original ``seq``, so
the (time, seq) replay order is byte-stable across a save/restore.
"""
from __future__ import annotations

import enum
import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .platform import VirtualClock


class EventKind(enum.Enum):
    INVOKE_START = "invoke_start"
    COLD_START_DONE = "cold_start_done"
    CLIENT_FINISH = "client_finish"
    PLATFORM_FAILURE = "platform_failure"
    WARM_EXPIRY = "warm_expiry"
    ROUND_DEADLINE = "round_deadline"


# compaction thresholds: rebuild the heap when cancelled tombstones
# outnumber live entries and the heap is big enough for it to matter
_COMPACT_MIN_SIZE = 64


# slots=True: at fleet scale the queue holds millions of Event objects;
# slotted instances drop the per-event __dict__ (~2x smaller, faster
# attribute access on the pop hot path)
@dataclass(slots=True)
class Event:
    time: float
    seq: int                       # schedule order — deterministic tiebreak
    kind: EventKind
    client_id: Optional[str] = None
    round_number: Optional[int] = None
    data: Dict[str, Any] = field(default_factory=dict)
    cancelled: bool = False
    # owning queue backref so lazy cancellation keeps the queue's live
    # counter exact (never serialized, never compared)
    _queue: Optional["EventQueue"] = field(default=None, repr=False,
                                           compare=False)

    def cancel(self) -> None:
        """Lazy cancellation: the heap entry stays, `pop` skips it."""
        if self.cancelled:
            return
        self.cancelled = True
        if self._queue is not None:
            self._queue._on_cancel()

    # ---- checkpoint surface ------------------------------------------
    def to_record(self) -> dict:
        """JSON-ready snapshot.  `data` must already be a plain record
        (strings/numbers/lists) — enforced by convention: every scheduler
        of events passes serializable payloads only."""
        return {"time": self.time, "seq": self.seq, "kind": self.kind.value,
                "client_id": self.client_id,
                "round_number": self.round_number, "data": dict(self.data)}

    @classmethod
    def from_record(cls, rec: dict) -> "Event":
        return cls(time=float(rec["time"]), seq=int(rec["seq"]),
                   kind=EventKind(rec["kind"]),
                   client_id=rec.get("client_id"),
                   round_number=rec.get("round_number"),
                   data=dict(rec.get("data", {})))


class EventQueue:
    """Deterministic future-event list on a shared `VirtualClock`.

    `pop` advances the clock to the popped event's time, so virtual time
    only ever moves at event boundaries and every consumer observes the
    same timeline.  Popped events are appended to `trace` — tests assert
    on it and it doubles as a simulation log.

    ``len(queue)`` is O(1): a live-event counter is maintained by
    `schedule`/`cancel`/`pop`, and the heap is compacted (cancelled
    tombstones dropped) whenever they outnumber the live entries.

    ``trace_maxlen`` bounds the popped-event log: the default (None)
    keeps the historical unbounded list, while fleet-scale runs pass a
    window size so memory stays O(window) over millions of events (the
    durable record stream is the TraceRecorder's job, not this log's).
    """

    def __init__(self, clock: Optional[VirtualClock] = None, recorder=None,
                 trace_maxlen: Optional[int] = None):
        self.clock = clock or VirtualClock()
        self._heap: List[tuple] = []
        self._next_seq = 0
        self._live = 0
        self.trace = (deque(maxlen=trace_maxlen)
                      if trace_maxlen is not None else [])
        # optional TraceRecorder (faas/trace.py): notified of every popped
        # event for opt-in event-stream export
        self.recorder = recorder

    # ------------------------------------------------------------------
    def schedule(self, time: float, kind: EventKind,
                 client_id: Optional[str] = None,
                 round_number: Optional[int] = None, **data: Any) -> Event:
        ev = Event(time=float(time), seq=self._next_seq, kind=kind,
                   client_id=client_id, round_number=round_number, data=data)
        self._next_seq += 1
        self._push(ev)
        return ev

    def _push(self, ev: Event) -> None:
        ev._queue = self
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._live += 1

    def pop(self) -> Optional[Event]:
        """Next live event (clock advances to it), or None when drained."""
        while self._heap:
            _, _, ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self._live -= 1
            # detach: a later cancel() of this already-delivered event
            # (fired deadlines, resolved lifecycles) must not decrement
            # the live counter a second time
            ev._queue = None
            self.clock.advance_to(ev.time)
            self.trace.append(ev)
            if self.recorder is not None:
                self.recorder.on_event(ev)
            return ev
        return None

    def peek_time(self) -> Optional[float]:
        while self._heap and self._heap[0][2].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return self._live

    def __bool__(self) -> bool:
        return self._live > 0

    # ---- lazy-cancellation bookkeeping --------------------------------
    def _on_cancel(self) -> None:
        self._live -= 1
        if (len(self._heap) >= _COMPACT_MIN_SIZE
                and self._live * 2 < len(self._heap)):
            self._compact()

    def _compact(self) -> None:
        """Drop cancelled tombstones: rebuild the heap from live events."""
        entries = [e for e in self._heap if not e[2].cancelled]
        heapq.heapify(entries)
        self._heap = entries

    # ---- checkpoint surface (fl/checkpointing.py) --------------------
    def state_dict(self) -> dict:
        """Snapshot the pending timeline: every live event (original seq
        preserved) plus the schedule counter, so a restored queue keeps
        scheduling new events past the old counter and replays the
        remaining (time, seq) order byte-identically."""
        live = sorted((e[2] for e in self._heap if not e[2].cancelled),
                      key=lambda ev: (ev.time, ev.seq))
        return {"next_seq": self._next_seq,
                "events": [ev.to_record() for ev in live]}

    def load_state_dict(self, state: dict) -> Dict[int, Event]:
        """Rebuild the pending timeline; returns ``{seq: Event}`` so
        callers holding event handles (the engine's cancellation lists,
        the async driver's deadline tickets) can re-link them."""
        self._heap = []
        self._live = 0
        by_seq: Dict[int, Event] = {}
        for rec in state.get("events", []):
            ev = Event.from_record(rec)
            self._push(ev)
            by_seq[ev.seq] = ev
        self._next_seq = int(state.get("next_seq", 0))
        return by_seq
