"""Event-driven trace export + rolling fleet telemetry.

`TraceRecorder` is the single sink every layer of the simulation reports
into: the platform reports sampled invocation plans (cold starts), the
invocation engine reports one record per resolved invocation *attempt*
(cold start, retry index, billed duration, arrival virtual time, routing
decision), the cost meter reports every billed charge, and the training
driver reports every aggregation event and every scheduler cohort
decision (``scheduling`` records).  Records are plain dicts dumped
as JSONL, so a full experiment round-trips: summing the ``billing``
records reconstructs ``CostMeter.total`` exactly, and the attempt stream
replays the schedule the event queue produced.

Because everything runs on the virtual clock, two same-seed runs emit
byte-identical traces — the recorder never reads wall-clock time.

Fleet scale: by default all records buffer in memory (`records`), which
is exactly the historical behaviour.  Passing ``stream_path`` turns the
recorder into a streaming writer: records accumulate in a bounded
buffer and are appended to the JSONL file every ``flush_every`` records,
so memory stays O(flush_every) at any trace length; ``shard_records``
additionally rotates the stream across numbered shard files
(``<stem>.00000.jsonl``, ``<stem>.00001.jsonl``, …) for multi-gigabyte
runs.  The streamed bytes are the exact `dumps()` bytes — same-seed
runs produce byte-identical output in either mode — and the read-back
surface (`select`, `billed_total`, `dumps`, `record_count`) spans
flushed shards plus the live buffer transparently.

The recorder also keeps a *rolling window* of per-platform attempt
outcomes (failures, cold starts), fed exclusively by the platform-side
`on_plan` hook — one observation per sampled attempt, including crash
plans that never surface as events — so attaching the same recorder to
the engine as well never double-counts.  `platform_stats()` exposes it
as recent failure/cold-start rates, which
`faas.fleet.TelemetryRoutingPolicy` reads to de-prioritize degraded
providers (the platforms must therefore carry the recorder, e.g. via
`PlatformFleet.attach_recorder`).
"""
from __future__ import annotations

import json
from collections import deque
from pathlib import Path
from typing import Dict, FrozenSet, Iterator, List, Optional

# record types emitted into the JSONL stream
REC_ATTEMPT = "attempt"
REC_BILLING = "billing"
REC_AGGREGATION = "aggregation"
REC_ROUTE = "route"
REC_EVENT = "event"
REC_SCHEDULING = "scheduling"

# The declared key-set contract for every record type.  Golden trace
# tests compare *bytes*, so the exact keys each sink emits are part of
# the public surface: "required" keys appear in every record of that
# type, "optional" keys only under documented conditions (compression
# on, barrier-free round aliasing, ...), and "open" marks the two sinks
# that accept **extra metadata (aggregation/scheduling payloads).
# repro-lint's CON002 statically checks the sink literals below against
# this table — extend the table and the golden fixtures together.
RECORD_SCHEMAS = {
    REC_ATTEMPT: {
        "required": ["client_id", "platform", "round", "attempt",
                     "start_time", "arrival_time", "cold",
                     "cold_start_s", "billed_s", "status"],
        "optional": ["payload_bytes", "dispatch_s", "ticket"],
        "open": False,
    },
    REC_BILLING: {
        "required": ["cost", "duration_s", "kind", "client_id",
                     "round"],
        "optional": [],
        "open": False,
    },
    REC_AGGREGATION: {
        "required": ["time", "round", "merged", "strategy", "mode"],
        "optional": [],
        "open": True,       # server_opt/update_norm/compression extras
    },
    REC_SCHEDULING: {
        "required": ["time", "round", "scheduler", "mode", "want",
                     "selected", "pool_size"],
        "optional": [],
        "open": True,       # per-scheduler payload (tiers, score stats)
    },
    REC_ROUTE: {
        "required": ["client_id", "platform", "reason"],
        "optional": [],
        "open": False,
    },
    REC_EVENT: {
        "required": ["time", "kind", "client_id", "round"],
        "optional": [],
        "open": False,
    },
}

_UNSHARDED_ROOM = 1 << 62


def _dump_line(rec: dict) -> str:
    """One canonical JSONL line (deterministic: sorted keys,
    repr-round-trip floats) — the single formatter both the in-memory
    and the streaming paths go through."""
    return json.dumps(rec, sort_keys=True) + "\n"


class TraceRecorder:
    """Collects simulation records and rolling per-platform telemetry."""

    def __init__(self, telemetry_window: int = 50,
                 event_kinds: Optional[FrozenSet[str]] = None,
                 stream_path=None, flush_every: int = 4096,
                 shard_records: Optional[int] = None):
        self.records: List[dict] = []       # in-memory buffer
        self.telemetry_window = telemetry_window
        # queue-event logging is opt-in (the attempt stream already covers
        # the invocation lifecycle); pass e.g. {"round_deadline"}
        self.event_kinds = event_kinds or frozenset()
        self._windows: Dict[str, deque] = {}
        self._round_aliases: Dict[int, int] = {}
        # streaming mode (None = buffer everything, the historical default)
        self.stream_path = Path(stream_path) if stream_path else None
        self.flush_every = max(1, int(flush_every))
        self.shard_records = shard_records
        self._flushed = 0                   # records already on disk
        self._shards: List[Path] = []
        self._shard_counts: List[int] = []

    @property
    def record_count(self) -> int:
        """Total records emitted so far (flushed + buffered) — the
        checkpoint trace-offset surface at any fleet size."""
        return self._flushed + len(self.records)

    @property
    def streaming(self) -> bool:
        return self.stream_path is not None

    def alias_round(self, engine_round: int, reported_round) -> None:
        """Barrier-free mode: the engine schedules each invocation as its
        own synthetic ticket; aliasing maps the ticket onto the current
        model version (the driver refreshes it at resolution time), so
        attempt records share a 'round' number space with billing and
        aggregation records.  The original ticket id is preserved in the
        record's 'ticket' field."""
        self._round_aliases[engine_round] = reported_round

    def _append(self, rec: dict) -> None:
        self.records.append(rec)
        if (self.stream_path is not None
                and len(self.records) >= self.flush_every):
            self.flush()

    # ---- sinks (called by the simulation layers) ----------------------
    def attempt(self, *, client_id: str, platform: str, round_number,
                attempt: int, start_time: float, arrival_time: float,
                cold: bool, cold_start_s: float, billed_s: float,
                status: str, payload_bytes: Optional[int] = None,
                dispatch_s: Optional[float] = None) -> None:
        """One resolved invocation attempt (success, failure, or a crash
        discovered at a deadline).  `status` is "ok" or a failure reason
        from faas.platform (crash/platform/timeout).  `payload_bytes` is
        the update's simulated wire size when compression is on — None
        (the dense default) keeps the record's key set byte-identical to
        pre-compression traces.  `dispatch_s` is the executor's wall-clock
        group-dispatch latency when timing collection is on — same
        only-when-set rule, so default traces never gain the key.  Pure
        record sink — telemetry windows are fed by `on_plan` (one
        observation per sampled attempt), never here, so a recorder
        attached to both the engine and the platforms counts each attempt
        once."""
        rec = {
            "type": REC_ATTEMPT, "client_id": client_id,
            "platform": platform, "round": round_number,
            "attempt": attempt, "start_time": start_time,
            "arrival_time": arrival_time, "cold": cold,
            "cold_start_s": cold_start_s, "billed_s": billed_s,
            "status": status,
        }
        if payload_bytes is not None:
            rec["payload_bytes"] = payload_bytes
        if dispatch_s is not None:
            rec["dispatch_s"] = dispatch_s
        if round_number in self._round_aliases:
            rec["ticket"] = round_number
            rec["round"] = self._round_aliases[round_number]
        self._append(rec)

    def billing(self, *, cost: float, duration_s: float, kind: str,
                client_id: Optional[str] = None,
                round_number=None) -> None:
        """One charge on the cost meter.  Summing the `cost` fields of all
        billing records reconstructs `CostMeter.total`."""
        self._append({
            "type": REC_BILLING, "cost": cost, "duration_s": duration_s,
            "kind": kind, "client_id": client_id, "round": round_number,
        })

    def aggregation(self, *, time: float, round_number, merged: int,
                    strategy: str, mode: str, **extra) -> None:
        """One aggregation event (a round close, or an async merge).
        `extra` carries merge-pipeline metadata when a non-identity
        server optimizer is configured: `server_opt` (family name),
        `server_steps` (optimizer steps taken), and `update_norm`
        (‖Δ‖₂ of the pseudo-gradient; 0.0 for a zero-update merge)."""
        rec = {
            "type": REC_AGGREGATION, "time": time, "round": round_number,
            "merged": merged, "strategy": strategy, "mode": mode,
        }
        rec.update(extra)
        self._append(rec)

    def scheduling(self, *, time: float, round_number, scheduler: str,
                   mode: str, want: int, selected, pool_size: int,
                   **extra) -> None:
        """One Scheduler.propose() decision (fl/scheduler.py): a round
        cohort in barrier modes, a slot refill in barrier-free mode.
        `extra` carries scheduler-specific payload (tier counts for
        fedlesscan, score stats for apodotiko, cohort for adaptive)."""
        rec = {
            "type": REC_SCHEDULING, "time": time, "round": round_number,
            "scheduler": scheduler, "mode": mode, "want": want,
            "selected": list(selected), "pool_size": pool_size,
        }
        rec.update(extra)
        self._append(rec)

    def route(self, client_id: str, platform: str, reason: str) -> None:
        """A routing decision (fresh assignment or telemetry re-route)."""
        self._append({
            "type": REC_ROUTE, "client_id": client_id,
            "platform": platform, "reason": reason,
        })

    def on_plan(self, platform: str, plan, attempt: int) -> None:
        """Platform hook: a sampled invocation plan.  Feeds the cold-start
        telemetry window even for attempts that never produce an event
        (crash profiles)."""
        w = self._windows.setdefault(
            platform, deque(maxlen=self.telemetry_window))
        w.append((plan.failure is not None, plan.cold))

    def on_event(self, ev) -> None:
        """EventQueue hook: called for every popped event; records only
        the kinds in `event_kinds` (off by default)."""
        if ev.kind.value in self.event_kinds:
            self._append({
                "type": REC_EVENT, "time": ev.time, "kind": ev.kind.value,
                "client_id": ev.client_id, "round": ev.round_number,
            })

    # ---- streaming writer ---------------------------------------------
    def _shard_with_room(self) -> tuple:
        """(path, remaining capacity) of the shard to append to next."""
        if not self.shard_records:
            if not self._shards:
                self._shards = [self.stream_path]
                self._shard_counts = [0]
            return self._shards[0], _UNSHARDED_ROOM
        if (not self._shards
                or self._shard_counts[-1] >= self.shard_records):
            i = len(self._shards)
            p = self.stream_path.with_name(
                f"{self.stream_path.stem}.{i:05d}.jsonl")
            self._shards.append(p)
            self._shard_counts.append(0)
        return self._shards[-1], self.shard_records - self._shard_counts[-1]

    def flush(self) -> None:
        """Append the buffer to the stream file(s) and drop it — memory
        stays bounded regardless of trace length.  No-op when not
        streaming (the buffer IS the trace then)."""
        if self.stream_path is None or not self.records:
            return
        self.stream_path.parent.mkdir(parents=True, exist_ok=True)
        buf = self.records
        pos = 0
        while pos < len(buf):
            path, room = self._shard_with_room()
            take = buf[pos:pos + room]
            with path.open("a", encoding="utf-8") as fh:
                fh.writelines(_dump_line(r) for r in take)
            self._shard_counts[-1] += len(take)
            pos += len(take)
        self._flushed += len(buf)
        self.records = []

    def shard_paths(self) -> List[Path]:
        """Stream files written so far (one entry unless sharding)."""
        return list(self._shards)

    def _iter_lines(self) -> Iterator[str]:
        """Every record as its canonical JSONL line — flushed shards
        first, then the live buffer; never materializes the full trace."""
        for path in self._shards:
            with path.open("r", encoding="utf-8") as fh:
                yield from fh
        for rec in self.records:
            yield _dump_line(rec)

    def iter_records(self) -> Iterator[dict]:
        """Every record as a dict, in emission order, across both the
        flushed stream and the live buffer."""
        for path in self._shards:
            with path.open("r", encoding="utf-8") as fh:
                for line in fh:
                    if line.strip():
                        yield json.loads(line)
        yield from self.records

    # ---- checkpoint surface (fl/checkpointing.py) ---------------------
    def telemetry_state_dict(self) -> dict:
        """Snapshot the rolling per-platform windows (NOT the record
        stream: a resumed run writes its own trace, but telemetry-reactive
        routing must keep seeing the same recent failure/cold rates)."""
        return {name: [[bool(f), bool(c)] for f, c in w]
                for name, w in self._windows.items()}

    def load_telemetry_state(self, state: dict) -> None:
        self._windows = {
            name: deque(((bool(f), bool(c)) for f, c in obs),
                        maxlen=self.telemetry_window)
            for name, obs in state.items()}

    # ---- telemetry (read by TelemetryRoutingPolicy) -------------------
    def platform_stats(self) -> Dict[str, dict]:
        """Recent per-platform rates over the rolling window."""
        stats = {}
        for name, w in self._windows.items():
            n = len(w)
            failures = sum(1 for failed, _ in w if failed)
            colds = sum(1 for _, cold in w if cold)
            stats[name] = {
                "attempts": n,
                "failures": failures,
                "cold_starts": colds,
                "failure_rate": failures / n if n else 0.0,
                "cold_rate": colds / n if n else 0.0,
            }
        return stats

    # ---- export -------------------------------------------------------
    def select(self, record_type: str) -> List[dict]:
        if self._flushed:
            return [r for r in self.iter_records()
                    if r["type"] == record_type]
        return [r for r in self.records if r["type"] == record_type]

    def billed_total(self) -> float:
        """Reconstruct the meter total from the trace stream."""
        return sum(r["cost"] for r in self.select(REC_BILLING))

    def dumps(self) -> str:
        """The full trace as a JSONL string — byte-identical whether the
        recorder buffered or streamed."""
        if self._flushed:
            return "".join(self._iter_lines())
        return "".join(_dump_line(r) for r in self.records)

    def to_jsonl(self, path) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        if self._flushed:
            self.flush()
            with p.open("w", encoding="utf-8") as out:
                for line in self._iter_lines():
                    out.write(line)
        else:
            p.write_text(self.dumps())
        return p


def load_jsonl(path) -> List[dict]:
    """Round-trip loader for exported traces."""
    return [json.loads(line)
            for line in Path(path).read_text().splitlines() if line]
