"""Event-driven trace export + rolling fleet telemetry.

`TraceRecorder` is the single sink every layer of the simulation reports
into: the platform reports sampled invocation plans (cold starts), the
invocation engine reports one record per resolved invocation *attempt*
(cold start, retry index, billed duration, arrival virtual time, routing
decision), the cost meter reports every billed charge, and the training
driver reports every aggregation event and every scheduler cohort
decision (``scheduling`` records).  Records are plain dicts dumped
as JSONL, so a full experiment round-trips: summing the ``billing``
records reconstructs ``CostMeter.total`` exactly, and the attempt stream
replays the schedule the event queue produced.

Because everything runs on the virtual clock, two same-seed runs emit
byte-identical traces — the recorder never reads wall-clock time.

The recorder also keeps a *rolling window* of per-platform attempt
outcomes (failures, cold starts), fed exclusively by the platform-side
`on_plan` hook — one observation per sampled attempt, including crash
plans that never surface as events — so attaching the same recorder to
the engine as well never double-counts.  `platform_stats()` exposes it
as recent failure/cold-start rates, which
`faas.fleet.TelemetryRoutingPolicy` reads to de-prioritize degraded
providers (the platforms must therefore carry the recorder, e.g. via
`PlatformFleet.attach_recorder`).
"""
from __future__ import annotations

import json
from collections import deque
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional

# record types emitted into the JSONL stream
REC_ATTEMPT = "attempt"
REC_BILLING = "billing"
REC_AGGREGATION = "aggregation"
REC_ROUTE = "route"
REC_EVENT = "event"
REC_SCHEDULING = "scheduling"


class TraceRecorder:
    """Collects simulation records and rolling per-platform telemetry."""

    def __init__(self, telemetry_window: int = 50,
                 event_kinds: Optional[FrozenSet[str]] = None):
        self.records: List[dict] = []
        self.telemetry_window = telemetry_window
        # queue-event logging is opt-in (the attempt stream already covers
        # the invocation lifecycle); pass e.g. {"round_deadline"}
        self.event_kinds = event_kinds or frozenset()
        self._windows: Dict[str, deque] = {}
        self._round_aliases: Dict[int, int] = {}

    def alias_round(self, engine_round: int, reported_round) -> None:
        """Barrier-free mode: the engine schedules each invocation as its
        own synthetic ticket; aliasing maps the ticket onto the current
        model version (the driver refreshes it at resolution time), so
        attempt records share a 'round' number space with billing and
        aggregation records.  The original ticket id is preserved in the
        record's 'ticket' field."""
        self._round_aliases[engine_round] = reported_round

    # ---- sinks (called by the simulation layers) ----------------------
    def attempt(self, *, client_id: str, platform: str, round_number,
                attempt: int, start_time: float, arrival_time: float,
                cold: bool, cold_start_s: float, billed_s: float,
                status: str) -> None:
        """One resolved invocation attempt (success, failure, or a crash
        discovered at a deadline).  `status` is "ok" or a failure reason
        from faas.platform (crash/platform/timeout).  Pure record sink —
        telemetry windows are fed by `on_plan` (one observation per
        sampled attempt), never here, so a recorder attached to both the
        engine and the platforms counts each attempt once."""
        rec = {
            "type": REC_ATTEMPT, "client_id": client_id,
            "platform": platform, "round": round_number,
            "attempt": attempt, "start_time": start_time,
            "arrival_time": arrival_time, "cold": cold,
            "cold_start_s": cold_start_s, "billed_s": billed_s,
            "status": status,
        }
        if round_number in self._round_aliases:
            rec["ticket"] = round_number
            rec["round"] = self._round_aliases[round_number]
        self.records.append(rec)

    def billing(self, *, cost: float, duration_s: float, kind: str,
                client_id: Optional[str] = None,
                round_number=None) -> None:
        """One charge on the cost meter.  Summing the `cost` fields of all
        billing records reconstructs `CostMeter.total`."""
        self.records.append({
            "type": REC_BILLING, "cost": cost, "duration_s": duration_s,
            "kind": kind, "client_id": client_id, "round": round_number,
        })

    def aggregation(self, *, time: float, round_number, merged: int,
                    strategy: str, mode: str, **extra) -> None:
        """One aggregation event (a round close, or an async merge).
        `extra` carries merge-pipeline metadata when a non-identity
        server optimizer is configured: `server_opt` (family name),
        `server_steps` (optimizer steps taken), and `update_norm`
        (‖Δ‖₂ of the pseudo-gradient; 0.0 for a zero-update merge)."""
        rec = {
            "type": REC_AGGREGATION, "time": time, "round": round_number,
            "merged": merged, "strategy": strategy, "mode": mode,
        }
        rec.update(extra)
        self.records.append(rec)

    def scheduling(self, *, time: float, round_number, scheduler: str,
                   mode: str, want: int, selected, pool_size: int,
                   **extra) -> None:
        """One Scheduler.propose() decision (fl/scheduler.py): a round
        cohort in barrier modes, a slot refill in barrier-free mode.
        `extra` carries scheduler-specific payload (tier counts for
        fedlesscan, score stats for apodotiko, cohort for adaptive)."""
        rec = {
            "type": REC_SCHEDULING, "time": time, "round": round_number,
            "scheduler": scheduler, "mode": mode, "want": want,
            "selected": list(selected), "pool_size": pool_size,
        }
        rec.update(extra)
        self.records.append(rec)

    def route(self, client_id: str, platform: str, reason: str) -> None:
        """A routing decision (fresh assignment or telemetry re-route)."""
        self.records.append({
            "type": REC_ROUTE, "client_id": client_id,
            "platform": platform, "reason": reason,
        })

    def on_plan(self, platform: str, plan, attempt: int) -> None:
        """Platform hook: a sampled invocation plan.  Feeds the cold-start
        telemetry window even for attempts that never produce an event
        (crash profiles)."""
        w = self._windows.setdefault(
            platform, deque(maxlen=self.telemetry_window))
        w.append((plan.failure is not None, plan.cold))

    def on_event(self, ev) -> None:
        """EventQueue hook: called for every popped event; records only
        the kinds in `event_kinds` (off by default)."""
        if ev.kind.value in self.event_kinds:
            self.records.append({
                "type": REC_EVENT, "time": ev.time, "kind": ev.kind.value,
                "client_id": ev.client_id, "round": ev.round_number,
            })

    # ---- checkpoint surface (fl/checkpointing.py) ---------------------
    def telemetry_state_dict(self) -> dict:
        """Snapshot the rolling per-platform windows (NOT the record
        stream: a resumed run writes its own trace, but telemetry-reactive
        routing must keep seeing the same recent failure/cold rates)."""
        return {name: [[bool(f), bool(c)] for f, c in w]
                for name, w in self._windows.items()}

    def load_telemetry_state(self, state: dict) -> None:
        self._windows = {
            name: deque(((bool(f), bool(c)) for f, c in obs),
                        maxlen=self.telemetry_window)
            for name, obs in state.items()}

    # ---- telemetry (read by TelemetryRoutingPolicy) -------------------
    def platform_stats(self) -> Dict[str, dict]:
        """Recent per-platform rates over the rolling window."""
        stats = {}
        for name, w in self._windows.items():
            n = len(w)
            failures = sum(1 for failed, _ in w if failed)
            colds = sum(1 for _, cold in w if cold)
            stats[name] = {
                "attempts": n,
                "failures": failures,
                "cold_starts": colds,
                "failure_rate": failures / n if n else 0.0,
                "cold_rate": colds / n if n else 0.0,
            }
        return stats

    # ---- export -------------------------------------------------------
    def select(self, record_type: str) -> List[dict]:
        return [r for r in self.records if r["type"] == record_type]

    def billed_total(self) -> float:
        """Reconstruct the meter total from the trace stream."""
        return sum(r["cost"] for r in self.select(REC_BILLING))

    def dumps(self) -> str:
        """The full trace as a JSONL string (deterministic: sorted keys,
        repr-round-trip floats)."""
        return "".join(json.dumps(r, sort_keys=True) + "\n"
                       for r in self.records)

    def to_jsonl(self, path) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.dumps())
        return p


def load_jsonl(path) -> List[dict]:
    """Round-trip loader for exported traces."""
    return [json.loads(line)
            for line in Path(path).read_text().splitlines() if line]
