"""Per-provider FaaS platform profiles + multi-platform invoker.

FedLess is cloud-agnostic (paper §III-A): clients may live on GCF, AWS
Lambda, or a self-hosted OpenFaaS cluster simultaneously.  Profiles carry
provider-measured characteristics (cold-start medians, SLO, billing);
`MultiPlatformInvoker` routes each client to its platform while keeping
the controller completely provider-agnostic.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from .cost import FunctionShape, PriceBook
from .fleet import PlatformFleet, RoutingPolicy
from .invoker import ClientWorkFn, InvocationResult
from .platform import ClientProfile, FaaSConfig, SimulatedFaaSPlatform

Pytree = Any

# Provider characteristics (public measurements: Wang et al. ATC'18,
# provider docs; prices: 2022 price books used by the paper's cost model)
PLATFORM_PROFILES: Dict[str, dict] = {
    "gcf-gen2": dict(
        faas=FaaSConfig(cold_start_median_s=3.0, cold_start_sigma=0.5,
                        warm_idle_timeout_s=900.0, failure_rate=0.0005,
                        function_timeout_s=3600.0),
        shape=FunctionShape(memory_mb=2048, vcpus=1.0, timeout_s=540.0),
        prices=PriceBook(vcpu_second=0.0000240, gib_second=0.0000025,
                         per_invocation=0.40 / 1e6)),
    "aws-lambda": dict(
        faas=FaaSConfig(cold_start_median_s=1.2, cold_start_sigma=0.6,
                        warm_idle_timeout_s=420.0, failure_rate=0.0003,
                        function_timeout_s=900.0),
        shape=FunctionShape(memory_mb=2048, vcpus=1.2, timeout_s=900.0),
        prices=PriceBook(vcpu_second=0.0, gib_second=0.0000167,
                         per_invocation=0.20 / 1e6)),
    "openfaas": dict(
        faas=FaaSConfig(cold_start_median_s=8.0, cold_start_sigma=0.8,
                        warm_idle_timeout_s=300.0, failure_rate=0.002,
                        perf_variation=(0.7, 1.6),
                        function_timeout_s=1800.0),
        shape=FunctionShape(memory_mb=4096, vcpus=1.0, timeout_s=1800.0),
        # self-hosted: amortised VM cost expressed per-second
        prices=PriceBook(vcpu_second=0.0000110, gib_second=0.0000015,
                         per_invocation=0.0)),
}


def make_platform(profile: str, seed: int = 0) -> SimulatedFaaSPlatform:
    p = PLATFORM_PROFILES[profile]
    return SimulatedFaaSPlatform(p["faas"], p["shape"], seed=seed,
                                 name=profile)


class MultiPlatformInvoker:
    """Routes each client to its provider's simulated platform.

    A thin invoker facade over `fleet.PlatformFleet`: `assignment` maps
    client_id → profile name; unassigned clients use `default` (or the
    fleet routing mode).  Presents the same interface as MockInvoker so
    the controller doesn't change (the paper's provider-agnostic design).
    """

    def __init__(self, work_fn: ClientWorkFn,
                 assignment: Dict[str, str],
                 profiles: Optional[Dict[str, ClientProfile]] = None,
                 default: str = "gcf-gen2", seed: int = 0,
                 routing_mode: str = "sticky"):
        self.work_fn = work_fn
        self.profiles = profiles or {}
        self.default = default
        self.fleet = PlatformFleet.from_profiles(
            routing=RoutingPolicy(list(PLATFORM_PROFILES),
                                  assignment=assignment, default=default,
                                  mode=routing_mode, seed=seed),
            seed=seed)
        self.platforms = self.fleet.platforms
        self.assignment = self.fleet.routing.assignment
        self.platform = self.platforms[default]

    def platform_of(self, cid: str) -> SimulatedFaaSPlatform:
        return self.fleet.platform_of(cid)

    def invoke_clients(self, client_ids: Sequence[str],
                       global_params: Pytree, round_number: int,
                       start_time: float) -> List[InvocationResult]:
        results = []
        for cid in client_ids:
            platform = self.platform_of(cid)
            profile = self.profiles.get(cid, ClientProfile())
            if profile.crash:
                outcome = platform.invoke(cid, 0.0, start_time, profile)
                results.append(InvocationResult(outcome=outcome,
                                                update=None))
                continue
            update, nominal_s = self.work_fn(cid, global_params,
                                             round_number)
            outcome = platform.invoke(cid, nominal_s, start_time, profile)
            results.append(InvocationResult(
                outcome=outcome,
                update=None if outcome.crashed else update))
        return results
