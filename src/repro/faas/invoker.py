"""Invoker — bridges the FL controller and the (simulated) FaaS platform.

This is the paper's *Mock Invoker* (§IV-A): it lets the entire system run
on one machine by simulating the behaviour of the deployed client
functions, while executing the clients' actual training code so that the
produced model updates are real.  The controller code path is identical to
what a live-HTTP invoker would use.

Two layers live here:

  * `MockInvoker` — the per-client work + platform routing surface
    (single platform; `faas.profiles.MultiPlatformInvoker` is the fleet
    twin).  Its legacy `invoke_clients` batch API is kept for direct
    tests and external callers.
  * `InvocationEngine` — the event-driven scheduler the controller now
    drives.  It turns each invocation into lifecycle events on the
    shared `EventQueue`, enforces a per-round concurrency cap, and
    re-invokes transiently failed clients up to `max_retries` times (the
    FedLess invoker's retry behaviour) — every attempt billed.
"""
from __future__ import annotations

from collections import deque
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.aggregation import (ClientUpdate, update_from_record,
                                update_to_record)
from .events import Event, EventKind, EventQueue
from .platform import (FAIL_PLATFORM, FAIL_TIMEOUT, ClientProfile,
                       InvocationOutcome, InvocationPlan,
                       SimulatedFaaSPlatform)

Pytree = Any

# Client work callback: (client_id, global_params, round) ->
#   (ClientUpdate, nominal_work_seconds)
ClientWorkFn = Callable[[str, Pytree, int], tuple]


@dataclass
class InvocationResult:
    outcome: InvocationOutcome
    update: Optional[ClientUpdate]  # None when the invocation crashed


@dataclass
class ClientCompletion:
    """Terminal result of one logical invocation (all attempts included)."""
    round_number: int
    client_id: str
    outcome: InvocationOutcome
    update: Optional[ClientUpdate]          # None when terminally failed
    attempts: int = 1
    failed_attempts: List[InvocationOutcome] = field(default_factory=list)

    @property
    def success(self) -> bool:
        return not self.outcome.crashed


class MockInvoker:
    """Invokes client functions against the simulated platform.

    `profiles` carries the experiment scenario's straggler injection
    (slow factors / crashes) keyed by client id.
    """

    def __init__(self, platform: SimulatedFaaSPlatform,
                 work_fn: ClientWorkFn,
                 profiles: Optional[Dict[str, ClientProfile]] = None):
        self.platform = platform
        self.work_fn = work_fn
        self.profiles = profiles or {}

    def platform_of(self, client_id: str) -> SimulatedFaaSPlatform:
        return self.platform

    def invoke_clients(self, client_ids: Sequence[str], global_params: Pytree,
                       round_number: int,
                       start_time: float) -> List[InvocationResult]:
        results = []
        for cid in client_ids:
            profile = self.profiles.get(cid, ClientProfile())
            if profile.crash:
                outcome = self.platform.invoke(cid, 0.0, start_time, profile)
                results.append(InvocationResult(outcome=outcome, update=None))
                continue
            update, nominal_s = self.work_fn(cid, global_params, round_number)
            outcome = self.platform.invoke(cid, nominal_s, start_time, profile)
            results.append(InvocationResult(
                outcome=outcome, update=None if outcome.crashed else update))
        return results


# ======================================================================
class _RoundState:
    """Per-round scheduling state inside the engine."""

    def __init__(self, round_number: int, client_ids: Sequence[str],
                 global_params: Pytree):
        self.round_number = round_number
        self.client_ids = list(client_ids)
        self.global_params = global_params
        self.waiting: deque = deque()            # cap overflow, not yet fired
        self.active = 0                          # invocations in flight
        self.platform_names: Dict[str, str] = {} # routing decision at start
        self.attempts: Dict[str, int] = {}
        self.failed: Dict[str, List[InvocationOutcome]] = {}
        # cid -> (plan, update, [scheduled events])
        self.inflight: Dict[str, Tuple[InvocationPlan,
                                       Optional[ClientUpdate], list]] = {}
        self.work: Dict[str, tuple] = {}         # cid -> (update, nominal_s)
        # deferred batch work: a thunk producing work-cache entries, run
        # when the round's first INVOKE_START fires (not at open_round) —
        # the overlapped-dispatch hook.  Never checkpointed: open_round
        # and the first event land in the same controller turn.
        self.work_provider: Optional[Callable[[], Optional[Dict[str, tuple]]]] = None
        self.retrying: set = set()               # retry fired, not restarted
        self.done: set = set()
        self.closed = False


class InvocationEngine:
    """Event-driven invocation scheduler over any invoker that exposes
    `platform_of(cid)`, `work_fn` and `profiles`.

    The engine owns the invocation lifecycle; the controller owns round
    semantics (deadline, history, cost, aggregation) and consumes the
    `ClientCompletion`s the engine emits from `handle()`.
    """

    def __init__(self, invoker, max_retries: int = 1,
                 max_concurrency: Optional[int] = None,
                 retry_on_timeout: bool = False, recorder=None):
        self.invoker = invoker
        self.max_retries = max_retries
        self.max_concurrency = max_concurrency
        self.retry_on_timeout = retry_on_timeout
        # optional TraceRecorder (faas/trace.py): one record per resolved
        # invocation attempt, carrying the routing decision (platform name)
        self.recorder = recorder
        self._rounds: Dict[int, _RoundState] = {}

    def _record_attempt(self, st: _RoundState, cid: str,
                        plan: InvocationPlan, attempt: int,
                        arrival_time: float, status: str) -> None:
        if self.recorder is None:
            return
        outcome = plan.to_outcome()
        # compressed runs stamp the attempt with its simulated wire size;
        # dense updates keep payload None and the record's key set stays
        # exactly the legacy one (byte-parity with pre-compression traces)
        cached = st.work.get(cid)
        payload = (cached[0].payload_bytes
                   if cached is not None and cached[0] is not None else None)
        # dispatch_s is wall-clock launch telemetry stamped by the
        # executor when timing collection is on — like payload_bytes it
        # is only-when-set, so dense/default traces stay byte-identical
        dispatch = (cached[0].dispatch_s
                    if cached is not None and cached[0] is not None else None)
        # the platform captured at _start time: platform_of() may be a
        # *mutating* routing call (TelemetryRoutingPolicy can re-route),
        # so it must not be re-resolved as a side effect of logging
        self.recorder.attempt(
            client_id=cid, platform=st.platform_names.get(cid, "?"),
            round_number=st.round_number, attempt=attempt,
            start_time=plan.start_time, arrival_time=arrival_time,
            cold=plan.cold, cold_start_s=plan.cold_start_s,
            billed_s=outcome.duration_s, status=status,
            payload_bytes=payload, dispatch_s=dispatch)

    # ------------------------------------------------------------------
    def open_round(self, queue: EventQueue, client_ids: Sequence[str],
                   global_params: Pytree, round_number: int,
                   start_time: float,
                   precomputed: Optional[Dict[str, tuple]] = None,
                   work_provider: Optional[
                       Callable[[], Optional[Dict[str, tuple]]]] = None
                   ) -> None:
        """Schedule the round's invocations; at most `max_concurrency` are
        in flight at once, the rest start as earlier ones resolve.

        ``precomputed`` seeds the work cache eagerly; ``work_provider``
        defers the same batch to the round's first INVOKE_START — with
        overlapped dispatch the provider *launches* the executor's async
        group dispatch and returns unready handles, so the rest of the
        round's event bookkeeping runs while the devices train.  Both
        fire at the same virtual time with identical client order, so
        the two paths are trace-byte-identical."""
        st = _RoundState(round_number, client_ids, global_params)
        if precomputed:
            st.work.update(precomputed)
        st.work_provider = work_provider
        self._rounds[round_number] = st
        cap = self.max_concurrency or len(st.client_ids)
        for cid in st.client_ids[:cap]:
            self._fire(queue, st, cid, start_time)
        st.waiting.extend(st.client_ids[cap:])

    def _fire(self, queue: EventQueue, st: _RoundState, cid: str,
              when: float) -> None:
        st.active += 1
        queue.schedule(when, EventKind.INVOKE_START, client_id=cid,
                       round_number=st.round_number)

    # ------------------------------------------------------------------
    def handle(self, queue: EventQueue,
               event: Event) -> Optional[ClientCompletion]:
        """Process one event; returns a ClientCompletion when an
        invocation reached a terminal state (success or retries
        exhausted), else None."""
        kind = event.kind
        if kind is EventKind.INVOKE_START:
            self._start(queue, event)
        elif kind is EventKind.CLIENT_FINISH:
            return self._finish(queue, event)
        elif kind is EventKind.PLATFORM_FAILURE:
            return self._failure(queue, event)
        elif kind is EventKind.WARM_EXPIRY:
            # events carry the platform *name* (payloads must stay
            # serializable for the checkpoint snapshot); resolve it
            # against the invoker's platform registry here
            platform = self._platform_named(event.data.get("platform"))
            if platform is not None:
                platform.expire_warm(event.client_id, event.time)
        # COLD_START_DONE / ROUND_DEADLINE: telemetry / controller-owned
        return None

    # ------------------------------------------------------------------
    def _start(self, queue: EventQueue, event: Event) -> None:
        st = self._rounds.get(event.round_number)
        if st is None or st.closed:
            return      # round closed between scheduling and firing
        cid = event.client_id
        if st.work_provider is not None:
            # consume exactly once, before any per-client work_fn can run
            provider, st.work_provider = st.work_provider, None
            produced = provider()
            if produced:
                st.work.update(produced)
        st.retrying.discard(cid)
        profile = self.invoker.profiles.get(cid, ClientProfile())
        platform = self.invoker.platform_of(cid)
        st.platform_names[cid] = platform.name

        if profile.crash:
            update, nominal_s = None, 0.0
        elif cid in st.work:
            update, nominal_s = st.work[cid]
        else:
            update, nominal_s = self.invoker.work_fn(
                cid, st.global_params, st.round_number)
            st.work[cid] = (update, nominal_s)

        # compressed updates carry their simulated wire size — the upload
        # rides inside the invocation window, so the platform's timeout /
        # speed-scaling / billing math all see the transfer term (dense
        # updates have payload_bytes None: zero-size legacy behaviour)
        work_s = nominal_s
        if update is not None and update.payload_bytes is not None:
            bw = platform.config.upload_bandwidth_bps
            if bw > 0:
                work_s = nominal_s + update.payload_bytes / bw

        attempt = st.attempts.get(cid, 0)
        plan = platform.plan_invocation(cid, work_s, event.time, profile,
                                        attempt=attempt)
        scheduled: list = []
        if plan.cold and plan.cold_start_s > 0:
            scheduled.append(queue.schedule(
                event.time + plan.cold_start_s, EventKind.COLD_START_DONE,
                client_id=cid, round_number=st.round_number,
                platform=platform.name))
        if plan.failure is None:
            scheduled.append(queue.schedule(
                plan.finish_time, EventKind.CLIENT_FINISH, client_id=cid,
                round_number=st.round_number))
            queue.schedule(plan.warm_until, EventKind.WARM_EXPIRY,
                           client_id=cid, platform=platform.name)
        elif plan.fail_time != float("inf"):
            scheduled.append(queue.schedule(
                plan.fail_time, EventKind.PLATFORM_FAILURE, client_id=cid,
                round_number=st.round_number, reason=plan.failure))
        # FAIL_CRASH: no event — discovered at the round deadline
        st.inflight[cid] = (plan, update, scheduled)

    # ------------------------------------------------------------------
    def _finish(self, queue: EventQueue,
                event: Event) -> Optional[ClientCompletion]:
        st = self._rounds.get(event.round_number)
        if st is None or event.client_id not in st.inflight:
            return None     # resolved at a round close; stale event
        cid = event.client_id
        plan, update, _ = st.inflight.pop(cid)
        st.done.add(cid)
        self._release_slot(queue, st, event.time)
        self._record_attempt(st, cid, plan, st.attempts.get(cid, 0),
                             event.time, "ok")
        completion = ClientCompletion(
            round_number=st.round_number, client_id=cid,
            outcome=plan.to_outcome(), update=update,
            attempts=st.attempts.get(cid, 0) + 1,
            failed_attempts=st.failed.get(cid, []))
        self._maybe_gc(st)
        return completion

    def _failure(self, queue: EventQueue,
                 event: Event) -> Optional[ClientCompletion]:
        st = self._rounds.get(event.round_number)
        if st is None or event.client_id not in st.inflight:
            return None
        cid = event.client_id
        plan, update, _ = st.inflight.pop(cid)
        outcome = plan.to_outcome()
        st.failed.setdefault(cid, []).append(outcome)
        attempt = st.attempts.get(cid, 0)
        self._record_attempt(st, cid, plan, attempt, event.time,
                             plan.failure or "failed")

        retryable = (plan.failure == FAIL_PLATFORM
                     or (plan.failure == FAIL_TIMEOUT
                         and self.retry_on_timeout))
        if retryable and attempt < self.max_retries and not st.closed:
            # FedLess invoker behaviour: immediately re-invoke (same slot,
            # attempt counter bumped; every attempt is billed separately).
            st.attempts[cid] = attempt + 1
            st.retrying.add(cid)
            queue.schedule(event.time, EventKind.INVOKE_START, client_id=cid,
                           round_number=st.round_number)
            return None

        st.done.add(cid)
        self._release_slot(queue, st, event.time)
        completion = ClientCompletion(
            round_number=st.round_number, client_id=cid, outcome=outcome,
            update=None, attempts=attempt + 1,
            failed_attempts=st.failed.get(cid, [])[:-1])
        self._maybe_gc(st)
        return completion

    def _release_slot(self, queue: EventQueue, st: _RoundState,
                      now: float) -> None:
        st.active -= 1
        if st.waiting and not st.closed:
            self._fire(queue, st, st.waiting.popleft(), now)

    # ------------------------------------------------------------------
    def close_round(self, round_number: int,
                    now: float) -> Tuple[List[str], List[str], List[str]]:
        """Round deadline bookkeeping.  Returns

            (late, dead, unstarted)

        * late      — in flight with a live CLIENT_FINISH in the future:
                      the client is alive, its update will arrive
                      mid-flight during a later round;
        * dead      — in flight with no pending finish (crash profiles,
                      not-yet-observed timeout kills): cancelled;
        * unstarted — never fired because of the concurrency cap.
        """
        st = self._rounds.get(round_number)
        if st is None:
            return [], [], []
        st.closed = True
        late, dead = [], []
        for cid, (plan, _upd, scheduled) in list(st.inflight.items()):
            if plan.failure is None and plan.finish_time > now:
                late.append(cid)
                continue
            dead.append(cid)
            for ev in scheduled:
                ev.cancel()
            del st.inflight[cid]
            st.done.add(cid)
            # crash plans never surface as events — the deadline is the
            # first (and only) observation, so record the attempt here
            self._record_attempt(st, cid, plan, st.attempts.get(cid, 0),
                                 now, plan.failure or "unresponsive")
        # a retry whose INVOKE_START is still queued at close never runs
        # (the start handler drops it): the client missed the round
        dead.extend(sorted(st.retrying))
        st.done.update(st.retrying)
        st.retrying.clear()
        unstarted = list(st.waiting)
        st.waiting.clear()
        st.done.update(unstarted)
        self._maybe_gc(st)
        return late, dead, unstarted

    def drain_round(self, round_number: int,
                    now: float) -> List[Tuple[str, float]]:
        """Abandon an open round at experiment end: cancel its scheduled
        events and return (client_id, billable_s) for every in-flight
        attempt — the provider bills a launched invocation regardless of
        whether the controller is still listening for its result."""
        st = self._rounds.get(round_number)
        if st is None:
            return []
        st.closed = True
        billed = []
        for cid, (plan, _upd, scheduled) in list(st.inflight.items()):
            for ev in scheduled:
                ev.cancel()
            self._record_attempt(st, cid, plan, st.attempts.get(cid, 0),
                                 now, "abandoned")
            billed.append((cid, plan.to_outcome().duration_s))
            del st.inflight[cid]
            st.done.add(cid)
        st.retrying.clear()
        st.waiting.clear()
        self._maybe_gc(st)
        return billed

    def unresolved_count(self, round_number: int) -> int:
        """Clients of the round that could still produce an event: in
        flight, waiting on a slot, or mid-retry.  Crash-profile clients
        count — the controller cannot observe that they never respond."""
        st = self._rounds.get(round_number)
        if st is None:
            return 0
        return len(st.inflight) + len(st.waiting) + len(st.retrying)

    def _maybe_gc(self, st: _RoundState) -> None:
        if st.closed and not st.inflight and not st.waiting:
            self._rounds.pop(st.round_number, None)

    # ------------------------------------------------------------------
    # checkpoint surface (fl/checkpointing.py)
    # ------------------------------------------------------------------
    def _platform_named(self, name) -> Optional[SimulatedFaaSPlatform]:
        """Resolve a platform by name against the invoker (single-platform
        MockInvoker or a MultiPlatformInvoker's fleet).  Unknown names
        resolve to None — expiring a *different* platform's warm pool
        would be worse than ignoring a stale event."""
        platforms = getattr(self.invoker, "platforms", None)
        if platforms is not None:
            return platforms.get(name)
        platform = getattr(self.invoker, "platform", None)
        if platform is not None and (name is None or platform.name == name):
            return platform
        return None

    def state_dict(self, arrays: Dict[str, Any]) -> dict:
        """JSON-ready snapshot of every open round's scheduling state.

        Scalars (plans, attempts, failed outcomes, waiting/retrying/done
        sets) go into the returned record; pytrees — the round's global
        params and each cached `ClientUpdate` — are deposited into
        `arrays` under ``engine/...`` keys and saved alongside the
        checkpoint params (they share the model's tree structure).
        In-flight updates are not stored twice: an inflight entry's
        update *is* its work-cache entry, so only the cache is saved and
        `load_state_dict` re-links the reference.  Global-params trees
        are deduplicated by object identity: the async driver opens one
        engine round per in-flight ticket, all sharing the same model
        object, which would otherwise put N full model copies in every
        snapshot.
        """
        rounds = []
        params_slots: Dict[int, str] = {}    # id(tree) -> arrays key
        for rnd, st in sorted(self._rounds.items()):
            params_key = params_slots.get(id(st.global_params))
            if params_key is None:
                params_key = f"engine/params/{len(params_slots)}"
                params_slots[id(st.global_params)] = params_key
                arrays[params_key] = st.global_params
            work = {}
            for cid, (update, nominal_s) in st.work.items():
                entry = {"nominal_s": nominal_s, "update": None}
                if update is not None:
                    # .params is the device-pipeline lazy-materialization
                    # point: a batch-backed update (DeviceUpdateBatch row)
                    # builds its concrete pytree here, exactly when the
                    # in-flight snapshot genuinely needs tree structure
                    arrays[f"engine/{rnd}/work/{cid}"] = update.params
                    entry["update"] = update_to_record(update)
                work[cid] = entry
            rounds.append({
                "round": rnd,
                "params_key": params_key,
                "client_ids": list(st.client_ids),
                "waiting": list(st.waiting),
                "active": st.active,
                "platform_names": dict(st.platform_names),
                "attempts": dict(st.attempts),
                "failed": {cid: [asdict(o) for o in outs]
                           for cid, outs in st.failed.items()},
                "inflight": {cid: {"plan": asdict(plan),
                                   "has_update": update is not None,
                                   "scheduled": [ev.seq for ev in scheduled
                                                 if not ev.cancelled]}
                             for cid, (plan, update, scheduled)
                             in st.inflight.items()},
                "work": work,
                "retrying": sorted(st.retrying),
                "done": sorted(st.done),
                "closed": st.closed,
            })
        return {"rounds": rounds}

    def load_state_dict(self, state: dict, events_by_seq: Dict[int, Event],
                        arrays: Dict[str, Any]) -> None:
        """Inverse of `state_dict`: rebuild the open rounds and re-link
        their scheduled-event handles to the restored queue's events."""
        self._rounds = {}
        for rec in state.get("rounds", []):
            rnd = rec["round"]
            st = _RoundState(rnd, rec["client_ids"],
                             arrays.get(rec.get("params_key")))
            st.waiting = deque(rec.get("waiting", []))
            st.active = int(rec.get("active", 0))
            st.platform_names = dict(rec.get("platform_names", {}))
            st.attempts = {cid: int(n)
                           for cid, n in rec.get("attempts", {}).items()}
            st.failed = {cid: [InvocationOutcome(**o) for o in outs]
                         for cid, outs in rec.get("failed", {}).items()}
            for cid, w in rec.get("work", {}).items():
                update = None
                if w.get("update") is not None:
                    update = update_from_record(
                        w["update"], arrays[f"engine/{rnd}/work/{cid}"])
                st.work[cid] = (update, float(w["nominal_s"]))
            for cid, inf in rec.get("inflight", {}).items():
                update = (st.work[cid][0] if inf.get("has_update")
                          else None)
                scheduled = [events_by_seq[seq]
                             for seq in inf.get("scheduled", [])
                             if seq in events_by_seq]
                st.inflight[cid] = (InvocationPlan(**inf["plan"]), update,
                                    scheduled)
            st.retrying = set(rec.get("retrying", []))
            st.done = set(rec.get("done", []))
            st.closed = bool(rec.get("closed", False))
            self._rounds[rnd] = st
