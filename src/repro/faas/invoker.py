"""Invoker — bridges the FL controller and the (simulated) FaaS platform.

This is the paper's *Mock Invoker* (§IV-A): it lets the entire system run
on one machine by simulating the behaviour of the deployed client
functions, while executing the clients' actual training code so that the
produced model updates are real.  The controller code path is identical to
what a live-HTTP invoker would use.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

from ..core.aggregation import ClientUpdate
from .platform import (ClientProfile, InvocationOutcome,
                       SimulatedFaaSPlatform)

Pytree = Any

# Client work callback: (client_id, global_params, round) ->
#   (ClientUpdate, nominal_work_seconds)
ClientWorkFn = Callable[[str, Pytree, int], tuple]


@dataclass
class InvocationResult:
    outcome: InvocationOutcome
    update: Optional[ClientUpdate]  # None when the invocation crashed


class MockInvoker:
    """Invokes client functions against the simulated platform.

    `profiles` carries the experiment scenario's straggler injection
    (slow factors / crashes) keyed by client id.
    """

    def __init__(self, platform: SimulatedFaaSPlatform,
                 work_fn: ClientWorkFn,
                 profiles: Optional[Dict[str, ClientProfile]] = None):
        self.platform = platform
        self.work_fn = work_fn
        self.profiles = profiles or {}

    def invoke_clients(self, client_ids: Sequence[str], global_params: Pytree,
                       round_number: int,
                       start_time: float) -> List[InvocationResult]:
        results = []
        for cid in client_ids:
            profile = self.profiles.get(cid, ClientProfile())
            if profile.crash:
                outcome = self.platform.invoke(cid, 0.0, start_time, profile)
                results.append(InvocationResult(outcome=outcome, update=None))
                continue
            update, nominal_s = self.work_fn(cid, global_params, round_number)
            outcome = self.platform.invoke(cid, nominal_s, start_time, profile)
            results.append(InvocationResult(
                outcome=outcome, update=None if outcome.crashed else update))
        return results
