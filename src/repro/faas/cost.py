"""GCF gen-2 cost model (paper §VI-A5 / [85]).

Google bills 2nd-gen Cloud Functions per vCPU-second, per GiB-second of
memory, and per million invocations (Tier-1 prices, 2022):

    vCPU-second   $0.0000240
    GiB-second    $0.0000025
    invocations   $0.40 / 1e6

Gen-2 functions get a vCPU allocation proportional to memory
(2048 MB → 1 vCPU, the paper's client config).  The paper estimates a
straggler's cost as running for the *entire round duration* (§VI-C), which
`straggler_invocation_cost` reproduces.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class PriceBook:
    vcpu_second: float = 0.0000240
    gib_second: float = 0.0000025
    per_invocation: float = 0.40 / 1_000_000
    free_tier: bool = False  # paper reports raw costs, no free tier


@dataclass(frozen=True)
class FunctionShape:
    memory_mb: int = 2048
    vcpus: float = 1.0
    timeout_s: float = 540.0   # paper's client function timeout


def invocation_cost(duration_s: float, shape: FunctionShape,
                    prices: PriceBook = PriceBook()) -> float:
    """Cost of one function invocation running for `duration_s` seconds.

    GCF bills duration rounded up to the nearest 100 ms increment.
    """
    billed = max(0.1, -(-duration_s // 0.1) * 0.1)  # ceil to 100 ms
    gib = shape.memory_mb / 1024.0
    return (billed * shape.vcpus * prices.vcpu_second
            + billed * gib * prices.gib_second
            + prices.per_invocation)


def straggler_invocation_cost(round_duration_s: float, shape: FunctionShape,
                              prices: PriceBook = PriceBook()) -> float:
    """Paper §VI-C: a straggler is charged as if it ran the whole round."""
    return invocation_cost(round_duration_s, shape, prices)


class CostMeter:
    """Accumulates experiment cost across invocations (one per client call)."""

    def __init__(self, shape: FunctionShape = FunctionShape(),
                 prices: PriceBook = PriceBook()):
        self.shape = shape
        self.prices = prices
        self.total = 0.0
        self.invocations = 0

    def charge(self, duration_s: float) -> float:
        c = invocation_cost(duration_s, self.shape, self.prices)
        self.total += c
        self.invocations += 1
        return c

    def charge_straggler(self, round_duration_s: float) -> float:
        c = straggler_invocation_cost(round_duration_s, self.shape, self.prices)
        self.total += c
        self.invocations += 1
        return c
