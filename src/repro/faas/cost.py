"""GCF gen-2 cost model (paper §VI-A5 / [85]).

Google bills 2nd-gen Cloud Functions per vCPU-second, per GiB-second of
memory, and per million invocations (Tier-1 prices, 2022):

    vCPU-second   $0.0000240
    GiB-second    $0.0000025
    invocations   $0.40 / 1e6

Gen-2 functions get a vCPU allocation proportional to memory
(2048 MB → 1 vCPU, the paper's client config).  The paper estimates a
straggler's cost as running for the *entire round duration* (§VI-C), which
`straggler_invocation_cost` reproduces.

When `PriceBook.free_tier` is set, the monthly GCF free tier (2M
invocations, 180k vCPU-seconds, 360k GiB-seconds) is consumed first: a
`FreeTierAllowance` tracks the remaining grant and `invocation_cost`
only bills usage beyond it.  The paper reports raw costs (free tier
off), which stays the default.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class PriceBook:
    vcpu_second: float = 0.0000240
    gib_second: float = 0.0000025
    per_invocation: float = 0.40 / 1_000_000
    # internet egress for the client's update upload (GCP premium tier,
    # first TiB); only billed when updates carry a simulated wire size
    egress_per_gib: float = 0.12
    free_tier: bool = False  # paper reports raw costs, no free tier


def egress_cost(payload_bytes: int,
                prices: Optional[PriceBook] = None) -> float:
    """Cost of shipping one encoded client update to the server."""
    prices = prices if prices is not None else PriceBook()
    return (payload_bytes / 2**30) * prices.egress_per_gib


@dataclass
class FreeTierAllowance:
    """Remaining monthly free-tier grant (GCF gen-2 public quotas)."""
    invocations: float = 2_000_000.0
    vcpu_seconds: float = 180_000.0
    gib_seconds: float = 360_000.0

    def consume(self, attr: str, amount: float) -> float:
        """Consume up to `amount` from the grant; return the *billable*
        remainder that exceeded it."""
        remaining = getattr(self, attr)
        free = min(amount, remaining)
        setattr(self, attr, remaining - free)
        return amount - free


@dataclass(frozen=True)
class FunctionShape:
    memory_mb: int = 2048
    vcpus: float = 1.0
    timeout_s: float = 540.0   # paper's client function timeout


def invocation_cost(duration_s: float, shape: FunctionShape,
                    prices: Optional[PriceBook] = None,
                    allowance: Optional[FreeTierAllowance] = None) -> float:
    """Cost of one function invocation running for `duration_s` seconds.

    GCF bills duration rounded up to the nearest 100 ms increment.  With
    `prices.free_tier` and an `allowance`, the free-tier grant is drawn
    down first and only the excess is billed (the allowance is mutated).
    """
    prices = prices if prices is not None else PriceBook()
    billed = max(0.1, -(-duration_s // 0.1) * 0.1)  # ceil to 100 ms
    gib = shape.memory_mb / 1024.0
    vcpu_s = billed * shape.vcpus
    gib_s = billed * gib
    n_inv = 1.0
    if prices.free_tier and allowance is not None:
        vcpu_s = allowance.consume("vcpu_seconds", vcpu_s)
        gib_s = allowance.consume("gib_seconds", gib_s)
        n_inv = allowance.consume("invocations", n_inv)
    return (vcpu_s * prices.vcpu_second
            + gib_s * prices.gib_second
            + n_inv * prices.per_invocation)


def straggler_invocation_cost(round_duration_s: float, shape: FunctionShape,
                              prices: Optional[PriceBook] = None,
                              allowance: Optional[FreeTierAllowance] = None
                              ) -> float:
    """Paper §VI-C: a straggler is charged as if it ran the whole round."""
    return invocation_cost(round_duration_s, shape, prices, allowance)


class CostMeter:
    """Accumulates experiment cost across invocations (one per client call).

    Beyond the total, the meter attributes every charge to the client and
    round (or async model version) it was incurred for — `by_client` and
    `rounds` — and, when a `TraceRecorder` is attached, emits one billing
    record per charge so the JSONL trace reconstructs `total` exactly.
    """

    def __init__(self, shape: Optional[FunctionShape] = None,
                 prices: Optional[PriceBook] = None, trace=None):
        self.shape = shape if shape is not None else FunctionShape()
        self.prices = prices if prices is not None else PriceBook()
        self.trace = trace
        self.total = 0.0
        self.invocations = 0
        self.by_client: Dict[str, float] = {}
        self.rounds: Dict[int, float] = {}
        self.allowance = (FreeTierAllowance()
                          if self.prices.free_tier else None)

    def _record(self, cost: float, duration_s: float, kind: str,
                client_id: Optional[str], round_number) -> float:
        self.total += cost
        self.invocations += 1
        if client_id is not None:
            self.by_client[client_id] = self.by_client.get(client_id, 0.0) + cost
        if round_number is not None:
            self.rounds[round_number] = self.rounds.get(round_number, 0.0) + cost
        if self.trace is not None:
            self.trace.billing(cost=cost, duration_s=duration_s, kind=kind,
                               client_id=client_id, round_number=round_number)
        return cost

    def charge(self, duration_s: float, client_id: Optional[str] = None,
               round_number=None, kind: str = "attempt") -> float:
        c = invocation_cost(duration_s, self.shape, self.prices,
                            self.allowance)
        return self._record(c, duration_s, kind, client_id, round_number)

    def charge_egress(self, payload_bytes: Optional[int],
                      client_id: Optional[str] = None,
                      round_number=None) -> float:
        """Bill one update upload's egress.  None (dense runs) is a free
        no-op with no billing record — the compressed-vs-plaintext trace
        diff is exactly the egress lines."""
        if payload_bytes is None:
            return 0.0
        c = egress_cost(payload_bytes, self.prices)
        return self._record(c, 0.0, "egress", client_id, round_number)

    def charge_straggler(self, round_duration_s: float,
                         client_id: Optional[str] = None,
                         round_number=None) -> float:
        c = straggler_invocation_cost(round_duration_s, self.shape,
                                      self.prices, self.allowance)
        return self._record(c, round_duration_s, "straggler", client_id,
                            round_number)

    # ---- checkpoint surface (fl/checkpointing.py) --------------------
    def state_dict(self) -> dict:
        """JSON-ready snapshot of the tallies.  Round keys are ints in
        memory but JSON object keys are strings — serialization stringifies
        them here and `load_state_dict` casts them back, so a resumed
        meter's `rounds` keys stay ints and per-round totals keep
        accumulating into the same buckets."""
        state = {
            "total": self.total,
            "invocations": self.invocations,
            "by_client": dict(self.by_client),
            "rounds": {str(k): v for k, v in self.rounds.items()},
        }
        if self.allowance is not None:
            # free-tier billing: the remaining monthly grant is part of
            # the cost state (a resumed run must not re-grant it)
            state["allowance"] = {
                "invocations": self.allowance.invocations,
                "vcpu_seconds": self.allowance.vcpu_seconds,
                "gib_seconds": self.allowance.gib_seconds,
            }
        return state

    def load_state_dict(self, state: dict) -> None:
        self.total = float(state.get("total", 0.0))
        self.invocations = int(state.get("invocations", 0))
        self.by_client = dict(state.get("by_client", {}))
        self.rounds = {int(k): v
                       for k, v in state.get("rounds", {}).items()}
        if "allowance" in state and self.allowance is not None:
            for attr, left in state["allowance"].items():
                setattr(self.allowance, attr, float(left))
