"""Simulated serverless substrate: platform, invoker, GCF cost model."""
from .cost import CostMeter, FunctionShape, PriceBook, invocation_cost
from .invoker import InvocationResult, MockInvoker
from .profiles import (PLATFORM_PROFILES, MultiPlatformInvoker,
                       make_platform)
from .platform import (ClientProfile, FaaSConfig, InvocationOutcome,
                       SimulatedFaaSPlatform, VirtualClock)

__all__ = [
    "CostMeter", "FunctionShape", "PriceBook", "invocation_cost",
    "InvocationResult", "MockInvoker", "ClientProfile", "FaaSConfig",
    "InvocationOutcome", "SimulatedFaaSPlatform", "VirtualClock",
    "PLATFORM_PROFILES", "MultiPlatformInvoker", "make_platform",
]
