"""Simulated serverless substrate: event queue, platforms, fleet, invoker,
GCF cost model."""
from .cost import CostMeter, FunctionShape, PriceBook, invocation_cost
from .events import Event, EventKind, EventQueue
from .fleet import PlatformFleet, RoutingPolicy
from .invoker import (ClientCompletion, InvocationEngine, InvocationResult,
                      MockInvoker)
from .profiles import (PLATFORM_PROFILES, MultiPlatformInvoker,
                       make_platform)
from .platform import (ClientProfile, FaaSConfig, InvocationOutcome,
                       InvocationPlan, SimulatedFaaSPlatform, VirtualClock)

__all__ = [
    "CostMeter", "FunctionShape", "PriceBook", "invocation_cost",
    "Event", "EventKind", "EventQueue",
    "PlatformFleet", "RoutingPolicy",
    "ClientCompletion", "InvocationEngine", "InvocationResult", "MockInvoker",
    "ClientProfile", "FaaSConfig", "InvocationOutcome", "InvocationPlan",
    "SimulatedFaaSPlatform", "VirtualClock",
    "PLATFORM_PROFILES", "MultiPlatformInvoker", "make_platform",
]
