"""Simulated serverless substrate: event queue, platforms, fleet, invoker,
GCF cost model, trace export."""
from .cost import (CostMeter, FreeTierAllowance, FunctionShape, PriceBook,
                   invocation_cost)
from .events import Event, EventKind, EventQueue
from .fleet import PlatformFleet, RoutingPolicy, TelemetryRoutingPolicy
from .invoker import (ClientCompletion, InvocationEngine, InvocationResult,
                      MockInvoker)
from .profiles import (PLATFORM_PROFILES, MultiPlatformInvoker,
                       make_platform)
from .platform import (ClientProfile, FaaSConfig, InvocationOutcome,
                       InvocationPlan, SimulatedFaaSPlatform, VirtualClock)
from .trace import TraceRecorder, load_jsonl

__all__ = [
    "CostMeter", "FreeTierAllowance", "FunctionShape", "PriceBook",
    "invocation_cost",
    "Event", "EventKind", "EventQueue",
    "PlatformFleet", "RoutingPolicy", "TelemetryRoutingPolicy",
    "ClientCompletion", "InvocationEngine", "InvocationResult", "MockInvoker",
    "ClientProfile", "FaaSConfig", "InvocationOutcome", "InvocationPlan",
    "SimulatedFaaSPlatform", "VirtualClock",
    "PLATFORM_PROFILES", "MultiPlatformInvoker", "make_platform",
    "TraceRecorder", "load_jsonl",
]
