"""Multi-provider platform fleet + routing policy.

FedLess is cloud-agnostic (paper §III-A): one experiment's clients may
live on GCF, AWS Lambda and a self-hosted OpenFaaS cluster at the same
time.  `PlatformFleet` holds a set of *named* `SimulatedFaaSPlatform`s
with distinct `FaaSConfig`/`FunctionShape`/`PriceBook` profiles, all
sharing one `VirtualClock`, and a `RoutingPolicy` that decides which
provider serves which client — so the controller stays completely
provider-agnostic while the simulation reproduces per-provider cold-start
spectra, SLOs, scale-to-zero windows and price books.

Routing modes:

  * ``sticky``       — explicit client→platform assignment with a default
                        (FedLess deployment files pin each client);
  * ``round-robin``  — unassigned clients are spread across providers in
                        deterministic rotation (multi-region load spread);
  * ``random``       — seeded random choice per new client (then sticky).

Regional-outage scenarios: `set_platform_down` marks a provider as
failing every invocation (failure_rate = 1), which the retry machinery in
the invoker then observes as repeated PLATFORM_FAILURE events.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from ..core.interning import ClientInterner, grow_to
from .platform import SimulatedFaaSPlatform, VirtualClock


class _AssignmentView:
    """Dict-compatible live view of a policy's array-backed sticky table.

    The historical `policy.assignment` surface was a plain ``{client_id:
    platform_name}`` dict; at fleet scale the table is an int64 array
    over interned client indices, and this view keeps the dict reads and
    writes working against it unchanged."""

    __slots__ = ("_policy",)

    def __init__(self, policy: "RoutingPolicy"):
        self._policy = policy

    def get(self, client_id: str, default=None):
        name = self._policy._get_assignment(client_id)
        return default if name is None else name

    def __getitem__(self, client_id: str) -> str:
        name = self._policy._get_assignment(client_id)
        if name is None:
            raise KeyError(client_id)
        return name

    def __setitem__(self, client_id: str, name: str) -> None:
        self._policy._set_assignment(client_id, name)

    def __contains__(self, client_id) -> bool:
        return self._policy._get_assignment(client_id) is not None

    def _pairs(self):
        pol = self._policy
        ids = pol._interner.ids
        table = pol._assigned
        for i in range(len(ids)):
            p = table[i]
            if p >= 0:
                yield ids[i], pol._names[int(p)]

    def __iter__(self):
        return (cid for cid, _ in self._pairs())

    def __len__(self) -> int:
        n = len(self._policy._interner)
        return int((self._policy._assigned[:n] >= 0).sum())

    def keys(self):
        return list(self)

    def values(self):
        return [name for _, name in self._pairs()]

    def items(self):
        return list(self._pairs())

    def __eq__(self, other):
        return dict(self._pairs()) == other

    def __repr__(self):
        return f"_AssignmentView({dict(self._pairs())!r})"


class RoutingPolicy:
    """Maps client ids to platform names; decisions are sticky so a
    client's warm instances stay meaningful across rounds.

    The sticky table is array-backed (interned client index → platform
    index) so a million registered clients cost one int64 slot each, not
    a dict entry of Python strings; `assignment` exposes the historical
    dict surface as a live view."""

    def __init__(self, platform_names: Sequence[str],
                 assignment: Optional[Dict[str, str]] = None,
                 default: Optional[str] = None,
                 mode: str = "sticky", seed: int = 0):
        if not platform_names:
            raise ValueError("RoutingPolicy needs at least one platform")
        self.platform_names = list(platform_names)
        # encoding table: routing candidates first, then any foreign
        # names seeded via explicit assignments
        self._names: List[str] = list(self.platform_names)
        self._name_idx: Dict[str, int] = {
            n: i for i, n in enumerate(self._names)}
        self._interner = ClientInterner()
        self._assigned = np.full(0, -1, dtype=np.int64)
        self.default = default or self.platform_names[0]
        if self.default not in self.platform_names:
            raise ValueError(f"default platform {self.default!r} not in "
                             f"{self.platform_names}")
        if mode not in ("sticky", "round-robin", "random"):
            raise ValueError(f"unknown routing mode {mode!r}")
        self.mode = mode
        self._rr = 0
        self._rng = np.random.default_rng(seed)
        self._default_idx = self._name_idx[self.default]
        for cid, name in (assignment or {}).items():
            self._set_assignment(cid, name)

    # ---- array-backed sticky table -----------------------------------
    @property
    def assignment(self) -> _AssignmentView:
        return _AssignmentView(self)

    def _get_assignment(self, client_id: str) -> Optional[str]:
        i = self._interner.lookup(client_id)
        if i < 0 or i >= self._assigned.size:
            return None
        p = self._assigned[i]
        return self._names[int(p)] if p >= 0 else None

    def _set_assignment(self, client_id: str, name: str) -> None:
        pi = self._name_idx.get(name)
        if pi is None:                       # foreign name: extend encoding
            pi = len(self._names)
            self._names.append(name)
            self._name_idx[name] = pi
        i = self._interner.intern(client_id)
        if i >= self._assigned.size:
            self._assigned = grow_to(
                self._assigned, len(self._interner), fill=-1)
        self._assigned[i] = pi

    def route(self, client_id: str) -> str:
        name = self._get_assignment(client_id)
        if name is not None:
            return name
        if self.mode == "round-robin":
            name = self.platform_names[self._rr % len(self.platform_names)]
            self._rr += 1
        elif self.mode == "random":
            name = str(self._rng.choice(self.platform_names))
        else:
            name = self.default
        self._set_assignment(client_id, name)  # sticky from now on
        return name

    def prefill(self, client_ids: Sequence[str]) -> None:
        """Bulk-assign every unassigned client in one vectorized pass —
        the fleet-scale fast path for registering a whole pool up front.
        Per-client results are identical to repeated `route` calls; the
        ``random`` mode falls back to scalar draws to preserve the RNG
        stream."""
        idx = self._interner.indices_for(client_ids)
        self._assigned = grow_to(self._assigned, len(self._interner),
                                 fill=-1)
        need = idx[self._assigned[idx] < 0]
        if need.size == 0:
            return
        if self.mode == "round-robin":
            k = len(self.platform_names)
            self._assigned[need] = (self._rr + np.arange(need.size)) % k
            self._rr += int(need.size)
        elif self.mode == "random":
            for i in need:                   # stream parity with route()
                self._assigned[i] = self._name_idx[
                    str(self._rng.choice(self.platform_names))]
        else:
            self._assigned[need] = self._default_idx

    # ---- checkpoint surface (fl/checkpointing.py) --------------------
    def state_dict(self) -> dict:
        """JSON-ready snapshot of the mutable routing state (sticky
        assignments, rotation cursor, RNG stream)."""
        return {"assignment": dict(self.assignment._pairs()),
                "rr": self._rr,
                "rng": self._rng.bit_generator.state}

    def load_state_dict(self, state: dict) -> None:
        self._interner = ClientInterner()
        self._assigned = np.full(0, -1, dtype=np.int64)
        for cid, name in state.get("assignment", {}).items():
            self._set_assignment(cid, name)
        self._rr = int(state.get("rr", 0))
        if "rng" in state:
            self._rng.bit_generator.state = state["rng"]


class TelemetryRoutingPolicy(RoutingPolicy):
    """Routing that reacts to the fleet's trace telemetry.

    Reads the rolling per-platform failure/cold-start rates that a
    `TraceRecorder` (faas/trace.py) accumulates from the platforms' plan
    stream (attach the recorder to the platforms, e.g.
    `PlatformFleet.attach_recorder`) and scores each provider as

        score = failure_weight · recent_failure_rate
              + cold_weight · recent_cold_start_rate

    New clients are routed to the lowest-scoring provider (deterministic
    name tie-break).  Assignments stay sticky — warm pools keep their
    meaning — *unless* the assigned provider's score crosses
    `reroute_threshold` (e.g. a regional outage observed as repeated
    failures), in which case the client is re-routed to the current best
    provider and a ``route`` record is emitted.  Providers with fewer
    than `min_samples` recent attempts score 0 (no evidence ≠ bad).
    """

    def __init__(self, platform_names: Sequence[str], recorder,
                 assignment: Optional[Dict[str, str]] = None,
                 default: Optional[str] = None, seed: int = 0,
                 failure_weight: float = 1.0, cold_weight: float = 0.25,
                 reroute_threshold: float = 0.5, min_samples: int = 5):
        super().__init__(platform_names, assignment, default,
                         mode="sticky", seed=seed)
        self.recorder = recorder
        self.failure_weight = failure_weight
        self.cold_weight = cold_weight
        self.reroute_threshold = reroute_threshold
        self.min_samples = min_samples

    def _score(self, name: str, stats: Dict[str, dict]) -> float:
        s = stats.get(name)
        if not s or s["attempts"] < self.min_samples:
            return 0.0
        return (self.failure_weight * s["failure_rate"]
                + self.cold_weight * s["cold_rate"])

    def route(self, client_id: str) -> str:
        stats = self.recorder.platform_stats()
        assigned = self.assignment.get(client_id)
        if assigned is not None:
            if self._score(assigned, stats) < self.reroute_threshold:
                return assigned
            reason = "reroute"
        else:
            reason = "assign"
        best = min(self.platform_names,
                   key=lambda n: (self._score(n, stats), n))
        if assigned is not None and best == assigned:
            return assigned       # degraded, but still the least-bad option
        self.assignment[client_id] = best
        self.recorder.route(client_id, best, reason)
        return best


class PlatformFleet:
    """Named platforms + routing on one shared virtual clock."""

    def __init__(self, platforms: Dict[str, SimulatedFaaSPlatform],
                 routing: Optional[RoutingPolicy] = None):
        if not platforms:
            raise ValueError("PlatformFleet needs at least one platform")
        self.platforms = dict(platforms)
        self.routing = routing or RoutingPolicy(list(self.platforms))
        self.clock = VirtualClock()
        for p in self.platforms.values():
            p.clock = self.clock
        self._saved_failure_rates: Dict[str, float] = {}

    # ------------------------------------------------------------------
    @classmethod
    def from_profiles(cls, names: Optional[Iterable[str]] = None,
                      routing: Optional[RoutingPolicy] = None,
                      seed: int = 0) -> "PlatformFleet":
        """Build a fleet from the provider profile book (faas/profiles.py).

        Each platform gets a distinct RNG stream (seed + index) so
        provider timing draws are independent but reproducible.
        """
        from .profiles import PLATFORM_PROFILES   # circular-free at call time
        names = list(names) if names is not None else list(PLATFORM_PROFILES)
        platforms = {}
        for i, name in enumerate(names):
            prof = PLATFORM_PROFILES[name]
            platforms[name] = SimulatedFaaSPlatform(
                prof["faas"], prof["shape"], seed=seed + i, name=name)
        return cls(platforms, routing)

    # ------------------------------------------------------------------
    def platform_of(self, client_id: str) -> SimulatedFaaSPlatform:
        return self.platforms[self.routing.route(client_id)]

    def name_of(self, client_id: str) -> str:
        return self.routing.route(client_id)

    @property
    def default_platform(self) -> SimulatedFaaSPlatform:
        return self.platforms[self.routing.default]

    def attach_recorder(self, recorder) -> None:
        """Point every platform's plan telemetry at `recorder` (the
        routing policy may independently hold the same recorder)."""
        for p in self.platforms.values():
            p.recorder = recorder

    # ---- checkpoint surface (fl/checkpointing.py) --------------------
    def state_dict(self) -> dict:
        """Snapshot every platform's mutable state (RNG streams, warm
        pools, counters) plus the routing decisions — the multi-provider
        twin of `SimulatedFaaSPlatform.state_dict`.  The shared virtual
        clock is owned by the training driver's snapshot."""
        return {"platforms": {name: p.state_dict()
                              for name, p in self.platforms.items()},
                "routing": self.routing.state_dict()}

    def load_state_dict(self, state: dict) -> None:
        for name, pstate in state.get("platforms", {}).items():
            if name in self.platforms:
                self.platforms[name].load_state_dict(pstate)
        self.routing.load_state_dict(state.get("routing", {}))

    # ---- scenario knobs ----------------------------------------------
    def set_platform_down(self, name: str, down: bool = True) -> None:
        """Regional outage: every invocation on `name` fails (SLO → 0)."""
        p = self.platforms[name]
        if down:
            self._saved_failure_rates.setdefault(name, p.config.failure_rate)
            p.config = replace(p.config, failure_rate=1.0)
        elif name in self._saved_failure_rates:
            p.config = replace(
                p.config, failure_rate=self._saved_failure_rates.pop(name))

    # ---- fleet-wide telemetry ----------------------------------------
    @property
    def invocations(self) -> int:
        return sum(p.invocations for p in self.platforms.values())

    @property
    def cold_starts(self) -> int:
        return sum(p.cold_starts for p in self.platforms.values())

    def utilisation(self) -> Dict[str, Dict[str, int]]:
        return {name: {"invocations": p.invocations,
                       "cold_starts": p.cold_starts,
                       "warm_instances": p.warm_instance_count()}
                for name, p in self.platforms.items()}
