"""JAX device-safety rules: host syncs under jit, use-after-donate,
recompile hazards, and undeclared env gates.

These are the static twins of invariants the runtime only checks when a
test happens to drive the broken path: ``compile_count`` staying flat
(PR 8) detects a stray per-round ``jax.jit`` *after* it recompiled;
donation bugs surface as wrong numerics only when XLA actually reuses
the buffer; a ``float()`` inside a jitted body fails at trace time only
if that branch is traced.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..core import (FileContext, Finding, Project, Rule, call_name,
                    walk_scope)

# the env-gate registry module — the single place REPRO_* may be read
GATES_RELPATH = "analysis/gates.py"

# the mesh-axis vocabulary module — MESH_AXES is the declared set of
# axis names every mesh in the repo may use (JAX004 reads it by AST, so
# the lint engine never imports jax)
AXIS_RULES_RELPATH = "sharding/rules.py"

# wrapper entry points that donate caller buffers when donate=True;
# positions are the donated *positional* argument slots (mirrors
# donate_argnums on the jit twins in kernels/fed_agg.py)
DONATING_WRAPPERS: Dict[str, Tuple[int, ...]] = {
    "fed_agg": (0,),
    "fed_agg_apply": (0, 3, 4),
}

_HOST_SYNC_CALLS = {
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "jax.device_get", "onp.asarray", "onp.array",
}


def _is_jax_jit(node: ast.AST) -> bool:
    """The expression refers to jax.jit (or a bare jit import)."""
    dotted = (call_name(node) if isinstance(node, ast.Call)
              else None)
    if dotted is None:
        name = None
        if isinstance(node, ast.Attribute):
            parts: List[str] = []
            cur: ast.AST = node
            while isinstance(cur, ast.Attribute):
                parts.append(cur.attr)
                cur = cur.value
            if isinstance(cur, ast.Name):
                parts.append(cur.id)
                name = ".".join(reversed(parts))
        elif isinstance(node, ast.Name):
            name = node.id
        return name in ("jax.jit", "jit")
    return False


def _jit_call(node: ast.Call) -> bool:
    return call_name(node) in ("jax.jit", "jit")


def _partial_jit_decorator(dec: ast.AST) -> bool:
    """@functools.partial(jax.jit, ...) / @partial(jax.jit, ...)."""
    if not isinstance(dec, ast.Call):
        return False
    if call_name(dec) not in ("functools.partial", "partial"):
        return False
    return bool(dec.args) and _is_jax_jit(dec.args[0])


def _jitted_function_names(tree: ast.Module) -> Set[str]:
    """Function names that end up traced under jax.jit in this file:
    decorated defs, defs assigned through ``X = jax.jit(f, ...)``, and
    defs referenced anywhere inside a jax.jit(...) argument expression
    (covers ``jax.jit(jax.vmap(f, ...))``)."""
    defs = {n.name for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    jitted: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _is_jax_jit(dec) or _partial_jit_decorator(dec):
                    jitted.add(node.name)
        elif isinstance(node, ast.Call) and _jit_call(node):
            for arg in node.args:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Name) and sub.id in defs:
                        jitted.add(sub.id)
    return jitted


class HostSyncInJitRule(Rule):
    """JAX001: host synchronization inside a jit-traced function.

    ``float(x)`` / ``x.item()`` / ``np.asarray(x)`` on a traced value
    either fails at trace time (if that branch traces) or silently
    constant-folds a runtime value into the compiled program.  Hot paths
    must keep values on device; sync once, outside the jit.
    """

    id = "JAX001"
    name = "host-sync-in-jit"
    description = "float()/.item()/np.asarray inside a jitted function"

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        jitted = _jitted_function_names(ctx.tree)
        if not jitted:
            return
        for node in ast.walk(ctx.tree):
            if (not isinstance(node, (ast.FunctionDef,
                                      ast.AsyncFunctionDef))
                    or node.name not in jitted):
                continue
            for sub in ast.walk(node):
                if not isinstance(sub, ast.Call):
                    continue
                dotted = call_name(sub)
                if dotted in _HOST_SYNC_CALLS:
                    yield self.finding(
                        ctx, sub.lineno,
                        f"{dotted}() inside jitted `{node.name}` pulls "
                        f"the value to host; keep it on device (jnp)")
                elif (isinstance(sub.func, ast.Name)
                      and sub.func.id == "float" and sub.args
                      and not isinstance(sub.args[0], ast.Constant)):
                    yield self.finding(
                        ctx, sub.lineno,
                        f"float() inside jitted `{node.name}` forces a "
                        f"host sync (or a trace error); use "
                        f"jnp.float32/astype")
                elif (isinstance(sub.func, ast.Attribute)
                      and sub.func.attr == "item" and not sub.args):
                    yield self.finding(
                        ctx, sub.lineno,
                        f".item() inside jitted `{node.name}` forces a "
                        f"host sync; return the array and read it "
                        f"outside the jit")


def _donate_kwarg_active(node: ast.Call) -> bool:
    """donate=... present and not a literal False."""
    for kw in node.keywords:
        if kw.arg == "donate":
            return not (isinstance(kw.value, ast.Constant)
                        and kw.value.value is False)
    return False


def _donated_positions(node: ast.Call) -> Optional[Tuple[int, ...]]:
    """For a jax.jit(...) call: the donate_argnums value, if literal."""
    for kw in node.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for e in v.elts:
                    if (isinstance(e, ast.Constant)
                            and isinstance(e.value, int)):
                        out.append(e.value)
                return tuple(out)
    return None


class UseAfterDonateRule(Rule):
    """JAX002: reading a buffer after passing it at a donated position.

    Once a call donates an argument, XLA may have overwritten the buffer
    in place — any later read sees garbage *only on backends that honor
    donation*, so the bug passes every CPU test and corrupts results on
    TPU.  Covers twins created in-file via ``jax.jit(...,
    donate_argnums=...)`` and the exported kernels/fed_agg wrappers
    called with ``donate=True``.
    """

    id = "JAX002"
    name = "use-after-donate"
    description = "buffer read after being passed at a donated position"

    def _donating_callees(self, tree: ast.Module) -> Dict[str,
                                                          Tuple[int, ...]]:
        callees = dict(DONATING_WRAPPERS)
        for node in ast.walk(tree):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)
                    and _jit_call(node.value)):
                pos = _donated_positions(node.value)
                if pos:
                    callees[node.targets[0].id] = pos
        return callees

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        callees = self._donating_callees(ctx.tree)
        scopes: List[ast.AST] = [ctx.tree]
        scopes += [n for n in ast.walk(ctx.tree)
                   if isinstance(n, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))]
        for scope in scopes:
            yield from self._check_scope(ctx, scope, callees)

    def _check_scope(self, ctx: FileContext, scope: ast.AST,
                     callees: Dict[str, Tuple[int, ...]]
                     ) -> Iterator[Finding]:
        # this scope's own statements — nested defs are their own scopes
        nodes = list(walk_scope(scope))
        # (call start line, call end line, var name)
        donated: List[Tuple[int, int, str]] = []
        calls = [n for n in nodes if isinstance(n, ast.Call)]
        for node in calls:
            name = None
            if isinstance(node.func, ast.Name):
                name = node.func.id
            elif isinstance(node.func, ast.Attribute):
                name = node.func.attr
            if name not in callees:
                continue
            wrapper = name in DONATING_WRAPPERS
            if wrapper and not _donate_kwarg_active(node):
                continue
            for pos in callees[name]:
                if pos < len(node.args) and isinstance(node.args[pos],
                                                       ast.Name):
                    donated.append((node.lineno,
                                    node.end_lineno or node.lineno,
                                    node.args[pos].id))
        if not donated:
            return
        stores: List[Tuple[int, str]] = []
        loads: List[ast.Name] = []
        for node in nodes:
            if isinstance(node, ast.Name):
                if isinstance(node.ctx, ast.Store):
                    stores.append((node.lineno, node.id))
                elif isinstance(node.ctx, ast.Load):
                    loads.append(node)
        for call_line, call_end, var in donated:
            for load in loads:
                # reads inside the donating call's own span are the
                # donation itself, not a use-after
                if load.id != var or load.lineno <= call_end:
                    continue
                # a re-assignment between donation and read kills the
                # hazard — including `x = f(x)` reassigning on the
                # donating statement itself, the canonical pattern
                if any(call_line <= s_line <= load.lineno
                       for s_line, s_var in stores if s_var == var):
                    continue
                yield self.finding(
                    ctx, load.lineno,
                    f"`{var}` is read after being donated at line "
                    f"{call_line}; donated buffers may be overwritten "
                    f"in place on accelerator backends")
                break       # one finding per donated var is enough


class JitInRoundPathRule(Rule):
    """JAX003: ``jax.jit`` constructed inside a per-round call path.

    A fresh ``jax.jit`` object starts with an empty compile cache —
    building one per call retraces and recompiles every round, the exact
    hazard PR 8's ``compile_count`` counter only detects at runtime.
    Construction belongs at module scope or in ``__init__``; memoized
    builders need an explanatory pragma.
    """

    id = "JAX003"
    name = "jit-in-round-path"
    description = "jax.jit(...) constructed inside a function body"
    paths = ("core/", "fl/", "kernels/")

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        funcs = [n for n in ast.walk(ctx.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for fn in funcs:
            if fn.name == "__init__":       # construction-time is fine
                continue
            for node in walk_scope(fn):
                if isinstance(node, ast.Call) and _jit_call(node):
                    yield self.finding(
                        ctx, node.lineno,
                        f"jax.jit constructed inside `{fn.name}`; hoist "
                        f"to module scope / __init__, or memoize and "
                        f"pragma with the cache justification")


# collectives whose axis-name argument must come from the declared
# vocabulary; shard_map is handled separately (axis names live in its
# in_specs/out_specs PartitionSpecs)
_COLLECTIVE_CALLS = {
    "jax.lax.psum", "lax.psum", "psum",
    "jax.lax.pmean", "lax.pmean", "pmean",
    "jax.lax.pmax", "lax.pmax", "pmax",
    "jax.lax.pmin", "lax.pmin", "pmin",
    "jax.lax.all_gather", "lax.all_gather", "all_gather",
    "jax.lax.ppermute", "lax.ppermute", "ppermute",
    "jax.lax.axis_index", "lax.axis_index", "axis_index",
}

_SHARD_MAP_CALLS = {"shard_map", "jax.experimental.shard_map.shard_map",
                    "shd.shard_map"}


class UndeclaredMeshAxisRule(Rule):
    """JAX004: a mesh-axis literal outside the declared vocabulary.

    Every mesh this repo builds (launch/mesh.py) names its axes from
    ``sharding/rules.MESH_AXES``.  A ``shard_map`` spec or a collective
    (``psum``/``all_gather``/...) naming an axis *not* in that tuple is
    either a typo or a mesh the sharing rules (merge_spec, cohort_spec,
    batch_specs) know nothing about — both fail only at run time, on a
    multi-device host the CI tier may never provision.  Axis names that
    arrive through variables are out of scope (they were resolved from
    the declared constants already).
    """

    id = "JAX004"
    name = "undeclared-mesh-axis"
    description = ("shard_map/psum axis literal not declared in "
                   "sharding/rules.py MESH_AXES")

    def _declared_axes(self, project: Project) -> Set[str]:
        """AST-parse MESH_AXES from the project's sharding/rules.py:
        string elements directly, Name elements resolved against the
        module's own string-constant assignments (CLIENT_AXIS)."""
        for f in project.files:
            if not f.relpath.endswith(AXIS_RULES_RELPATH):
                continue
            consts: Dict[str, str] = {}
            for node in ast.walk(f.tree):
                if (isinstance(node, ast.Assign) and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, str)):
                    consts[node.targets[0].id] = node.value.value
            axes: Set[str] = set()
            for node in ast.walk(f.tree):
                target, value = None, None
                if (isinstance(node, ast.Assign) and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)):
                    target, value = node.targets[0].id, node.value
                elif (isinstance(node, ast.AnnAssign)
                        and isinstance(node.target, ast.Name)):
                    target, value = node.target.id, node.value
                if target != "MESH_AXES" or not isinstance(
                        value, (ast.Tuple, ast.List)):
                    continue
                for e in value.elts:
                    if (isinstance(e, ast.Constant)
                            and isinstance(e.value, str)):
                        axes.add(e.value)
                    elif isinstance(e, ast.Name) and e.id in consts:
                        axes.add(consts[e.id])
            return axes
        return set()

    @staticmethod
    def _axis_literals(expr: ast.AST) -> Iterator[ast.Constant]:
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                yield sub

    def _candidate_exprs(self, node: ast.Call) -> List[ast.AST]:
        """The expressions of this call that carry axis names."""
        dotted = call_name(node)
        if dotted in _SHARD_MAP_CALLS:
            exprs = [kw.value for kw in node.keywords
                     if kw.arg in ("in_specs", "out_specs")]
            # positional form: shard_map(f, mesh, in_specs, out_specs)
            exprs.extend(node.args[2:4])
            return exprs
        if dotted in _COLLECTIVE_CALLS:
            exprs = [kw.value for kw in node.keywords
                     if kw.arg == "axis_name"]
            pos = 0 if dotted.endswith("axis_index") else 1
            if len(node.args) > pos:
                exprs.append(node.args[pos])
            return exprs
        return []

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        if ctx.relpath.endswith(AXIS_RULES_RELPATH):
            return              # the vocabulary itself
        declared = self._declared_axes(project)
        seen: Set[Tuple[int, str]] = set()
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            for expr in self._candidate_exprs(node):
                for lit in self._axis_literals(expr):
                    axis = lit.value
                    if axis in declared or (lit.lineno, axis) in seen:
                        continue
                    seen.add((lit.lineno, axis))
                    yield self.finding(
                        ctx, lit.lineno,
                        f"mesh axis {axis!r} is not declared in "
                        f"sharding/rules.py MESH_AXES; add it to the "
                        f"vocabulary (or use the declared constant)")


class EnvGateRegistryRule(Rule):
    """GATE001: ``REPRO_*`` env access outside ``analysis/gates.py``.

    Scattered ``os.environ.get("REPRO_...")`` reads are how two call
    sites end up disagreeing about a default (import-time vs call-time
    reads of the same gate).  All gates live in the
    :mod:`repro.analysis.gates` registry; everything else imports it.
    """

    id = "GATE001"
    name = "env-gate-registry"
    description = "REPRO_* env access outside the analysis/gates registry"

    def _gate_name(self, node: ast.AST) -> Optional[str]:
        """The REPRO_* string touched by this expression, if any."""
        if isinstance(node, ast.Subscript):
            target = node.value
            key = node.slice
            if (isinstance(target, ast.Attribute)
                    and target.attr == "environ"
                    and isinstance(key, ast.Constant)
                    and isinstance(key.value, str)
                    and key.value.startswith("REPRO_")):
                return key.value
        if isinstance(node, ast.Call):
            dotted = call_name(node)
            if dotted in ("os.environ.get", "os.getenv",
                          "os.environ.setdefault", "os.environ.pop"):
                if (node.args and isinstance(node.args[0], ast.Constant)
                        and isinstance(node.args[0].value, str)
                        and node.args[0].value.startswith("REPRO_")):
                    return node.args[0].value
        return None

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        if ctx.relpath == GATES_RELPATH:
            return
        for node in ast.walk(ctx.tree):
            gate = self._gate_name(node)
            if gate:
                yield self.finding(
                    ctx, node.lineno,
                    f"direct env access to {gate}; read it through "
                    f"repro.analysis.gates (the documented registry)")


RULES = (HostSyncInJitRule(), UseAfterDonateRule(), JitInRoundPathRule(),
         UndeclaredMeshAxisRule(), EnvGateRegistryRule())
