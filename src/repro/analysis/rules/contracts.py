"""Contract rules: cross-file invariants the golden suites key on.

CON001 — every Pallas kernel entry point exported from
``kernels/__init__.py`` must have a pure-jnp oracle in ``kernels/ref.py``
and at least one test exercising both names (the allclose parity
surface; PRs 5/7 live and die by it).

CON002 — the dict literals each ``TraceRecorder`` sink emits must match
the key-set declared in ``RECORD_SCHEMAS`` (``faas/trace.py``): golden
trace tests compare *bytes*, so an undeclared key silently added to a
record invalidates every committed golden at once.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..core import (FileContext, Finding, Project, Rule,
                    walk_scope)

KERNELS_INIT = "kernels/__init__.py"
KERNELS_REF = "kernels/ref.py"
TRACE_MODULE = "faas/trace.py"

# __all__ entries that are not kernel entry points: constants
# (ALL_CAPS) and the oracle module itself
_NON_KERNEL_EXPORTS = {"ref"}


def _all_entries(tree: ast.Module) -> List[Tuple[str, int]]:
    """(name, lineno) for each string in the module's ``__all__``."""
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "__all__"
                and isinstance(node.value, (ast.List, ast.Tuple))):
            return [(e.value, e.lineno) for e in node.value.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str)]
    return []


class KernelOracleRule(Rule):
    """CON001: kernel entry points need an oracle and a parity test."""

    id = "CON001"
    name = "kernel-oracle-parity"
    description = ("every exported kernel needs a kernels/ref.py oracle "
                   "plus a test referencing both")

    def _oracle_for(self, kernel: str,
                    refs: Set[str]) -> Optional[str]:
        """Best oracle for ``kernel``: exact ``<base>_ref`` first, then
        the longest ``<prefix>_ref`` whose prefix the kernel name starts
        with (``topk_mask`` → ``topk_ref``, ``ssd_scan`` → ``ssd_ref``);
        ``_sharded`` variants parity-check against the unsharded oracle.
        """
        base = kernel[:-len("_sharded")] if kernel.endswith("_sharded") \
            else kernel
        if f"{base}_ref" in refs:
            return f"{base}_ref"
        best = None
        for r in refs:
            prefix = r[:-len("_ref")]
            if base.startswith(prefix):
                if best is None or len(prefix) > len(best) - len("_ref"):
                    best = r
        return best

    def check_project(self, project: Project) -> Iterator[Finding]:
        init_ctx = project.get(KERNELS_INIT)
        if init_ctx is None or init_ctx.tree is None:
            return
        entries = [(n, ln) for n, ln in _all_entries(init_ctx.tree)
                   if n not in _NON_KERNEL_EXPORTS and not n.isupper()]
        refs: Set[str] = set()
        ref_ctx = project.get(KERNELS_REF)
        if ref_ctx is not None and ref_ctx.tree is not None:
            refs = {n.name for n in ref_ctx.tree.body
                    if isinstance(n, ast.FunctionDef)
                    and n.name.endswith("_ref")}
        tests = project.test_sources()
        for kernel, lineno in entries:
            oracle = self._oracle_for(kernel, refs)
            if oracle is None:
                yield self.finding(
                    KERNELS_INIT, lineno,
                    f"kernel `{kernel}` has no oracle in kernels/ref.py "
                    f"(expected `{kernel}_ref` or a shared-prefix "
                    f"oracle)")
                continue
            if tests and not any(kernel in src and oracle in src
                                 for src in tests):
                yield self.finding(
                    KERNELS_INIT, lineno,
                    f"no test references both `{kernel}` and its oracle "
                    f"`{oracle}` — the parity surface is unguarded")


def _module_str_consts(tree: ast.Module) -> Dict[str, str]:
    consts: Dict[str, str] = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Constant)
                and isinstance(node.value.value, str)):
            consts[node.targets[0].id] = node.value.value
    return consts


def _resolve_key(node: ast.AST,
                 consts: Dict[str, str]) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.Name):
        return consts.get(node.id)
    return None


def _parse_schemas(tree: ast.Module, consts: Dict[str, str]
                   ) -> Optional[Dict[str, dict]]:
    """The ``RECORD_SCHEMAS`` dict literal, with REC_* names resolved."""
    for node in tree.body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "RECORD_SCHEMAS"
                and isinstance(node.value, ast.Dict)):
            continue
        schemas: Dict[str, dict] = {}
        for key_node, val_node in zip(node.value.keys,
                                      node.value.values):
            rec_type = _resolve_key(key_node, consts)
            if rec_type is None or not isinstance(val_node, ast.Dict):
                continue
            spec = {"required": set(), "optional": set(), "open": False}
            for k, v in zip(val_node.keys, val_node.values):
                field = _resolve_key(k, consts)
                if field in ("required", "optional"):
                    if isinstance(v, (ast.List, ast.Tuple, ast.Set)):
                        spec[field] = {
                            e.value for e in v.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str)}
                elif field == "open" and isinstance(v, ast.Constant):
                    spec["open"] = bool(v.value)
            schemas[rec_type] = spec
        return schemas
    return None


class TraceSchemaRule(Rule):
    """CON002: emitted trace-record key-sets match RECORD_SCHEMAS."""

    id = "CON002"
    name = "trace-record-schema"
    description = ("TraceRecorder record literals must match the "
                   "declared RECORD_SCHEMAS key-sets")
    paths = (TRACE_MODULE,)

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        consts = _module_str_consts(ctx.tree)
        schemas = _parse_schemas(ctx.tree, consts)
        if schemas is None:
            yield self.finding(
                ctx, 1,
                "faas/trace.py declares no RECORD_SCHEMAS — the golden "
                "tests key on exact record key-sets; declare them")
            return
        funcs = [n for n in ast.walk(ctx.tree)
                 if isinstance(n, (ast.FunctionDef,
                                   ast.AsyncFunctionDef))]
        for fn in funcs:
            yield from self._check_sink(ctx, fn, consts, schemas)

    def _record_literals(self, fn: ast.AST, consts: Dict[str, str]
                         ) -> Iterator[Tuple[str, Optional[str],
                                             ast.Dict]]:
        """(var name, record type, dict node) for each ``X = {...}`` or
        ``self._append({...})`` whose literal carries a "type" key."""
        for node in walk_scope(fn):
            dict_node, var = None, None
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Dict)):
                dict_node, var = node.value, node.targets[0].id
            elif (isinstance(node, ast.Call) and node.args
                    and isinstance(node.args[0], ast.Dict)):
                dict_node, var = node.args[0], ""
            if dict_node is None:
                continue
            rec_type = None
            for k, v in zip(dict_node.keys, dict_node.values):
                if _resolve_key(k, consts) == "type":
                    rec_type = _resolve_key(v, consts)
            if rec_type is not None:
                yield var, rec_type, dict_node

    def _check_sink(self, ctx: FileContext, fn: ast.AST,
                    consts: Dict[str, str],
                    schemas: Dict[str, dict]) -> Iterator[Finding]:
        for var, rec_type, dict_node in self._record_literals(fn,
                                                              consts):
            spec = schemas.get(rec_type)
            if spec is None:
                yield self.finding(
                    ctx, dict_node.lineno,
                    f"record type {rec_type!r} is emitted but not "
                    f"declared in RECORD_SCHEMAS")
                continue
            keys = {_resolve_key(k, consts)
                    for k in dict_node.keys} - {None, "type"}
            missing = spec["required"] - keys
            extra = keys - spec["required"] - spec["optional"]
            if missing:
                yield self.finding(
                    ctx, dict_node.lineno,
                    f"{rec_type!r} record is missing declared required "
                    f"keys: {sorted(missing)}")
            if extra:
                yield self.finding(
                    ctx, dict_node.lineno,
                    f"{rec_type!r} record writes undeclared keys "
                    f"{sorted(extra)} — declare them in RECORD_SCHEMAS "
                    f"(golden traces key on exact key-sets)")
            if not var:
                continue
            # conditional writes after the literal: rec["k"] = ...
            for node in walk_scope(fn):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Subscript)
                        and isinstance(node.targets[0].value, ast.Name)
                        and node.targets[0].value.id == var):
                    key = _resolve_key(node.targets[0].slice, consts)
                    if (key is not None and key != "type"
                            and key not in spec["required"]
                            and key not in spec["optional"]):
                        yield self.finding(
                            ctx, node.lineno,
                            f"{rec_type!r} record gains undeclared key "
                            f"{key!r}; declare it as optional in "
                            f"RECORD_SCHEMAS")
                elif (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "update"
                        and isinstance(node.func.value, ast.Name)
                        and node.func.value.id == var
                        and not spec["open"]):
                    yield self.finding(
                        ctx, node.lineno,
                        f"{rec_type!r} record takes open **extra but "
                        f"RECORD_SCHEMAS does not mark it open")


RULES = (KernelOracleRule(), TraceSchemaRule())
