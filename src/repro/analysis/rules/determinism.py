"""Determinism rules: the bug classes that silently break same-seed
byte-identical traces (the property every EUR/cost/time comparison and
every golden test in this repo rests on).
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from ..core import (FileContext, Finding, Project, Rule, call_name,
                    imported_module_aliases)

# stdlib-random functions that draw from (or reseed) the hidden global
# Mersenne state — anything here inside simulation code is a different
# run every time the import order or another caller changes
_STDLIB_DRAWS = {
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "betavariate",
    "expovariate", "triangular", "vonmisesvariate", "paretovariate",
    "lognormvariate", "weibullvariate", "getrandbits", "randbytes",
    "seed",
}

# np.random attributes that are *not* the legacy global-state API
_NP_RANDOM_OK = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937", "RandomState",
}

_WALLCLOCK_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "date.today", "datetime.date.today",
    "uuid.uuid1", "uuid.uuid4", "uuid4", "uuid1",
}


class UnseededRandomRule(Rule):
    """DET001: draws from a hidden global RNG stream.

    ``random.random()`` / ``np.random.rand()`` etc. consume global state
    whose sequence depends on every other caller in the process — two
    same-seed runs only stay byte-identical when every stream is an
    explicitly seeded ``np.random.default_rng(seed)`` / ``PRNGKey``.
    """

    id = "DET001"
    name = "unseeded-random"
    description = ("call into the global random/np.random state instead "
                   "of an explicitly seeded Generator")

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        random_aliases: Set[str] = imported_module_aliases(
            ctx.tree, "random")
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = call_name(node)
            if dotted is None:
                continue
            parts = dotted.split(".")
            # stdlib: random.<draw>()
            if (len(parts) == 2 and parts[0] in random_aliases
                    and parts[1] in _STDLIB_DRAWS):
                yield self.finding(
                    ctx, node.lineno,
                    f"{dotted}() draws from the process-global stdlib "
                    f"RNG; use a seeded np.random.default_rng / "
                    f"jax.random key instead")
            # numpy legacy global state: np.random.<fn>() — the
            # Generator construction surface is allowed
            if (len(parts) >= 3 and parts[-2] == "random"
                    and parts[0] in ("np", "numpy")
                    and parts[-1] not in _NP_RANDOM_OK):
                yield self.finding(
                    ctx, node.lineno,
                    f"{dotted}() uses numpy's legacy global RNG state; "
                    f"thread an explicit np.random.Generator through "
                    f"instead")


class WallClockRule(Rule):
    """DET002: wall-clock / uuid reads inside the simulation.

    Everything in ``faas/``, ``fl/`` and ``core/`` runs on the *virtual*
    clock — a single ``time.time()`` or ``uuid4()`` leaking into a
    record or a decision makes same-seed traces diverge byte-by-byte.
    (``launch/`` and benchmarks legitimately time walls; they are out of
    scope by path.)
    """

    id = "DET002"
    name = "wallclock-in-sim"
    description = ("wall-clock time / uuid read inside a virtual-clock "
                   "simulation path")
    paths = ("faas/", "fl/", "core/")

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = call_name(node)
            if dotted in _WALLCLOCK_CALLS:
                yield self.finding(
                    ctx, node.lineno,
                    f"{dotted}() reads the wall clock / host entropy in "
                    f"a simulation path; use the virtual clock (event "
                    f"time) or a seeded stream")


class BuiltinHashRule(Rule):
    """DET003: builtin ``hash()`` anywhere in ``src/``.

    ``hash(str)`` is salted per process (PYTHONHASHSEED), so any seed or
    key derived from it differs between runs — the exact bug PR 2 fixed
    by switching client seeds to crc32.  Use ``zlib.crc32`` /
    ``hashlib`` for stable derivation.
    """

    id = "DET003"
    name = "builtin-hash"
    description = "builtin hash() is salted per process; derive with crc32"

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "hash"):
                yield self.finding(
                    ctx, node.lineno,
                    "builtin hash() output changes with PYTHONHASHSEED; "
                    "use zlib.crc32 / hashlib for stable derivation")


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset")):
        return True
    # set ops on set expressions, e.g. set(a) - set(b)
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.Sub, ast.BitOr, ast.BitAnd, ast.BitXor)):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


class SetIterationRule(Rule):
    """DET004: iterating a set where order reaches an accumulator.

    Set iteration order depends on insertion history and the per-process
    hash seed; feeding it into any order-sensitive consumer (float
    accumulation, trace emission, cohort lists) is nondeterminism with a
    delay.  ``sorted(set(...))`` and membership tests are fine.
    """

    id = "DET004"
    name = "set-iteration-order"
    description = ("raw set iteration order is hash-seed dependent; "
                   "sort before iterating")
    paths = ("core/", "faas/", "fl/", "kernels/")

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                if _is_set_expr(node.iter):
                    yield self.finding(
                        ctx, node.lineno,
                        "for-loop iterates a set directly; wrap in "
                        "sorted() to pin the order")
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp,
                                   ast.DictComp, ast.SetComp)):
                for gen in node.generators:
                    # building another set from a set is order-free
                    if (_is_set_expr(gen.iter)
                            and not isinstance(node, ast.SetComp)):
                        yield self.finding(
                            ctx, node.lineno,
                            "comprehension iterates a set directly; "
                            "wrap in sorted() to pin the order")
            elif isinstance(node, ast.Call):
                fn = node.func
                if (isinstance(fn, ast.Name)
                        and fn.id in ("list", "tuple", "enumerate",
                                      "iter", "next")
                        and node.args and _is_set_expr(node.args[0])):
                    yield self.finding(
                        ctx, node.lineno,
                        f"{fn.id}(set) materializes hash-seed-dependent "
                        f"order; use sorted() instead")


RULES = (UnseededRandomRule(), WallClockRule(), BuiltinHashRule(),
         SetIterationRule())
