"""Rule registry: every shipped repro-lint rule, by family.

Adding a rule = subclass :class:`repro.analysis.core.Rule` in the
matching family module, instantiate it in that module's ``RULES`` tuple,
and add a known-bad fixture under ``tests/analysis_fixtures/`` (the
meta-test asserts every registered rule fires on the corpus).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from ..core import Rule
from .contracts import RULES as CONTRACT_RULES
from .determinism import RULES as DETERMINISM_RULES
from .jax_safety import RULES as JAX_SAFETY_RULES

ALL_RULES: Sequence[Rule] = (
    DETERMINISM_RULES + JAX_SAFETY_RULES + CONTRACT_RULES)

_BY_KEY: Dict[str, Rule] = {}
for _r in ALL_RULES:
    _BY_KEY[_r.id.lower()] = _r
    _BY_KEY[_r.name.lower()] = _r


def select_rules(spec: Optional[Sequence[str]] = None) -> List[Rule]:
    """Resolve ``--rules`` ids/slugs (None = everything)."""
    if not spec:
        return list(ALL_RULES)
    picked: List[Rule] = []
    for key in spec:
        rule = _BY_KEY.get(key.strip().lower())
        if rule is None:
            raise KeyError(
                f"unknown rule {key!r}; available: "
                + ", ".join(sorted({r.id for r in ALL_RULES})))
        if rule not in picked:
            picked.append(rule)
    return picked
