"""Central registry for the ``REPRO_*`` environment gates.

Every runtime kill switch the simulation honours is declared here, with
its default and what flipping it reverts.  All reads go through this
module — ``repro-lint``'s ``env-gate-registry`` rule (GATE001) flags any
``os.environ`` access to a ``REPRO_*`` name anywhere else in ``src/``,
so a new gate cannot be introduced without documenting it in ``GATES``.

Reads happen at *call* time (no import-time caching) so tests can flip a
gate per-case with ``monkeypatch.setenv`` and every consumer — the
aggregation default, the merge pipeline, the compressor, the device
pipeline — sees the same value.

Import discipline: this module depends only on the stdlib.  Simulation
packages (``core/``, ``faas/``, ``fl/``, ``kernels/``) import it at
module load, so it must never import the lint engine (or jax) back.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional

# names that tell the truth in an env listing: every gate is REPRO_*
AGG_KERNEL = "REPRO_AGG_KERNEL"
COMPRESS = "REPRO_COMPRESS"
DEVICE_PIPELINE = "REPRO_DEVICE_PIPELINE"
OVERLAP_DISPATCH = "REPRO_OVERLAP_DISPATCH"
PALLAS_INTERPRET = "REPRO_PALLAS_INTERPRET"


@dataclass(frozen=True)
class Gate:
    """One documented environment kill switch."""
    name: str
    default: Optional[str]      # value assumed when the var is unset
    doc: str


GATES: Dict[str, Gate] = {g.name: g for g in (
    Gate(AGG_KERNEL, "1",
         "Pallas fed_agg / fed_agg_apply aggregation kernels; 0 reverts "
         "to the tree_map reference path (core/aggregation.py, "
         "core/merge.py)."),
    Gate(COMPRESS, "1",
         "Client-update compression (top-k / int8 codecs with error "
         "feedback); 0 forces dense updates even when a scheme is "
         "configured (core/compress.py)."),
    Gate(DEVICE_PIPELINE, "1",
         "Device-resident round pipeline (zero-copy executor→merge "
         "handoff via DeviceUpdateBatch); 0 reverts every consumer to "
         "the legacy per-client materialize path "
         "(core/device_batch.py)."),
    Gate(OVERLAP_DISPATCH, "1",
         "Overlapped executor dispatch: the vectorized cohort training "
         "launch is not blocked on — results flow back as async "
         "DeviceUpdateBatch handles while event/trace/billing "
         "bookkeeping proceeds; 0 blocks until the device compute "
         "finishes before the round's events run (fl/executor.py). "
         "Byte-inert either way: virtual time never reads the wall "
         "clock."),
    Gate(PALLAS_INTERPRET, None,
         "Pallas interpret-mode override: 1 forces the interpreter, 0 "
         "forces Mosaic lowering; unset picks interpret on CPU and "
         "Mosaic on TPU (kernels/ops.py, read once at import)."),
)}


def raw(name: str) -> Optional[str]:
    """The gate's raw env value (or its declared default when unset).

    Raises ``KeyError`` for names not declared in ``GATES`` — reading an
    undeclared ``REPRO_*`` var is exactly the drift this registry
    exists to prevent.
    """
    gate = GATES[name]
    return os.environ.get(name, gate.default)


def enabled(name: str) -> bool:
    """Boolean gates follow one convention: anything but ``"0"`` is on."""
    return raw(name) != "0"


# ---- per-gate helpers (the call sites read as prose) -----------------
def agg_kernel_enabled() -> bool:
    return enabled(AGG_KERNEL)


def compress_enabled() -> bool:
    return enabled(COMPRESS)


def device_pipeline_enabled() -> bool:
    return enabled(DEVICE_PIPELINE)


def overlap_dispatch_enabled() -> bool:
    return enabled(OVERLAP_DISPATCH)


def pallas_interpret_override() -> Optional[bool]:
    """Three-state: None (backend decides) / True / False."""
    value = raw(PALLAS_INTERPRET)
    if value is None:
        return None
    return value != "0"
