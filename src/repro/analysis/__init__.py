"""repro-lint: determinism & device-safety static analysis.

The reproduction's headline claims (EUR/cost/time comparisons, donation
safety, recompile-free rounds, kernel↔oracle parity) all rest on
invariants that golden-trace tests can only check after the fact, and
only along the paths their inputs happen to exercise.  This package
checks the same invariants *statically*, at review time, across every
source file:

  determinism   unseeded RNG calls, wall-clock/uuid reads in simulation
                paths, builtin ``hash()`` in seed derivation, raw set
                iteration feeding order-sensitive accumulation
  jax-safety    host syncs inside ``jit``-ed functions, use-after-donate
                on buffers handed to the ``donate_argnums`` twins,
                ``jax.jit`` construction inside per-round call paths,
                ``REPRO_*`` env reads outside ``analysis/gates.py``
  contract      every Pallas kernel entry point needs a matching oracle
                in ``kernels/ref.py`` plus a test referencing both;
                ``TraceRecorder`` record key-sets must match the schema
                declared in ``faas/trace.py`` (golden tests key on them)

Run it with ``python -m repro.analysis`` (see ``__main__.py`` for the
CLI).  Suppress a single line with ``# repro-lint: disable=RULE``;
grandfather pre-existing findings via the committed ``baseline.json``.

This ``__init__`` stays import-light on purpose: simulation modules
import :mod:`repro.analysis.gates` (the env-gate registry) at module
load, and must not drag the lint engine in with it.
"""
from __future__ import annotations

__all__ = ["gates"]
