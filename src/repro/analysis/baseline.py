"""Baseline suppression: grandfathered findings, committed as JSON.

A finding's fingerprint is ``rule:path:crc32(stripped line):occurrence``
— keyed on the *content* of the flagged line rather than its number, so
unrelated edits that shift lines don't invalidate the baseline, while
editing the flagged line itself (the moment to actually fix it) does.

``baseline.json`` lives next to this module and is committed; CI fails
on any finding not in it.  Shrink it whenever you fix a grandfathered
finding — never grow it to sneak a new one past review.
"""
from __future__ import annotations

import json
from collections import Counter
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from .core import Finding, Project, line_fingerprint

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def fingerprints(project: Project,
                 findings: Sequence[Finding]) -> List[str]:
    """One stable fingerprint per finding (order-aligned)."""
    seen: Counter = Counter()
    out: List[str] = []
    for f in findings:
        ctx = project.get(f.path)
        crc = line_fingerprint(ctx, f.line) if ctx is not None else 0
        key = (f.rule, f.path, crc)
        out.append(f"{f.rule}:{f.path}:{crc:08x}:{seen[key]}")
        seen[key] += 1
    return out


def load(path: Optional[Path] = None) -> Dict[str, dict]:
    """fingerprint → recorded finding dict (empty when absent)."""
    p = Path(path) if path else DEFAULT_BASELINE
    if not p.is_file():
        return {}
    data = json.loads(p.read_text(encoding="utf-8"))
    return dict(data.get("findings", {}))


def write(path: Optional[Path], project: Project,
          findings: Sequence[Finding]) -> Path:
    p = Path(path) if path else DEFAULT_BASELINE
    entries = {
        fp: {"rule": f.rule, "path": f.path, "line": f.line,
             "message": f.message}
        for fp, f in zip(fingerprints(project, findings), findings)}
    payload = {
        "version": 1,
        "comment": ("grandfathered repro-lint findings; shrink when "
                    "fixing, never grow to bypass a new finding"),
        "findings": dict(sorted(entries.items())),
    }
    p.write_text(json.dumps(payload, indent=2, sort_keys=False) + "\n",
                 encoding="utf-8")
    return p


def partition(project: Project, findings: Sequence[Finding],
              baseline: Dict[str, dict]
              ) -> Tuple[List[Finding], List[Finding]]:
    """(new, grandfathered) split of ``findings`` against ``baseline``."""
    new: List[Finding] = []
    old: List[Finding] = []
    for fp, f in zip(fingerprints(project, findings), findings):
        (old if fp in baseline else new).append(f)
    return new, old
