"""repro-lint engine: file contexts, the Rule protocol, pragma filtering.

The engine is deliberately boring: walk ``.py`` files under a root,
parse each once into a :class:`FileContext`, hand every context to every
registered rule (``check_file``), then give project-level rules one shot
at the whole corpus (``check_project`` — used by the kernel↔oracle
contract, which must cross-reference ``kernels/__init__.py``,
``kernels/ref.py`` and the test suite).  Findings are filtered through
per-line ``# repro-lint: disable=RULE`` pragmas before they reach the
caller; baseline suppression lives in :mod:`repro.analysis.baseline`.

Paths are always reported relative to the scanned root (posix form), so
a rule scoped to e.g. ``faas/`` fires identically on ``src/repro/faas/``
and on a fixture corpus mirroring that layout.
"""
from __future__ import annotations

import ast
import re
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set

# severity is informational — any non-baselined finding fails the run
SEV_ERROR = "error"
SEV_WARNING = "warning"

_PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\- ]+)")

# directories never worth parsing
_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache"}


@dataclass(frozen=True)
class Finding:
    """One rule violation at a concrete source location."""
    rule: str                   # rule id, e.g. "DET001"
    name: str                   # rule slug, e.g. "unseeded-random"
    path: str                   # posix path relative to the scan root
    line: int                   # 1-based
    message: str
    severity: str = SEV_ERROR

    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "name": self.name, "path": self.path,
                "line": self.line, "message": self.message,
                "severity": self.severity}


def line_fingerprint(ctx: "FileContext", line: int) -> int:
    """CRC of the stripped source line — stable across pure renumbering
    (the baseline keys on it instead of the line number)."""
    text = ""
    if 1 <= line <= len(ctx.lines):
        text = ctx.lines[line - 1].strip()
    return zlib.crc32(text.encode("utf-8"))


class FileContext:
    """One parsed source file plus its pragma map."""

    def __init__(self, path: Path, relpath: str,
                 source: Optional[str] = None):
        self.path = path
        self.relpath = relpath
        self.source = (path.read_text(encoding="utf-8")
                       if source is None else source)
        self.lines = self.source.splitlines()
        self.syntax_error: Optional[SyntaxError] = None
        try:
            self.tree: Optional[ast.Module] = ast.parse(
                self.source, filename=str(path))
        except SyntaxError as exc:        # surfaced as its own finding
            self.tree = None
            self.syntax_error = exc
        self._pragmas: Dict[int, Set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _PRAGMA_RE.search(text)
            if m:
                self._pragmas[i] = {
                    p.strip().lower()
                    for p in m.group(1).split(",") if p.strip()}

    def suppressed(self, finding: Finding) -> bool:
        ids = self._pragmas.get(finding.line)
        if not ids:
            return False
        return ("all" in ids or finding.rule.lower() in ids
                or finding.name.lower() in ids)


@dataclass
class Project:
    """The full scanned corpus, handed to project-level rules."""
    root: Path                          # the scanned package root
    files: List[FileContext] = field(default_factory=list)
    # directory holding the test suite (None when scanning a corpus that
    # has no tests — contract rules then skip their test-coverage leg)
    tests_dir: Optional[Path] = None

    def get(self, relpath: str) -> Optional[FileContext]:
        for ctx in self.files:
            if ctx.relpath == relpath:
                return ctx
        return None

    def test_sources(self) -> List[str]:
        if self.tests_dir is None or not self.tests_dir.is_dir():
            return []
        return [p.read_text(encoding="utf-8")
                for p in sorted(self.tests_dir.glob("test_*.py"))]


class Rule:
    """Base rule: subclass and override ``check_file`` and/or
    ``check_project``.  ``id`` is the stable code (pragma/baseline key),
    ``name`` the human slug; ``paths`` restricts ``check_file`` to
    relpaths matching any of the given prefixes (empty = all files)."""

    id: str = ""
    name: str = ""
    description: str = ""
    severity: str = SEV_ERROR
    paths: Sequence[str] = ()

    def applies(self, relpath: str) -> bool:
        if not self.paths:
            return True
        return any(relpath == p or relpath.startswith(p)
                   for p in self.paths)

    def check_file(self, ctx: FileContext,
                   project: Project) -> Iterator[Finding]:
        return iter(())

    def check_project(self, project: Project) -> Iterator[Finding]:
        return iter(())

    # ---- helpers for subclasses --------------------------------------
    def finding(self, ctx_or_path, line: int, message: str) -> Finding:
        path = (ctx_or_path.relpath if isinstance(ctx_or_path, FileContext)
                else str(ctx_or_path))
        return Finding(rule=self.id, name=self.name, path=path, line=line,
                       message=message, severity=self.severity)


def iter_source_files(root: Path) -> Iterator[Path]:
    for path in sorted(root.rglob("*.py")):
        if any(part in _SKIP_DIRS for part in path.parts):
            continue
        yield path


def load_project(root: Path,
                 tests_dir: Optional[Path] = None) -> Project:
    root = root.resolve()
    project = Project(root=root, tests_dir=tests_dir)
    if root.is_file():
        project.files.append(
            FileContext(root, root.name))
        return project
    for path in iter_source_files(root):
        rel = path.relative_to(root).as_posix()
        project.files.append(FileContext(path, rel))
    return project


def run_rules(project: Project, rules: Iterable[Rule]) -> List[Finding]:
    """All non-pragma-suppressed findings, ordered by (path, line, rule)."""
    rules = list(rules)
    findings: List[Finding] = []
    for ctx in project.files:
        if ctx.syntax_error is not None:
            findings.append(Finding(
                rule="E000", name="syntax-error", path=ctx.relpath,
                line=ctx.syntax_error.lineno or 1,
                message=f"file does not parse: {ctx.syntax_error.msg}"))
            continue
        for rule in rules:
            if not rule.applies(ctx.relpath):
                continue
            for f in rule.check_file(ctx, project):
                if not ctx.suppressed(f):
                    findings.append(f)
    for rule in rules:
        for f in rule.check_project(project):
            ctx = project.get(f.path)
            if ctx is None or not ctx.suppressed(f):
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


# ---- shared AST utilities (used across rule modules) -----------------
def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def imported_module_aliases(tree: ast.Module, module: str) -> Set[str]:
    """Local names bound to ``module`` by a plain import / import-as."""
    aliases: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == module:
                    aliases.add(a.asname or a.name)
    return aliases


def call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)


def walk_scope(scope: ast.AST) -> Iterator[ast.AST]:
    """Nodes belonging to ``scope``'s own body, *excluding* nested
    function subtrees (which are their own scopes for rules that reason
    about one function at a time)."""
    stack = list(getattr(scope, "body", []))
    while stack:
        node = stack.pop()
        yield node
        # nested defs are yielded (callers may want the node itself)
        # but never descended into
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))
