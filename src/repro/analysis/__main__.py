"""repro-lint CLI: ``python -m repro.analysis [ROOT] [options]``.

Exit status: 0 when every finding is grandfathered in the baseline (or
there are none), 1 when new findings exist, 2 on usage errors.

Examples::

    python -m repro.analysis                      # lint src/repro
    python -m repro.analysis --format json        # machine-readable
    python -m repro.analysis --rules DET001,JAX002
    python -m repro.analysis --write-baseline     # grandfather the rest
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from . import baseline as baseline_mod
from .core import Finding, load_project, run_rules
from .rules import ALL_RULES, select_rules

_PKG_ROOT = Path(__file__).resolve().parents[1]      # src/repro


def _default_tests_dir(root: Path) -> Optional[Path]:
    """tests/ next to the src tree, when scanning the real package."""
    for candidate in (root.parent.parent / "tests",
                      root.parent / "tests"):
        if candidate.is_dir():
            return candidate
    return None


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=("repro-lint: determinism & device-safety static "
                     "analysis for the FedLesScan reproduction"))
    parser.add_argument(
        "root", nargs="?", default=str(_PKG_ROOT),
        help="directory (or single file) to scan [default: src/repro]")
    parser.add_argument(
        "--format", choices=("text", "json"), default="text")
    parser.add_argument(
        "--rules", default=None,
        help="comma-separated rule ids/slugs to run [default: all]")
    parser.add_argument(
        "--baseline", default=None,
        help=("baseline JSON path [default: the committed "
              "analysis/baseline.json]"))
    parser.add_argument(
        "--no-baseline", action="store_true",
        help="report every finding, ignoring the baseline")
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="grandfather all current findings into the baseline file")
    parser.add_argument(
        "--output", default=None,
        help="also write the report to this file")
    parser.add_argument(
        "--tests-dir", default=None,
        help=("test-suite directory for contract rules [default: "
              "auto-detected tests/ next to the scanned root]"))
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the rule catalog and exit")
    return parser


def _render_text(new: List[Finding], old: List[Finding],
                 rule_count: int) -> str:
    lines = [f"{f.location()}: {f.rule} ({f.name}) {f.message}"
             for f in new]
    lines.append(
        f"repro-lint: {len(new)} finding(s)"
        f"{f', {len(old)} baselined' if old else ''} "
        f"across {rule_count} rule(s)")
    return "\n".join(lines)


def _render_json(project, new: List[Finding], old: List[Finding],
                 rules) -> str:
    new_fps = baseline_mod.fingerprints(project, new)
    old_fps = baseline_mod.fingerprints(project, old)
    return json.dumps({
        "findings": [dict(f.to_dict(), fingerprint=fp)
                     for f, fp in zip(new, new_fps)],
        "baselined": [dict(f.to_dict(), fingerprint=fp)
                      for f, fp in zip(old, old_fps)],
        "summary": {
            "new": len(new), "baselined": len(old),
            "rules": sorted(r.id for r in rules),
            "files": len(project.files),
        },
    }, indent=2) + "\n"


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rule in ALL_RULES:
            scope = ", ".join(rule.paths) if rule.paths else "all files"
            print(f"{rule.id}  {rule.name:24s} [{scope}]  "
                  f"{rule.description}")
        return 0
    try:
        rules = select_rules(
            args.rules.split(",") if args.rules else None)
    except KeyError as exc:
        print(exc.args[0], file=sys.stderr)
        return 2

    root = Path(args.root)
    if not root.exists():
        print(f"no such path: {root}", file=sys.stderr)
        return 2
    tests_dir = (Path(args.tests_dir) if args.tests_dir
                 else _default_tests_dir(root.resolve()))
    project = load_project(root, tests_dir=tests_dir)
    findings = run_rules(project, rules)

    if args.write_baseline:
        path = baseline_mod.write(
            Path(args.baseline) if args.baseline else None,
            project, findings)
        print(f"wrote {len(findings)} finding(s) to {path}")
        return 0

    base = ({} if args.no_baseline
            else baseline_mod.load(
                Path(args.baseline) if args.baseline else None))
    new, old = baseline_mod.partition(project, findings, base)

    report = (_render_json(project, new, old, rules)
              if args.format == "json"
              else _render_text(new, old, len(rules)) + "\n")
    sys.stdout.write(report)
    if args.output:
        out = Path(args.output)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(report, encoding="utf-8")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
