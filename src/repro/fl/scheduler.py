"""Unified client-scheduling subsystem — every cohort decision in one place.

A `Scheduler` owns all client picking and the `TrainingDriver` consumes
one uniform surface in every mode:

* ``propose(pool, want, now, round_number, exclude=frozenset())`` —
  pick the next cohort (sync round cohorts, semi-async refills, and
  single-slot async rotation refills all go through this call).  The
  driver passes the *full* population plus an ``exclude`` set of
  in-flight clients, so no O(N) filtered pool list is materialized per
  refill; schedulers resolve exclusion against their interning tables
  as a vectorized mask.  Legacy schedulers without the ``exclude``
  parameter still get a pre-filtered pool (the driver sniffs the
  signature once).
* ``notify_finish`` / ``notify_miss`` — the driver's feedback channel:
  every observed completion, miss, or crash is reported back so
  behaviour-aware schedulers can adapt;
* ``cohort_size(round_number, telemetry)`` — how many clients the next
  round should invoke, given trailing `RoundStats` telemetry (the
  adaptive-sizing hook).

Shipped policies (``make_scheduler``):

``random``      uniform sampling (FedAvg/FedProx behaviour);
``fedlesscan``  the paper's Algorithm 2 tier selection (rookies →
                DBSCAN-clustered participants → stragglers), wrapping
                ``core.selection.select_clients``;
``apodotiko``   score-based probabilistic sampling (arXiv 2404.14033):
                a per-client score combining duration EMA, success
                rate, cold-start rate, and selection staleness feeds a
                softmax whose temperature anneals over rounds —
                explore early, exploit reliable clients late;
``adaptive``    cohort sizing driven by trailing EUR / straggler ratio
                (grow the cohort while updates land, shrink it while
                slots are being wasted), selection delegated to an
                inner scheduler;
``rotation``    the barrier-free driver's default: deterministic cyclic
                rotation with exponential (virtual-time) failure
                backoff.

Fleet scale: every per-client tally lives in a flat NumPy array keyed
by a `ClientInterner` index (core/interning.py) — Apodotiko scoring is
a handful of masked array expressions plus one weighted `rng.choice`,
and the rotation scan is a vectorized pass over the rolled order array.
The array paths replay the *exact* float op sequence and RNG stream of
the historical dict implementation, so same-seed cohorts are
byte-identical (gated by tests/test_fleet_scale.py golden traces).

Strategies keep working unchanged: ``Strategy.select`` is a shim that
delegates to the strategy's own scheduler (random for FedAvg-like
strategies, Algorithm 2 for FedLesScan, whole-pool for SAFA).
`state_dict`/`load_state_dict` round-trip scheduler state for the
round-tagged checkpoint/resume path (fl/checkpointing.py).
"""
from __future__ import annotations

import inspect
from typing import List, Optional, Sequence

import numpy as np

from ..core.features import ema_step, normalize01
from ..core.history import ClientHistoryDB
from ..core.interning import ClientInterner, grow_to
from ..core.selection import SelectionPlan, select_clients, select_random
from .metrics import TrailingMetricsCache

EMPTY = frozenset()

# pool size beyond which Apodotiko scoring switches to float32 passes —
# far above any byte-parity-gated run, so small-fleet cohorts stay
# bit-identical to the float64 reference
_SCORE_F32_MIN = 1 << 18


def _rng_state(rng: np.random.Generator) -> dict:
    return rng.bit_generator.state


def _set_rng_state(rng: np.random.Generator, state) -> None:
    # JSON round-trips tuple-typed entries as lists; numpy accepts dicts
    rng.bit_generator.state = state


def scheduler_supports_exclude(scheduler) -> bool:
    """Does `scheduler.propose` accept the `exclude` kwarg?  Legacy
    subclasses with the four-argument signature get the pre-filtered
    pool instead (the driver checks once, not per call)."""
    try:
        params = inspect.signature(scheduler.propose).parameters
    except (TypeError, ValueError):
        return False
    return ("exclude" in params
            or any(p.kind is p.VAR_KEYWORD for p in params.values()))


def _excluded_mask(interner: ClientInterner, pool_idx: np.ndarray,
                   exclude) -> Optional[np.ndarray]:
    """Boolean keep-mask over `pool_idx` (None = keep everything)."""
    if not exclude:
        return None
    lookup = interner.lookup
    ex = np.fromiter((lookup(c) for c in exclude), np.int64, len(exclude))
    ex = ex[ex >= 0]
    if ex.size == 0:
        return None
    return ~np.isin(pool_idx, ex)


class _ArrayMap:
    """Dict-like view over one per-client tally array.

    The array-backed schedulers store tallies as flat arrays; this view
    keeps the historical ``{client_id: value}`` read/write surface alive
    for tests and debugging.  An entry "exists" when its value differs
    from the column default (or when its paired seen-flag is set)."""

    __slots__ = ("_sched", "_attr", "_default", "_cast", "_seen_attr",
                 "_always")

    def __init__(self, sched, attr: str, default, cast, seen_attr=None,
                 always_present=False):
        self._sched = sched
        self._attr = attr
        self._default = default
        self._cast = cast
        self._seen_attr = seen_attr
        self._always = always_present

    def _present(self, i: int) -> bool:
        if self._always:
            return True
        if self._seen_attr is not None:
            return bool(getattr(self._sched, self._seen_attr)[i])
        return getattr(self._sched, self._attr)[i] != self._default

    def __getitem__(self, client_id: str):
        i = self._sched._interner.lookup(client_id)
        if i < 0 or not self._present(i):
            raise KeyError(client_id)
        return self._cast(getattr(self._sched, self._attr)[i])

    def get(self, client_id: str, default=None):
        try:
            return self[client_id]
        except KeyError:
            return default

    def __setitem__(self, client_id: str, value) -> None:
        i = self._sched._intern(client_id)
        getattr(self._sched, self._attr)[i] = value
        if self._seen_attr is not None:
            getattr(self._sched, self._seen_attr)[i] = True
        sync = getattr(self._sched, "_sync_rates", None)
        if sync is not None:            # keep derived mirrors coherent
            sync(i)

    def __contains__(self, client_id: str) -> bool:
        i = self._sched._interner.lookup(client_id)
        return i >= 0 and self._present(i)

    def _indices(self):
        return [i for i in range(len(self._sched._interner))
                if self._present(i)]

    def __iter__(self):
        ids = self._sched._interner.ids
        return iter([ids[i] for i in self._indices()])

    def __len__(self) -> int:
        return len(self._indices())

    def keys(self):
        return list(self)

    def values(self):
        arr = getattr(self._sched, self._attr)
        return [self._cast(arr[i]) for i in self._indices()]

    def items(self):
        ids = self._sched._interner.ids
        arr = getattr(self._sched, self._attr)
        return [(ids[i], self._cast(arr[i])) for i in self._indices()]


class Scheduler:
    """Base class: owns the RNG and the default (fixed) cohort size."""

    name = "base"

    def __init__(self, clients_per_round: int,
                 rng: Optional[np.random.Generator] = None, seed: int = 0):
        self.clients_per_round = clients_per_round
        self.rng = rng if rng is not None else np.random.default_rng(seed)

    # ---- the three-call protocol the TrainingDriver consumes ----------
    def propose(self, pool: Sequence[str], want: int, now: float,
                round_number: int, exclude=EMPTY) -> List[str]:
        """Pick up to `want` clients from `pool` minus `exclude` (the
        in-flight set; empty in barrier modes where the driver proposes
        whole cohorts at round start)."""
        raise NotImplementedError

    def notify_finish(self, client_id: str, now: float,
                      duration_s: float = 0.0, cold: bool = False,
                      late: bool = False) -> None:
        """A client's update physically arrived (possibly late)."""

    def notify_miss(self, client_id: str, now: float,
                    crashed: bool = True) -> None:
        """A client missed: `crashed` distinguishes terminal failures /
        unresponsive clients from merely-late or never-started ones."""

    def cohort_size(self, round_number: int, telemetry: Sequence) -> int:
        """How many clients the next round should invoke.  `telemetry`
        is the driver's trailing `RoundStats` window (may be empty)."""
        return self.clients_per_round

    # ---- trace + checkpoint surfaces ----------------------------------
    def decision_info(self) -> dict:
        """Extra payload for the last propose()'s `scheduling` record."""
        return {}

    def state_dict(self) -> dict:
        return {"rng": _rng_state(self.rng)}

    def load_state_dict(self, state: dict) -> None:
        if "rng" in state:
            _set_rng_state(self.rng, state["rng"])


class RandomScheduler(Scheduler):
    """Uniform random cohorts — FedAvg/FedProx selection."""

    name = "random"

    def __init__(self, clients_per_round: int,
                 rng: Optional[np.random.Generator] = None, seed: int = 0):
        super().__init__(clients_per_round, rng=rng, seed=seed)
        self._interner = ClientInterner()

    def propose(self, pool, want, now, round_number, exclude=EMPTY):
        if not exclude:
            return select_random(pool, want, self.rng)
        if not hasattr(pool, "__len__"):
            pool = list(pool)
        keep = _excluded_mask(self._interner,
                              self._interner.indices_for(pool), exclude)
        if keep is None:
            return select_random(pool, want, self.rng)
        positions = np.flatnonzero(keep)
        k = min(want, positions.size)
        pos = self.rng.choice(positions.size, size=k, replace=False)
        return [pool[int(i)] for i in positions[pos]]


class StrategySelectScheduler(Scheduler):
    """Adapter for legacy Strategy subclasses that override `select`
    directly (pre-scheduler API): `propose` calls the override, so a
    hand-written selection policy keeps winning over the strategy's
    default scheduler when the driver picks its cohorts.  Keeps the
    legacy four-argument signature — the driver pre-filters the pool."""

    name = "strategy-select"

    def __init__(self, strategy):
        super().__init__(strategy.config.clients_per_round,
                         rng=strategy.rng)
        self.strategy = strategy

    def propose(self, pool, want, now, round_number):
        return self.strategy.select(pool, round_number)


class FullPoolScheduler(Scheduler):
    """SAFA-style: invoke every eligible client, ignore `want` (the
    round then closes at the strategy's quorum)."""

    name = "full"

    def propose(self, pool, want, now, round_number, exclude=EMPTY):
        if exclude:
            return [c for c in pool if c not in exclude]
        return list(pool)


class FedLesScanScheduler(Scheduler):
    """Paper Algorithm 2 — tier selection over the behavioural history
    (rookies → clustered participants → stragglers)."""

    name = "fedlesscan"

    def __init__(self, clients_per_round: int, history: ClientHistoryDB,
                 max_rounds: int = 50, ema_alpha: float = 0.5,
                 rng: Optional[np.random.Generator] = None, seed: int = 0):
        super().__init__(clients_per_round, rng=rng, seed=seed)
        self.history = history
        self.max_rounds = max_rounds
        self.ema_alpha = ema_alpha
        self.last_plan: Optional[SelectionPlan] = None

    def propose(self, pool, want, now, round_number, exclude=EMPTY):
        plan = select_clients(self.history, pool, round_number,
                              self.max_rounds, want, self.rng,
                              ema_alpha=self.ema_alpha, exclude=exclude)
        self.last_plan = plan
        return plan.selected

    def decision_info(self):
        p = self.last_plan
        if p is None:
            return {}
        return {"rookies": len(p.rookies),
                "clustered": len(p.cluster_clients),
                "stragglers": len(p.straggler_clients),
                "n_clusters": p.n_clusters, "eps": p.eps}


class ApodotikoScheduler(Scheduler):
    """Score-based probabilistic sampling (Apodotiko, arXiv 2404.14033).

    Each client gets a score in [0, 1] from four behavioural terms::

        score = w_dur  · (1 − norm(durationEMA))     fast clients up
              + w_succ · successRate                  reliable clients up
              + w_cold · (1 − coldStartRate)          warm clients up
              + w_stale· norm(roundsSinceSelected)    ignored clients up

    Unseen clients score 1.0 (maximum) so every client is explored
    before the policy starts discriminating.  The cohort is sampled
    without replacement from ``softmax(score / T)`` with the temperature
    annealed geometrically over rounds (``T = max(T_min, T0·decay^t)``)
    — early rounds explore broadly, late rounds concentrate on the
    clients that kept delivering.

    All behavioural tallies are flat arrays over the scheduler's own
    interning table; one propose at 10⁶ clients is a few masked array
    expressions plus a single weighted sample.
    """

    name = "apodotiko"

    def __init__(self, clients_per_round: int,
                 rng: Optional[np.random.Generator] = None, seed: int = 0, *,
                 ema_alpha: float = 0.5, temperature: float = 0.35,
                 temperature_decay: float = 0.9,
                 min_temperature: float = 0.05,
                 w_duration: float = 0.3, w_success: float = 0.4,
                 w_cold: float = 0.1, w_staleness: float = 0.2):
        super().__init__(clients_per_round, rng=rng, seed=seed)
        self.ema_alpha = ema_alpha
        self.temperature = temperature
        self.temperature_decay = temperature_decay
        self.min_temperature = min_temperature
        self.weights = (w_duration, w_success, w_cold, w_staleness)
        # behavioural tallies, fed exclusively by the notify hooks
        self._interner = ClientInterner()
        self._alloc(0)
        self._last_stats: Optional[dict] = None

    def _alloc(self, n: int) -> None:
        self._dur = np.zeros(n, np.float64)       # duration EMA
        self._seen = np.zeros(n, bool)            # has a duration EMA
        self._obs = np.zeros(n, np.int64)         # resolved invocations
        self._succ = np.zeros(n, np.int64)
        self._fin = np.zeros(n, np.int64)         # cold-rate denominator
        self._cold = np.zeros(n, np.int64)
        self._last_sel = np.full(n, -1, np.int64)
        # derived float32 mirrors for the fleet-scale scoring path —
        # maintained per event (O(1)), rebuilt wholesale on state load,
        # never checkpointed.  Defaults match the scoring identities:
        # success rate 1 while unobserved, cold rate 0 while unfinished.
        self._dur32 = np.zeros(n, np.float32)
        self._rate_succ = np.ones(n, np.float32)
        self._rate_cold = np.zeros(n, np.float32)
        self._iota = np.arange(n)

    def _capacity(self) -> None:
        n = len(self._interner)
        if n > self._dur.shape[0]:
            self._dur = grow_to(self._dur, n, fill=0.0)
            self._seen = grow_to(self._seen, n, fill=False)
            self._obs = grow_to(self._obs, n)
            self._succ = grow_to(self._succ, n)
            self._fin = grow_to(self._fin, n)
            self._cold = grow_to(self._cold, n)
            self._last_sel = grow_to(self._last_sel, n, fill=-1)
            self._dur32 = grow_to(self._dur32, n, fill=0.0)
            self._rate_succ = grow_to(self._rate_succ, n, fill=1.0)
            self._rate_cold = grow_to(self._rate_cold, n, fill=0.0)
            if self._dur.shape[0] > self._iota.shape[0]:
                self._iota = np.arange(self._dur.shape[0])

    def _intern(self, client_id: str) -> int:
        i = self._interner.intern(client_id)
        self._capacity()
        return i

    # ---- feedback -----------------------------------------------------
    def notify_finish(self, client_id, now, duration_s=0.0, cold=False,
                      late=False):
        i = self._intern(client_id)
        # a late arrival is the second half of an invocation the deadline
        # already reported through notify_miss — it contributes duration /
        # cold-start data but not a second resolved-invocation observation
        # (else chronic-but-productive stragglers are double-penalized)
        if not late:
            self._obs[i] += 1
            self._succ[i] += 1
        self._fin[i] += 1
        if cold:
            self._cold[i] += 1
        prev = float(self._dur[i]) if self._seen[i] else None
        self._dur[i] = ema_step(prev, duration_s, self.ema_alpha)
        self._seen[i] = True
        self._sync_rates(i)

    def notify_miss(self, client_id, now, crashed=True):
        i = self._intern(client_id)     # intern first: it may grow _obs
        self._obs[i] += 1
        self._sync_rates(i)

    def _sync_rates(self, i: int) -> None:
        """Refresh one row of the float32 scoring mirrors (same rounding
        as casting the int-tally divisions, so the mirror path scores
        exactly what the on-the-fly float32 path would)."""
        self._dur32[i] = self._dur[i]
        obs = self._obs[i]
        if obs > 0:
            self._rate_succ[i] = self._succ[i] / obs
        fin = self._fin[i]
        if fin > 0:
            self._rate_cold[i] = self._cold[i] / fin

    def _rebuild_rates(self) -> None:
        """Vectorized mirror rebuild after a bulk state load."""
        self._dur32 = self._dur.astype(np.float32)
        n = self._dur.shape[0]
        rs = np.ones(n, np.float32)
        np.divide(self._succ, self._obs, out=rs, where=self._obs > 0)
        rc = np.zeros(n, np.float32)
        np.divide(self._cold, self._fin, out=rc, where=self._fin > 0)
        self._rate_succ, self._rate_cold = rs, rc

    # ---- dict-like views (historical debug/test surface) --------------
    @property
    def _duration_ema(self):
        return _ArrayMap(self, "_dur", 0.0, float, seen_attr="_seen")

    @property
    def _observations(self):
        return _ArrayMap(self, "_obs", 0, int)

    @property
    def _successes(self):
        return _ArrayMap(self, "_succ", 0, int)

    @property
    def _finishes(self):
        return _ArrayMap(self, "_fin", 0, int)

    @property
    def _cold_starts(self):
        return _ArrayMap(self, "_cold", 0, int)

    @property
    def _last_selected(self):
        return _ArrayMap(self, "_last_sel", -1, int)

    # ---- scoring ------------------------------------------------------
    def _scores(self, idx, round_number: int) -> np.ndarray:
        if not isinstance(idx, np.ndarray):       # id sequence (tests)
            idx = self._interner.indices_for(list(idx))
            self._capacity()
        n = idx.size
        if n > _SCORE_F32_MIN:
            return self._scores_f32(idx, round_number)
        w_dur, w_succ, w_cold, w_stale = self.weights
        seen, dur = self._seen[idx], self._dur[idx]
        n_succ, obs = self._succ[idx], self._obs[idx]
        fin, n_cold = self._fin[idx], self._cold[idx]
        last = self._last_sel[idx]
        dur_norm = normalize01(dur, mask=seen)
        succ = np.ones(n, np.float64)
        np.divide(n_succ, obs, out=succ, where=obs > 0)
        cold = np.zeros(n, np.float64)
        np.divide(n_cold, fin, out=cold, where=fin > 0)
        stale_norm = normalize01((round_number - last).astype(np.float64))
        # same left-associative sum as the spelled-out expression, built
        # in place to avoid a chain of n-sized temporaries
        scores = 1.0 - dur_norm
        scores *= w_dur
        succ *= w_succ
        scores += succ
        np.subtract(1.0, cold, out=cold)
        cold *= w_cold
        scores += cold
        stale_norm *= w_stale
        scores += stale_norm
        # rookies (never resolved): maximum score — explore them first
        scores[obs == 0] = 1.0
        return scores

    def _scores_f32(self, idx: np.ndarray, round_number: int) -> np.ndarray:
        """Fleet-scale scoring: float32 passes over the maintained
        mirrors, slice views when the pool is the whole registry.  Scores
        only rank clients for a softmax draw, so float32 precision is
        immaterial; small fleets never reach this path, keeping the
        byte-parity float64 behaviour."""
        w_dur, w_succ, w_cold, w_stale = self.weights
        n = idx.size
        if (n == len(self._interner) and n > 0 and idx[0] == 0
                and idx[n - 1] == n - 1
                and bool((idx == self._iota[:n]).all())):
            seen = self._seen[:n]
            dur32, obs = self._dur32[:n], self._obs[:n]
            succ_rate, cold_rate = self._rate_succ[:n], self._rate_cold[:n]
            last = self._last_sel[:n]
        else:
            seen = self._seen[idx]
            dur32, obs = self._dur32[idx], self._obs[idx]
            succ_rate, cold_rate = self._rate_succ[idx], self._rate_cold[idx]
            last = self._last_sel[idx]
        dur_norm = normalize01(dur32, mask=seen, dtype=np.float32)
        stale_norm = normalize01(round_number - last.astype(np.float32),
                                 dtype=np.float32)
        # left-associative weighted sum, in place; the mirrors are store
        # state so every term that touches them makes a fresh array first
        scores = 1.0 - dur_norm
        scores *= w_dur
        scores += succ_rate * np.float32(w_succ)
        tmp = 1.0 - cold_rate
        tmp *= w_cold
        scores += tmp
        stale_norm *= w_stale
        scores += stale_norm
        scores[obs == 0] = 1.0      # rookies: maximum score, explore first
        return scores

    def propose(self, pool, want, now, round_number, exclude=EMPTY):
        if not hasattr(pool, "__len__"):
            pool = list(pool)
        pool_idx = self._interner.indices_for(pool)
        self._capacity()
        keep = _excluded_mask(self._interner, pool_idx, exclude)
        if keep is None:
            idx, positions = pool_idx, None
        else:
            idx, positions = pool_idx[keep], np.flatnonzero(keep)
        k = min(want, idx.size)
        if k <= 0:
            return []
        scores = self._scores(idx, round_number)
        t = max(self.min_temperature,
                self.temperature * self.temperature_decay ** round_number)
        logits = scores / t
        logits -= logits.max()
        probs = np.exp(logits, out=logits)      # same values, no n-temp
        if probs.dtype != np.float64:           # float32 scoring path:
            probs = probs.astype(np.float64)    # Generator.choice checks
        probs /= probs.sum()                    # sum(p)=1 in float64
        pos = self.rng.choice(idx.size, size=k, replace=False, p=probs)
        self._last_sel[idx[pos]] = round_number
        self._last_stats = {"score_min": float(scores.min()),
                            "score_max": float(scores.max()),
                            "score_mean": float(scores.mean())}
        if positions is not None:
            pos = positions[pos]
        return [pool[int(i)] for i in pos]

    def decision_info(self):
        return dict(self._last_stats) if self._last_stats else {}

    # ---- checkpoint surface (JSON shape matches the dict-era state) ---
    def _emit(self, array: np.ndarray, mask: np.ndarray, cast) -> dict:
        ids = self._interner.ids
        return {ids[i]: cast(array[i]) for i in np.flatnonzero(mask)}

    def state_dict(self):
        state = super().state_dict()
        n = len(self._interner)
        sl = slice(0, n)
        state.update(
            duration_ema=self._emit(self._dur, self._seen[sl], float),
            observations=self._emit(self._obs, self._obs[sl] > 0, int),
            successes=self._emit(self._succ, self._succ[sl] > 0, int),
            finishes=self._emit(self._fin, self._fin[sl] > 0, int),
            cold_starts=self._emit(self._cold, self._cold[sl] > 0, int),
            last_selected=self._emit(self._last_sel,
                                     self._last_sel[sl] >= 0, int))
        return state

    def load_state_dict(self, state):
        super().load_state_dict(state)
        fields = (("duration_ema", "_dur"), ("observations", "_obs"),
                  ("successes", "_succ"), ("finishes", "_fin"),
                  ("cold_starts", "_cold"), ("last_selected", "_last_sel"))
        self._interner = ClientInterner()
        for key, _ in fields:
            self._interner.intern_many(list(state.get(key, {})))
        self._alloc(0)
        self._capacity()
        for key, attr in fields:
            arr = getattr(self, attr)
            for cid, val in state.get(key, {}).items():
                arr[self._interner.index_of(cid)] = val
        for cid in state.get("duration_ema", {}):
            self._seen[self._interner.index_of(cid)] = True
        self._rebuild_rates()


class AdaptiveScheduler(Scheduler):
    """Adaptive cohort sizing over an inner selection policy.

    Reads the trailing `RoundStats` window: while the effective update
    ratio stays high (slots are not being wasted) the cohort grows one
    client per round toward `max_cohort`; when EUR drops or the
    straggler ratio spikes it shrinks toward `min_cohort` — spending
    invocations where they convert into updates.  The trailing metrics
    are memoized on the window's identity (`TrailingMetricsCache`), so
    repeated `cohort_size` calls against an unchanged telemetry window
    don't recompute them.
    """

    name = "adaptive"

    def __init__(self, clients_per_round: int,
                 rng: Optional[np.random.Generator] = None, seed: int = 0, *,
                 inner: Optional[Scheduler] = None,
                 min_cohort: Optional[int] = None,
                 max_cohort: Optional[int] = None, low_eur: float = 0.6,
                 high_eur: float = 0.95, straggler_cap: float = 0.4,
                 window: int = 3):
        super().__init__(clients_per_round, rng=rng, seed=seed)
        self.inner = inner or RandomScheduler(clients_per_round, rng=self.rng)
        self._inner_excludes = scheduler_supports_exclude(self.inner)
        self.min_cohort = (min_cohort if min_cohort is not None
                           else max(2, clients_per_round // 2))
        self.max_cohort = max_cohort or 2 * clients_per_round
        self.low_eur = low_eur
        self.high_eur = high_eur
        self.straggler_cap = straggler_cap
        self.window = window
        self._trailing = TrailingMetricsCache(window)
        self._size = clients_per_round

    def cohort_size(self, round_number, telemetry):
        if telemetry:
            eur, straggling = self._trailing.compute(telemetry)
            if eur <= self.low_eur or straggling >= self.straggler_cap:
                self._size = max(self.min_cohort, self._size - 1)
            elif eur >= self.high_eur:
                self._size = min(self.max_cohort, self._size + 1)
        return self._size

    def propose(self, pool, want, now, round_number, exclude=EMPTY):
        if self._inner_excludes:
            return self.inner.propose(pool, want, now, round_number,
                                      exclude=exclude)
        if exclude:
            pool = [c for c in pool if c not in exclude]
        return self.inner.propose(pool, want, now, round_number)

    def notify_finish(self, client_id, now, **kwargs):
        self.inner.notify_finish(client_id, now, **kwargs)

    def notify_miss(self, client_id, now, crashed=True):
        self.inner.notify_miss(client_id, now, crashed=crashed)

    def decision_info(self):
        info = {"cohort": self._size}
        info.update(self.inner.decision_info())
        return info

    def state_dict(self):
        state = super().state_dict()
        state["size"] = self._size
        state["inner"] = self.inner.state_dict()
        return state

    def load_state_dict(self, state):
        super().load_state_dict(state)
        self._size = int(state.get("size", self._size))
        self.inner.load_state_dict(state.get("inner", {}))


class RotationScheduler(Scheduler):
    """Barrier-free rotation — the async driver's default policy.

    Deterministic cyclic rotation over the whole population, skipping
    clients outside the eligible pool (in flight) and clients in
    failure backoff; when every eligible client is cooling down, the
    first one is probed anyway.  A crashed/failing client's cooldown
    doubles per consecutive failure (the async twin of the paper's
    Eq. 1) and resets when an update of theirs finally arrives.

    The rotation is an index array plus a cursor; each pick is one
    vectorized scan over the rolled order (semantically identical to
    the historical deque walk, including cursor advancement: the
    cursor moves one slot per inspected client, and a full fruitless
    scan leaves it in place).
    """

    name = "rotation"

    def __init__(self, clients_per_round: int, client_ids: Sequence[str],
                 timeout_s: float = 120.0,
                 rng: Optional[np.random.Generator] = None, seed: int = 0):
        super().__init__(clients_per_round, rng=rng, seed=seed)
        self.timeout_s = timeout_s
        self._interner = ClientInterner()
        self._set_rotation(list(client_ids))

    def _set_rotation(self, client_ids: Sequence[str]) -> None:
        self._order = self._interner.intern_many(client_ids)
        self._cursor = 0
        n = len(self._interner)
        self._streak = np.zeros(n, np.int64)
        self._cool = np.zeros(n, np.float64)

    def _capacity(self) -> None:
        n = len(self._interner)
        if n > self._streak.shape[0]:
            self._streak = grow_to(self._streak, n)
            self._cool = grow_to(self._cool, n, fill=0.0)

    def _intern(self, client_id: str) -> int:
        i = self._interner.intern(client_id)
        self._capacity()
        return i

    # ---- dict-like views (historical debug/test surface) --------------
    @property
    def _fail_streak(self):
        return _ArrayMap(self, "_streak", 0, int, always_present=True)

    @property
    def _cooldown_until(self):
        return _ArrayMap(self, "_cool", 0.0, float)

    def _next(self, elig: np.ndarray, now: float) -> Optional[int]:
        order, c = self._order, self._cursor
        n = order.size
        rolled = np.concatenate((order[c:], order[:c]))
        emask = elig[rolled]
        ready = emask & (self._cool[rolled] <= now)
        if ready.any():
            j = int(ready.argmax())
            self._cursor = (c + j + 1) % n      # one rotation per inspection
            return int(rolled[j])
        if emask.any():
            # everyone eligible is cooling down: probe the first anyway
            # (a full scan happened — the cursor ends where it started)
            return int(rolled[int(emask.argmax())])
        return None

    def propose(self, pool, want, now, round_number, exclude=EMPTY):
        if self._order.size == 0 or want <= 0:
            return []
        if not hasattr(pool, "__len__"):
            pool = list(pool)
        pool_idx = self._interner.indices_for(pool)
        self._capacity()
        elig = np.zeros(len(self._interner), bool)
        elig[pool_idx] = True
        if exclude:
            lookup = self._interner.lookup
            for cid in exclude:
                i = lookup(cid)
                if i >= 0:
                    elig[i] = False
        # One vectorized pass builds the order-space candidate sets; each
        # pick is then a binary search from the cursor instead of an
        # O(n) roll per pick (`_next`), with identical semantics: `used`
        # holds this propose's picks, and skipping them costs at most
        # `want` steps since candidate arrays are sorted.
        order = self._order
        n = order.size
        emask = elig[order]
        ready_pos = np.flatnonzero(emask & (self._cool[order] <= now))
        elig_pos = np.flatnonzero(emask)
        used: set = set()

        def first_from(pos: np.ndarray, c: int) -> Optional[int]:
            m = pos.size
            if m == 0:
                return None
            j = int(np.searchsorted(pos, c))
            for k in range(m):
                p = int(pos[(j + k) % m])
                if p not in used:
                    return p
            return None

        ids = self._interner.ids
        out: List[str] = []
        for _ in range(want):
            p = first_from(ready_pos, self._cursor)
            if p is not None:
                self._cursor = (p + 1) % n    # one rotation per inspection
            else:
                # everyone eligible is cooling down: probe the first
                # anyway (full fruitless scan — cursor stays put)
                p = first_from(elig_pos, self._cursor)
                if p is None:
                    break
            used.add(p)
            out.append(ids[int(order[p])])
        return out

    def notify_finish(self, client_id, now, duration_s=0.0, cold=False,
                      late=False):
        i = self._intern(client_id)
        self._streak[i] = 0
        self._cool[i] = 0.0

    def notify_miss(self, client_id, now, crashed=True):
        if not crashed:
            return      # late-but-alive clients are not penalized
        i = self._intern(client_id)
        streak = int(self._streak[i]) + 1
        self._streak[i] = streak
        self._cool[i] = now + self.timeout_s * 2.0 ** (streak - 1)

    def state_dict(self):
        state = super().state_dict()
        order = np.concatenate((self._order[self._cursor:],
                                self._order[:self._cursor]))
        ids = self._interner.ids
        n = len(ids)
        state.update(
            rotation=[ids[i] for i in order],
            fail_streak={ids[i]: int(self._streak[i])
                         for i in np.flatnonzero(self._streak[:n] > 0)},
            cooldown_until={ids[i]: float(self._cool[i])
                            for i in np.flatnonzero(self._cool[:n] > 0.0)})
        return state

    def load_state_dict(self, state):
        super().load_state_dict(state)
        if "rotation" in state:
            self._set_rotation(list(state["rotation"]))
        else:
            self._streak[:] = 0
            self._cool[:] = 0.0
        for cid, streak in state.get("fail_streak", {}).items():
            self._streak[self._intern(cid)] = int(streak)
        for cid, until in state.get("cooldown_until", {}).items():
            self._cool[self._intern(cid)] = float(until)


SCHEDULERS = {cls.name: cls for cls in
              (RandomScheduler, FullPoolScheduler, FedLesScanScheduler,
               ApodotikoScheduler, AdaptiveScheduler, RotationScheduler)}


def make_scheduler(name: str, clients_per_round: int, *,
                   history: Optional[ClientHistoryDB] = None,
                   max_rounds: int = 50, ema_alpha: float = 0.5,
                   client_ids: Optional[Sequence[str]] = None,
                   timeout_s: float = 120.0,
                   rng: Optional[np.random.Generator] = None,
                   seed: int = 0, **kwargs) -> Scheduler:
    """Factory for the shipped scheduling policies."""
    if name not in SCHEDULERS:
        raise ValueError(f"unknown scheduler {name!r}; "
                         f"available: {sorted(SCHEDULERS)}")
    if name == "fedlesscan":
        if history is None:
            raise ValueError("the fedlesscan scheduler needs a "
                             "ClientHistoryDB (history=...)")
        return FedLesScanScheduler(clients_per_round, history,
                                   max_rounds=max_rounds,
                                   ema_alpha=ema_alpha, rng=rng, seed=seed)
    if name == "rotation":
        return RotationScheduler(clients_per_round, client_ids or [],
                                 timeout_s=timeout_s, rng=rng, seed=seed)
    return SCHEDULERS[name](clients_per_round, rng=rng, seed=seed, **kwargs)
