"""Unified client-scheduling subsystem — every cohort decision in one place.

Before this module, client picking was smeared across three layers:
per-strategy ``Strategy.select`` overrides, Algorithm 2 in
``core/selection.py``, and the async rotation + failure backoff
hard-coded in the training driver.  A `Scheduler` now owns *all* of it,
and the `TrainingDriver` consumes one uniform surface in every mode:

* ``propose(pool, want, now, round_number)`` — pick the next cohort
  (sync round cohorts, semi-async refills, and single-slot async
  rotation refills all go through this call);
* ``notify_finish`` / ``notify_miss`` — the driver's feedback channel:
  every observed completion, miss, or crash is reported back so
  behaviour-aware schedulers can adapt;
* ``cohort_size(round_number, telemetry)`` — how many clients the next
  round should invoke, given trailing `RoundStats` telemetry (the
  adaptive-sizing hook).

Shipped policies (``make_scheduler``):

``random``      uniform sampling (FedAvg/FedProx behaviour);
``fedlesscan``  the paper's Algorithm 2 tier selection (rookies →
                DBSCAN-clustered participants → stragglers), wrapping
                ``core.selection.select_clients``;
``apodotiko``   score-based probabilistic sampling (arXiv 2404.14033):
                a per-client score combining duration EMA, success
                rate, cold-start rate, and selection staleness feeds a
                softmax whose temperature anneals over rounds —
                explore early, exploit reliable clients late;
``adaptive``    cohort sizing driven by trailing EUR / straggler ratio
                (grow the cohort while updates land, shrink it while
                slots are being wasted), selection delegated to an
                inner scheduler;
``rotation``    the barrier-free driver's default: deterministic cyclic
                rotation with exponential (virtual-time) failure
                backoff, extracted verbatim from the old controller.

Strategies keep working unchanged: ``Strategy.select`` is now a shim
that delegates to the strategy's own scheduler (random for FedAvg-like
strategies, Algorithm 2 for FedLesScan, whole-pool for SAFA).
`state_dict`/`load_state_dict` round-trip scheduler state for the
round-tagged checkpoint/resume path (fl/checkpointing.py).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.features import ema_step, normalize01
from ..core.history import ClientHistoryDB
from ..core.selection import SelectionPlan, select_clients, select_random
from .metrics import trailing_eur, trailing_straggler_ratio


def _rng_state(rng: np.random.Generator) -> dict:
    return rng.bit_generator.state


def _set_rng_state(rng: np.random.Generator, state) -> None:
    # JSON round-trips tuple-typed entries as lists; numpy accepts dicts
    rng.bit_generator.state = state


class Scheduler:
    """Base class: owns the RNG and the default (fixed) cohort size."""

    name = "base"

    def __init__(self, clients_per_round: int,
                 rng: Optional[np.random.Generator] = None, seed: int = 0):
        self.clients_per_round = clients_per_round
        self.rng = rng if rng is not None else np.random.default_rng(seed)

    # ---- the three-call protocol the TrainingDriver consumes ----------
    def propose(self, pool: Sequence[str], want: int, now: float,
                round_number: int) -> List[str]:
        """Pick up to `want` clients from `pool` (the currently eligible
        population — the driver already excludes in-flight clients)."""
        raise NotImplementedError

    def notify_finish(self, client_id: str, now: float,
                      duration_s: float = 0.0, cold: bool = False,
                      late: bool = False) -> None:
        """A client's update physically arrived (possibly late)."""

    def notify_miss(self, client_id: str, now: float,
                    crashed: bool = True) -> None:
        """A client missed: `crashed` distinguishes terminal failures /
        unresponsive clients from merely-late or never-started ones."""

    def cohort_size(self, round_number: int, telemetry: Sequence) -> int:
        """How many clients the next round should invoke.  `telemetry`
        is the driver's trailing `RoundStats` window (may be empty)."""
        return self.clients_per_round

    # ---- trace + checkpoint surfaces ----------------------------------
    def decision_info(self) -> dict:
        """Extra payload for the last propose()'s `scheduling` record."""
        return {}

    def state_dict(self) -> dict:
        return {"rng": _rng_state(self.rng)}

    def load_state_dict(self, state: dict) -> None:
        if "rng" in state:
            _set_rng_state(self.rng, state["rng"])


class RandomScheduler(Scheduler):
    """Uniform random cohorts — FedAvg/FedProx selection."""

    name = "random"

    def propose(self, pool, want, now, round_number):
        return select_random(pool, want, self.rng)


class StrategySelectScheduler(Scheduler):
    """Adapter for legacy Strategy subclasses that override `select`
    directly (pre-scheduler API): `propose` calls the override, so a
    hand-written selection policy keeps winning over the strategy's
    default scheduler when the driver picks its cohorts."""

    name = "strategy-select"

    def __init__(self, strategy):
        super().__init__(strategy.config.clients_per_round,
                         rng=strategy.rng)
        self.strategy = strategy

    def propose(self, pool, want, now, round_number):
        return self.strategy.select(pool, round_number)


class FullPoolScheduler(Scheduler):
    """SAFA-style: invoke every eligible client, ignore `want` (the
    round then closes at the strategy's quorum)."""

    name = "full"

    def propose(self, pool, want, now, round_number):
        return list(pool)


class FedLesScanScheduler(Scheduler):
    """Paper Algorithm 2 — tier selection over the behavioural history
    (rookies → clustered participants → stragglers)."""

    name = "fedlesscan"

    def __init__(self, clients_per_round: int, history: ClientHistoryDB,
                 max_rounds: int = 50, ema_alpha: float = 0.5,
                 rng: Optional[np.random.Generator] = None, seed: int = 0):
        super().__init__(clients_per_round, rng=rng, seed=seed)
        self.history = history
        self.max_rounds = max_rounds
        self.ema_alpha = ema_alpha
        self.last_plan: Optional[SelectionPlan] = None

    def propose(self, pool, want, now, round_number):
        plan = select_clients(self.history, pool, round_number,
                              self.max_rounds, want, self.rng,
                              ema_alpha=self.ema_alpha)
        self.last_plan = plan
        return plan.selected

    def decision_info(self):
        p = self.last_plan
        if p is None:
            return {}
        return {"rookies": len(p.rookies),
                "clustered": len(p.cluster_clients),
                "stragglers": len(p.straggler_clients),
                "n_clusters": p.n_clusters, "eps": p.eps}


class ApodotikoScheduler(Scheduler):
    """Score-based probabilistic sampling (Apodotiko, arXiv 2404.14033).

    Each client gets a score in [0, 1] from four behavioural terms::

        score = w_dur  · (1 − norm(durationEMA))     fast clients up
              + w_succ · successRate                  reliable clients up
              + w_cold · (1 − coldStartRate)          warm clients up
              + w_stale· norm(roundsSinceSelected)    ignored clients up

    Unseen clients score 1.0 (maximum) so every client is explored
    before the policy starts discriminating.  The cohort is sampled
    without replacement from ``softmax(score / T)`` with the temperature
    annealed geometrically over rounds (``T = max(T_min, T0·decay^t)``)
    — early rounds explore broadly, late rounds concentrate on the
    clients that kept delivering.
    """

    name = "apodotiko"

    def __init__(self, clients_per_round: int,
                 rng: Optional[np.random.Generator] = None, seed: int = 0, *,
                 ema_alpha: float = 0.5, temperature: float = 0.35,
                 temperature_decay: float = 0.9,
                 min_temperature: float = 0.05,
                 w_duration: float = 0.3, w_success: float = 0.4,
                 w_cold: float = 0.1, w_staleness: float = 0.2):
        super().__init__(clients_per_round, rng=rng, seed=seed)
        self.ema_alpha = ema_alpha
        self.temperature = temperature
        self.temperature_decay = temperature_decay
        self.min_temperature = min_temperature
        self.weights = (w_duration, w_success, w_cold, w_staleness)
        # behavioural tallies, fed exclusively by the notify hooks
        self._duration_ema: Dict[str, float] = {}
        self._observations: Dict[str, int] = {}   # resolved invocations
        self._successes: Dict[str, int] = {}
        self._finishes: Dict[str, int] = {}       # cold-rate denominator
        self._cold_starts: Dict[str, int] = {}
        self._last_selected: Dict[str, int] = {}
        self._last_scores: Dict[str, float] = {}

    # ---- feedback -----------------------------------------------------
    def notify_finish(self, client_id, now, duration_s=0.0, cold=False,
                      late=False):
        # a late arrival is the second half of an invocation the deadline
        # already reported through notify_miss — it contributes duration /
        # cold-start data but not a second resolved-invocation observation
        # (else chronic-but-productive stragglers are double-penalized)
        if not late:
            self._observations[client_id] = (
                self._observations.get(client_id, 0) + 1)
            self._successes[client_id] = self._successes.get(client_id,
                                                             0) + 1
        self._finishes[client_id] = self._finishes.get(client_id, 0) + 1
        if cold:
            self._cold_starts[client_id] = (
                self._cold_starts.get(client_id, 0) + 1)
        prev = self._duration_ema.get(client_id)
        self._duration_ema[client_id] = ema_step(prev, duration_s,
                                                 self.ema_alpha)

    def notify_miss(self, client_id, now, crashed=True):
        self._observations[client_id] = self._observations.get(client_id,
                                                               0) + 1

    # ---- scoring ------------------------------------------------------
    def _scores(self, pool: Sequence[str], round_number: int) -> np.ndarray:
        w_dur, w_succ, w_cold, w_stale = self.weights
        durations = np.array([self._duration_ema.get(c, 0.0) for c in pool])
        seen = np.array([c in self._duration_ema for c in pool])
        dur_norm = normalize01(durations, mask=seen)
        succ = np.array([
            self._successes.get(c, 0) / obs if (obs := self._observations.get(c, 0))
            else 1.0 for c in pool])
        cold = np.array([
            self._cold_starts.get(c, 0) / fin
            if (fin := self._finishes.get(c, 0)) else 0.0 for c in pool])
        stale = np.array([
            float(round_number - self._last_selected.get(c, -1))
            for c in pool])
        stale_norm = normalize01(stale)
        scores = (w_dur * (1.0 - dur_norm) + w_succ * succ
                  + w_cold * (1.0 - cold) + w_stale * stale_norm)
        # rookies (never resolved): maximum score — explore them first
        rookie = np.array([self._observations.get(c, 0) == 0 for c in pool])
        scores[rookie] = 1.0
        return scores

    def propose(self, pool, want, now, round_number):
        pool = list(pool)
        k = min(want, len(pool))
        if k <= 0:
            return []
        scores = self._scores(pool, round_number)
        t = max(self.min_temperature,
                self.temperature * self.temperature_decay ** round_number)
        logits = scores / t
        logits -= logits.max()
        probs = np.exp(logits)
        probs /= probs.sum()
        chosen = list(self.rng.choice(pool, size=k, replace=False, p=probs))
        for cid in chosen:
            self._last_selected[cid] = round_number
        self._last_scores = {c: float(s) for c, s in zip(pool, scores)}
        return chosen

    def decision_info(self):
        if not self._last_scores:
            return {}
        vals = np.array(list(self._last_scores.values()))
        return {"score_min": float(vals.min()),
                "score_max": float(vals.max()),
                "score_mean": float(vals.mean())}

    def state_dict(self):
        state = super().state_dict()
        state.update(duration_ema=dict(self._duration_ema),
                     observations=dict(self._observations),
                     successes=dict(self._successes),
                     finishes=dict(self._finishes),
                     cold_starts=dict(self._cold_starts),
                     last_selected=dict(self._last_selected))
        return state

    def load_state_dict(self, state):
        super().load_state_dict(state)
        self._duration_ema = dict(state.get("duration_ema", {}))
        self._observations = dict(state.get("observations", {}))
        self._successes = dict(state.get("successes", {}))
        self._finishes = dict(state.get("finishes", {}))
        self._cold_starts = dict(state.get("cold_starts", {}))
        self._last_selected = dict(state.get("last_selected", {}))


class AdaptiveScheduler(Scheduler):
    """Adaptive cohort sizing over an inner selection policy.

    Reads the trailing `RoundStats` window: while the effective update
    ratio stays high (slots are not being wasted) the cohort grows one
    client per round toward `max_cohort`; when EUR drops or the
    straggler ratio spikes it shrinks toward `min_cohort` — spending
    invocations where they convert into updates.
    """

    name = "adaptive"

    def __init__(self, clients_per_round: int,
                 rng: Optional[np.random.Generator] = None, seed: int = 0, *,
                 inner: Optional[Scheduler] = None,
                 min_cohort: Optional[int] = None,
                 max_cohort: Optional[int] = None, low_eur: float = 0.6,
                 high_eur: float = 0.95, straggler_cap: float = 0.4,
                 window: int = 3):
        super().__init__(clients_per_round, rng=rng, seed=seed)
        self.inner = inner or RandomScheduler(clients_per_round, rng=self.rng)
        self.min_cohort = (min_cohort if min_cohort is not None
                           else max(2, clients_per_round // 2))
        self.max_cohort = max_cohort or 2 * clients_per_round
        self.low_eur = low_eur
        self.high_eur = high_eur
        self.straggler_cap = straggler_cap
        self.window = window
        self._size = clients_per_round

    def cohort_size(self, round_number, telemetry):
        if telemetry:
            eur = trailing_eur(telemetry, self.window)
            straggling = trailing_straggler_ratio(telemetry, self.window)
            if eur <= self.low_eur or straggling >= self.straggler_cap:
                self._size = max(self.min_cohort, self._size - 1)
            elif eur >= self.high_eur:
                self._size = min(self.max_cohort, self._size + 1)
        return self._size

    def propose(self, pool, want, now, round_number):
        return self.inner.propose(pool, want, now, round_number)

    def notify_finish(self, client_id, now, **kwargs):
        self.inner.notify_finish(client_id, now, **kwargs)

    def notify_miss(self, client_id, now, crashed=True):
        self.inner.notify_miss(client_id, now, crashed=crashed)

    def decision_info(self):
        info = {"cohort": self._size}
        info.update(self.inner.decision_info())
        return info

    def state_dict(self):
        state = super().state_dict()
        state["size"] = self._size
        state["inner"] = self.inner.state_dict()
        return state

    def load_state_dict(self, state):
        super().load_state_dict(state)
        self._size = int(state.get("size", self._size))
        self.inner.load_state_dict(state.get("inner", {}))


class RotationScheduler(Scheduler):
    """Barrier-free rotation — the async driver's default policy.

    Deterministic cyclic rotation over the whole population, skipping
    clients outside the eligible pool (in flight) and clients in
    failure backoff; when every eligible client is cooling down, the
    first one is probed anyway.  A crashed/failing client's cooldown
    doubles per consecutive failure (the async twin of the paper's
    Eq. 1) and resets when an update of theirs finally arrives.
    """

    name = "rotation"

    def __init__(self, clients_per_round: int, client_ids: Sequence[str],
                 timeout_s: float = 120.0,
                 rng: Optional[np.random.Generator] = None, seed: int = 0):
        super().__init__(clients_per_round, rng=rng, seed=seed)
        self._rotation = deque(client_ids)
        self.timeout_s = timeout_s
        self._fail_streak: Dict[str, int] = {}
        self._cooldown_until: Dict[str, float] = {}

    def _next(self, eligible: set, now: float) -> Optional[str]:
        fallback = None
        for _ in range(len(self._rotation)):
            cid = self._rotation[0]
            self._rotation.rotate(-1)
            if cid not in eligible:
                continue
            if self._cooldown_until.get(cid, 0.0) <= now:
                return cid
            if fallback is None:
                fallback = cid
        return fallback

    def propose(self, pool, want, now, round_number):
        eligible = set(pool)
        out: List[str] = []
        for _ in range(want):
            cid = self._next(eligible, now)
            if cid is None:
                break
            out.append(cid)
            eligible.discard(cid)
        return out

    def notify_finish(self, client_id, now, duration_s=0.0, cold=False,
                      late=False):
        self._fail_streak[client_id] = 0
        self._cooldown_until.pop(client_id, None)

    def notify_miss(self, client_id, now, crashed=True):
        if not crashed:
            return      # late-but-alive clients are not penalized
        streak = self._fail_streak.get(client_id, 0) + 1
        self._fail_streak[client_id] = streak
        self._cooldown_until[client_id] = (
            now + self.timeout_s * 2.0 ** (streak - 1))

    def state_dict(self):
        state = super().state_dict()
        state.update(rotation=list(self._rotation),
                     fail_streak=dict(self._fail_streak),
                     cooldown_until=dict(self._cooldown_until))
        return state

    def load_state_dict(self, state):
        super().load_state_dict(state)
        if "rotation" in state:
            self._rotation = deque(state["rotation"])
        self._fail_streak = dict(state.get("fail_streak", {}))
        self._cooldown_until = dict(state.get("cooldown_until", {}))


SCHEDULERS = {cls.name: cls for cls in
              (RandomScheduler, FullPoolScheduler, FedLesScanScheduler,
               ApodotikoScheduler, AdaptiveScheduler, RotationScheduler)}


def make_scheduler(name: str, clients_per_round: int, *,
                   history: Optional[ClientHistoryDB] = None,
                   max_rounds: int = 50, ema_alpha: float = 0.5,
                   client_ids: Optional[Sequence[str]] = None,
                   timeout_s: float = 120.0,
                   rng: Optional[np.random.Generator] = None,
                   seed: int = 0, **kwargs) -> Scheduler:
    """Factory for the shipped scheduling policies."""
    if name not in SCHEDULERS:
        raise ValueError(f"unknown scheduler {name!r}; "
                         f"available: {sorted(SCHEDULERS)}")
    if name == "fedlesscan":
        if history is None:
            raise ValueError("the fedlesscan scheduler needs a "
                             "ClientHistoryDB (history=...)")
        return FedLesScanScheduler(clients_per_round, history,
                                   max_rounds=max_rounds,
                                   ema_alpha=ema_alpha, rng=rng, seed=seed)
    if name == "rotation":
        return RotationScheduler(clients_per_round, client_ids or [],
                                 timeout_s=timeout_s, rng=rng, seed=seed)
    return SCHEDULERS[name](clients_per_round, rng=rng, seed=seed, **kwargs)
