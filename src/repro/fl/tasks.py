"""Training tasks: model + loss + local-training loop for FL clients.

A Task turns a ModelDef into the jit'd pieces Client_Update needs:
`init_params`, `local_train` (with FedProx proximal hook) and `evaluate`.
One jit cache is shared across all clients of an experiment (same HLO,
different data) — mirroring how FedLess ships one function image.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..data.loader import batches
from ..data.synthetic import ArrayDataset
from ..models.small import ModelDef
from ..optim import apply_updates, make_optimizer, proximal_grad

Pytree = Any


@dataclass(frozen=True)
class TaskConfig:
    epochs: int = 5
    batch_size: int = 10
    learning_rate: float = 1e-3
    optimizer: str = "adam"
    per_sample_time_s: float = 0.01   # nominal seconds/sample/epoch (sim)


class ClassificationTask:
    """Cross-entropy classification (covers CNNs, speech and char-LM —
    the LSTM predicts the next char, which is also a classification)."""

    def __init__(self, model: ModelDef, config: TaskConfig):
        self.model = model
        self.config = config
        self.optimizer = make_optimizer(config.optimizer,
                                        config.learning_rate)
        self._train_step = jax.jit(self._train_step_impl,
                                   static_argnums=(5,))  # mu: python float
        self._eval_batch = jax.jit(self._eval_batch_impl)

    # ------------------------------------------------------------------
    def init_params(self, seed: int = 0) -> Pytree:
        return self.model.init(jax.random.PRNGKey(seed))

    # ------------------------------------------------------------------
    def _loss(self, params, x, y):
        logits = self.model.apply(params, x)
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
        return ce, logits

    def _train_step_impl(self, params, opt_state, global_params, x, y, mu):
        (loss, _), grads = jax.value_and_grad(self._loss, has_aux=True)(
            params, x, y)
        grads = proximal_grad(grads, params, global_params, mu)
        updates, opt_state = self.optimizer.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    def local_train(self, global_params: Pytree, ds: ArrayDataset,
                    mu: float = 0.0, seed: int = 0) -> Tuple[Pytree, float]:
        """Run `epochs` local epochs from the global model. Returns the new
        local params and the mean training loss."""
        cfg = self.config
        rng = np.random.default_rng(seed)
        params = global_params
        opt_state = self.optimizer.init(params)
        losses = []
        for _ in range(cfg.epochs):
            for x, y in batches(ds, cfg.batch_size, rng):
                params, opt_state, loss = self._train_step(
                    params, opt_state, global_params,
                    jnp.asarray(x), jnp.asarray(y), float(mu))
                losses.append(float(loss))
        return params, float(np.mean(losses)) if losses else 0.0

    # ------------------------------------------------------------------
    def _eval_batch_impl(self, params, x, y):
        logits = self.model.apply(params, x)
        pred = jnp.argmax(logits, axis=-1)
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.take_along_axis(logp, y[:, None], axis=-1).sum()
        return (pred == y).sum(), ce

    def evaluate(self, params: Pytree, ds: ArrayDataset,
                 batch_size: int = 256) -> Tuple[float, float]:
        """Returns (accuracy, mean loss)."""
        correct, loss_sum, n = 0.0, 0.0, 0
        for i in range(0, len(ds), batch_size):
            x = jnp.asarray(ds.x[i:i + batch_size])
            y = jnp.asarray(ds.y[i:i + batch_size])
            c, l = self._eval_batch(params, x, y)
            correct += float(c)
            loss_sum += float(l)
            n += x.shape[0]
        return correct / max(1, n), loss_sum / max(1, n)

    # ------------------------------------------------------------------
    def nominal_work_seconds(self, ds: ArrayDataset) -> float:
        """Ideal training duration used by the virtual-time simulation:
        proportional to epochs × samples (plus model/data load overhead)."""
        cfg = self.config
        load_overhead = 2.0  # model + dataset fetch (paper Alg.1 line 19)
        return load_overhead + cfg.epochs * len(ds) * cfg.per_sample_time_s
