"""Vectorized client execution — one XLA dispatch per round.

The seed trained each selected client with an eager Python loop (N
clients × E epochs × B batches of separate jitted step calls).  This
module groups same-shape clients and runs their *entire* local training
through one ``jax.vmap``-of-``lax.scan`` dispatch:

  * each client's shuffled epoch schedule is materialised as an index
    matrix (replicating `data.loader.batches` draw-for-draw, so results
    match the per-client loop);
  * partial trailing batches are padded to the full batch size with a
    per-sample mask — the masked mean-CE loss makes padded samples
    contribute exactly zero gradient, so padding is numerically inert;
  * clients with the same (dataset size, sample shape, step count) stack
    into a ``(K, T, B, ...)`` batch and train under ``vmap`` over K, with
    per-client Adam states vmapped alongside the params;
  * K is padded up to a power-of-two bucket (duplicating the last
    client's stack; padded rows are discarded on the way out) so the
    compiled executable is reused across rounds whose cohort sizes
    differ — XLA compiles once per (bucket, step-shape), not once per K.

The controller feeds the resulting updates to the event engine as the
round's precomputed work cache; the per-client `ClientPool.work_fn` path
remains for incremental invocation and as the parity reference.

With the device pipeline enabled (``REPRO_DEVICE_PIPELINE``, default on)
the trained stack never leaves the device: `run_group_batch` flattens it
into the ``(K, P)`` ravel-layout matrix with one extra jitted dispatch
and hands downstream consumers a `core.device_batch.DeviceUpdateBatch` —
per-client pytrees and host loss scalars are materialized lazily.  The
flatten is a *separate* dispatch from the training jit on purpose: XLA
never gets the chance to rearrange training math around it, so enabling
the pipeline cannot perturb training numerics.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.flatten_util import ravel_pytree

from ..core.device_batch import DeviceUpdateBatch, pipeline_enabled
from ..optim import apply_updates, proximal_grad

Pytree = Any


def _batch_indices(n: int, batch_size: int, epochs: int,
                   rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
    """(T, B) index + mask matrices reproducing `loader.batches` order.

    Trailing partial batches are padded with index 0 / mask 0.

    Vectorized: one ``rng.permuted`` over a tiled arange draws all E
    epoch permutations at once — bit-identical, draw-for-draw, to E
    sequential ``rng.permutation(n)`` calls (both reduce to E row-wise
    Fisher–Yates passes over the same bit stream), without the
    O(E·n/B) per-batch Python loop.
    """
    orders = rng.permuted(np.tile(np.arange(n), (epochs, 1)), axis=1)
    per_epoch = -(-n // batch_size)             # batches per epoch
    pad = per_epoch * batch_size - n
    if pad:
        orders = np.concatenate(
            [orders, np.zeros((epochs, pad), dtype=orders.dtype)], axis=1)
    idx = orders.reshape(epochs * per_epoch, batch_size)
    mask = np.ones((epochs, per_epoch * batch_size), dtype=np.float32)
    if pad:
        mask[:, n:] = 0.0
    return idx, mask.reshape(epochs * per_epoch, batch_size)


def _bucket(k: int) -> int:
    """Next power of two ≥ k — the vmap width the kernel is compiled for."""
    return 1 << (k - 1).bit_length() if k > 1 else 1


class VectorizedExecutor:
    """Runs the local epochs of a group of clients as one vmapped scan."""

    def __init__(self, task):
        self.task = task
        self._jit_cache: Dict[float, Any] = {}   # mu -> compiled group fn
        # stacked-tree → (K, P) ravel-layout flatten; its own dispatch so
        # the training jit's numerics are untouched by the pipeline
        self._flatten = jax.jit(self._flatten_stacked)
        self._unravel_cache: Dict[Any, Callable] = {}
        # recompile accounting: one entry per distinct dispatch signature
        # (mu + bucketed operand shapes).  compile_count going flat across
        # rounds is the "compilation is a non-event" invariant the round-
        # pipeline tests assert.
        self._dispatch_keys: set = set()
        self.compile_count = 0

    # ------------------------------------------------------------------
    def _group_fn(self, mu: float):
        """vmap-over-clients of scan-over-steps local training."""
        if mu in self._jit_cache:
            return self._jit_cache[mu]
        task = self.task
        optimizer = task.optimizer

        def masked_loss(params, x, y, m):
            logits = task.model.apply(params, x)
            logp = jax.nn.log_softmax(logits)
            ce = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
            # identical to batch-mean CE when the mask is all ones
            return jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)

        def one_client(global_params, xs, ys, ms):
            opt_state = optimizer.init(global_params)

            def step(carry, batch):
                params, opt_state = carry
                x, y, m = batch
                loss, grads = jax.value_and_grad(masked_loss)(params, x, y, m)
                grads = proximal_grad(grads, params, global_params, mu)
                updates, opt_state = optimizer.update(grads, opt_state,
                                                      params)
                return (apply_updates(params, updates), opt_state), loss

            # XLA:CPU executes while-loops serially with poor fusion —
            # unrolling the (short) local-epoch scan is ~15x faster there
            # and harmless on TPU
            unroll = max(1, min(int(xs.shape[0]), 8))
            (params, _), losses = lax.scan(step, (global_params, opt_state),
                                           (xs, ys, ms), unroll=unroll)
            return params, jnp.mean(losses)

        # memoized per mu in _jit_cache (guard at the top of _group_fn),
        # so construction happens once per proximal setting, not per round
        fn = jax.jit(jax.vmap(one_client, in_axes=(None, 0, 0, 0)))  # repro-lint: disable=JAX003
        self._jit_cache[mu] = fn
        return fn

    # ------------------------------------------------------------------
    @staticmethod
    def _flatten_stacked(stacked: Pytree) -> jnp.ndarray:
        """(K, P) matrix whose row k is exactly
        ``ravel_pytree(tree_map(lambda l: l[k], stacked))[0]``: raveled
        leaves concatenated in tree order, cast to the promoted dtype."""
        leaves = jax.tree_util.tree_leaves(stacked)
        k = leaves[0].shape[0]
        dt = jnp.result_type(*[l.dtype for l in leaves])
        return jnp.concatenate(
            [l.reshape(k, -1).astype(dt) for l in leaves], axis=1)

    def _unravel_for(self, stacked: Pytree) -> Callable:
        """The shared row → pytree inverse (cached per tree structure)."""
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        key = (treedef,
               tuple((l.shape[1:], str(l.dtype)) for l in leaves))
        un = self._unravel_cache.get(key)
        if un is None:
            single = jax.tree_util.tree_unflatten(
                treedef, [jnp.zeros(l.shape[1:], l.dtype) for l in leaves])
            _, un = ravel_pytree(single)
            self._unravel_cache[key] = un
        return un

    def _train_group(self, cids: Sequence[str], datasets,
                     global_params: Pytree, mu: float,
                     seeds: Sequence[int]) -> Tuple[Pytree, jnp.ndarray]:
        """One bucketed vmap dispatch: (stacked out_params, losses) with
        K padded to the power-of-two bucket (rows ≥ len(cids) are pads)."""
        cfg = self.task.config
        xs, ys, ms = [], [], []
        for cid, ds, seed in zip(cids, datasets, seeds):
            rng = np.random.default_rng(seed)
            idx, mask = _batch_indices(len(ds), cfg.batch_size, cfg.epochs,
                                       rng)
            xs.append(ds.x[idx])        # (T, B, ...)
            ys.append(ds.y[idx])
            ms.append(mask)
        xs, ys, ms = np.stack(xs), np.stack(ys), np.stack(ms)
        pad = _bucket(len(cids)) - len(cids)
        if pad:
            xs = np.concatenate([xs, np.repeat(xs[-1:], pad, axis=0)])
            ys = np.concatenate([ys, np.repeat(ys[-1:], pad, axis=0)])
            ms = np.concatenate([ms, np.repeat(ms[-1:], pad, axis=0)])
        key = (mu, xs.shape, str(xs.dtype), ys.shape, str(ys.dtype))
        if key not in self._dispatch_keys:
            self._dispatch_keys.add(key)
            self.compile_count += 1
        return self._group_fn(mu)(
            global_params, jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(ms))

    def run_group(self, cids: Sequence[str], datasets, global_params: Pytree,
                  mu: float, seeds: Sequence[int]
                  ) -> Dict[str, Tuple[Pytree, float]]:
        """Train one same-shape group; returns cid -> (params, mean loss)."""
        out_params, losses = self._train_group(cids, datasets, global_params,
                                               mu, seeds)
        # one batched transfer for the whole loss vector — K per-scalar
        # float(losses[k]) syncs were K blocking round-trips
        losses_np = np.asarray(losses)
        results = {}
        for k, cid in enumerate(cids):
            params_k = jax.tree_util.tree_map(lambda l: l[k], out_params)
            results[cid] = (params_k, float(losses_np[k]))
        return results

    def run_group_batch(self, cids: Sequence[str], datasets,
                        global_params: Pytree, mu: float,
                        seeds: Sequence[int]) -> DeviceUpdateBatch:
        """Device-pipeline twin of `run_group`: the trained stack is
        flattened on device into the (K_bucket, P) ravel-layout matrix
        and returned as a DeviceUpdateBatch — nothing crosses to the
        host until a consumer materializes a row."""
        out_params, losses = self._train_group(cids, datasets, global_params,
                                               mu, seeds)
        return DeviceUpdateBatch(self._flatten(out_params), cids,
                                 self._unravel_for(out_params),
                                 losses=losses)

    # ------------------------------------------------------------------
    def _group(self, pool, cids: Sequence[str]) -> Dict[tuple, List[str]]:
        """Bucket clients by (dataset size, sample shape, dtype)."""
        groups: Dict[tuple, List[str]] = {}
        for cid in cids:
            ds = pool.clients[cid].dataset
            key = (len(ds), ds.x.shape[1:], str(ds.x.dtype))
            groups.setdefault(key, []).append(cid)
        return groups

    def warmup(self, pool, cids: Sequence[str], global_params: Pytree,
               round_number: int = 0) -> int:
        """Compile the train (and flatten) dispatches for the bucket
        shapes `cids` would use, without touching any round state — no
        packaging, no compressor residuals, results discarded.  Returns
        the executor's cumulative compile count."""
        for group_cids in self._group(pool, cids).values():
            datasets = [pool.clients[c].dataset for c in group_cids]
            seeds = [pool.client_seed(c, round_number) for c in group_cids]
            out_params, _losses = self._train_group(
                group_cids, datasets, global_params, pool.proximal_mu, seeds)
            if pipeline_enabled():
                self._flatten(out_params).block_until_ready()
        return self.compile_count

    def run_clients(self, pool, cids: Sequence[str], global_params: Pytree,
                    round_number: int) -> Dict[str, tuple]:
        """Group → train → package: cid -> (ClientUpdate, nominal_work_s),
        the same contract as `ClientPool.work_fn` per client.

        Pipeline on: each group's updates stay on device as one
        DeviceUpdateBatch and the packaged ClientUpdates are thin row
        views.  Pipeline off (``REPRO_DEVICE_PIPELINE=0``): the legacy
        per-client materialize → package path."""
        results: Dict[str, tuple] = {}
        for group_cids in self._group(pool, cids).values():
            datasets = [pool.clients[c].dataset for c in group_cids]
            seeds = [pool.client_seed(c, round_number) for c in group_cids]
            if pipeline_enabled():
                batch = self.run_group_batch(group_cids, datasets,
                                             global_params,
                                             pool.proximal_mu, seeds)
                for i, cid in enumerate(group_cids):
                    ds = pool.clients[cid].dataset
                    update = pool.package_update(cid, None, round_number,
                                                 global_params,
                                                 batch=batch, row=i)
                    results[cid] = (update,
                                    self.task.nominal_work_seconds(ds))
                continue
            trained = self.run_group(group_cids, datasets, global_params,
                                     pool.proximal_mu, seeds)
            for cid in group_cids:
                params, _loss = trained[cid]
                ds = pool.clients[cid].dataset
                # pool.package_update runs the optional compression stage
                # (same hook as the eager work_fn path)
                update = pool.package_update(cid, params, round_number,
                                             global_params)
                results[cid] = (update,
                                self.task.nominal_work_seconds(ds))
        return results
