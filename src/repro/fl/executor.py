"""Vectorized client execution — one XLA dispatch per round.

The seed trained each selected client with an eager Python loop (N
clients × E epochs × B batches of separate jitted step calls).  This
module groups same-shape clients and runs their *entire* local training
through one ``jax.vmap``-of-``lax.scan`` dispatch:

  * each client's shuffled epoch schedule is materialised as an index
    matrix (replicating `data.loader.batches` draw-for-draw, so results
    match the per-client loop);
  * partial trailing batches are padded to the full batch size with a
    per-sample mask — the masked mean-CE loss makes padded samples
    contribute exactly zero gradient, so padding is numerically inert;
  * clients with the same (dataset size, sample shape, step count) stack
    into a ``(K, T, B, ...)`` batch and train under ``vmap`` over K, with
    per-client Adam states vmapped alongside the params;
  * K is padded up to a power-of-two bucket (duplicating the last
    client's stack; padded rows are discarded on the way out) so the
    compiled executable is reused across rounds whose cohort sizes
    differ — XLA compiles once per (bucket, step-shape), not once per K.

The controller feeds the resulting updates to the event engine as the
round's precomputed work cache; the per-client `ClientPool.work_fn` path
remains for incremental invocation and as the parity reference.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..optim import apply_updates, proximal_grad

Pytree = Any


def _batch_indices(n: int, batch_size: int, epochs: int,
                   rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
    """(T, B) index + mask matrices reproducing `loader.batches` order.

    Trailing partial batches are padded with index 0 / mask 0.
    """
    idx_rows: List[np.ndarray] = []
    mask_rows: List[np.ndarray] = []
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n, batch_size):
            chunk = order[i:i + batch_size]
            pad = batch_size - len(chunk)
            mask = np.ones(batch_size, dtype=np.float32)
            if pad:
                chunk = np.concatenate([chunk, np.zeros(pad, dtype=chunk.dtype)])
                mask[batch_size - pad:] = 0.0
            idx_rows.append(chunk)
            mask_rows.append(mask)
    return np.stack(idx_rows), np.stack(mask_rows)


def _bucket(k: int) -> int:
    """Next power of two ≥ k — the vmap width the kernel is compiled for."""
    return 1 << (k - 1).bit_length() if k > 1 else 1


class VectorizedExecutor:
    """Runs the local epochs of a group of clients as one vmapped scan."""

    def __init__(self, task):
        self.task = task
        self._jit_cache: Dict[float, Any] = {}   # mu -> compiled group fn

    # ------------------------------------------------------------------
    def _group_fn(self, mu: float):
        """vmap-over-clients of scan-over-steps local training."""
        if mu in self._jit_cache:
            return self._jit_cache[mu]
        task = self.task
        optimizer = task.optimizer

        def masked_loss(params, x, y, m):
            logits = task.model.apply(params, x)
            logp = jax.nn.log_softmax(logits)
            ce = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
            # identical to batch-mean CE when the mask is all ones
            return jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)

        def one_client(global_params, xs, ys, ms):
            opt_state = optimizer.init(global_params)

            def step(carry, batch):
                params, opt_state = carry
                x, y, m = batch
                loss, grads = jax.value_and_grad(masked_loss)(params, x, y, m)
                grads = proximal_grad(grads, params, global_params, mu)
                updates, opt_state = optimizer.update(grads, opt_state,
                                                      params)
                return (apply_updates(params, updates), opt_state), loss

            # XLA:CPU executes while-loops serially with poor fusion —
            # unrolling the (short) local-epoch scan is ~15x faster there
            # and harmless on TPU
            unroll = max(1, min(int(xs.shape[0]), 8))
            (params, _), losses = lax.scan(step, (global_params, opt_state),
                                           (xs, ys, ms), unroll=unroll)
            return params, jnp.mean(losses)

        fn = jax.jit(jax.vmap(one_client, in_axes=(None, 0, 0, 0)))
        self._jit_cache[mu] = fn
        return fn

    # ------------------------------------------------------------------
    def run_group(self, cids: Sequence[str], datasets, global_params: Pytree,
                  mu: float, seeds: Sequence[int]
                  ) -> Dict[str, Tuple[Pytree, float]]:
        """Train one same-shape group; returns cid -> (params, mean loss)."""
        cfg = self.task.config
        xs, ys, ms = [], [], []
        for cid, ds, seed in zip(cids, datasets, seeds):
            rng = np.random.default_rng(seed)
            idx, mask = _batch_indices(len(ds), cfg.batch_size, cfg.epochs,
                                       rng)
            xs.append(ds.x[idx])        # (T, B, ...)
            ys.append(ds.y[idx])
            ms.append(mask)
        xs, ys, ms = np.stack(xs), np.stack(ys), np.stack(ms)
        pad = _bucket(len(cids)) - len(cids)
        if pad:
            xs = np.concatenate([xs, np.repeat(xs[-1:], pad, axis=0)])
            ys = np.concatenate([ys, np.repeat(ys[-1:], pad, axis=0)])
            ms = np.concatenate([ms, np.repeat(ms[-1:], pad, axis=0)])
        out_params, losses = self._group_fn(mu)(
            global_params, jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(ms))
        results = {}
        for k, cid in enumerate(cids):
            params_k = jax.tree_util.tree_map(lambda l: l[k], out_params)
            results[cid] = (params_k, float(losses[k]))
        return results

    # ------------------------------------------------------------------
    def run_clients(self, pool, cids: Sequence[str], global_params: Pytree,
                    round_number: int) -> Dict[str, tuple]:
        """Group → train → package: cid -> (ClientUpdate, nominal_work_s),
        the same contract as `ClientPool.work_fn` per client."""
        groups: Dict[tuple, List[str]] = {}
        for cid in cids:
            ds = pool.clients[cid].dataset
            key = (len(ds), ds.x.shape[1:], str(ds.x.dtype))
            groups.setdefault(key, []).append(cid)

        results: Dict[str, tuple] = {}
        for group_cids in groups.values():
            datasets = [pool.clients[c].dataset for c in group_cids]
            seeds = [pool.client_seed(c, round_number) for c in group_cids]
            trained = self.run_group(group_cids, datasets, global_params,
                                     pool.proximal_mu, seeds)
            for cid in group_cids:
                params, _loss = trained[cid]
                ds = pool.clients[cid].dataset
                # pool.package_update runs the optional compression stage
                # (same hook as the eager work_fn path)
                update = pool.package_update(cid, params, round_number,
                                             global_params)
                results[cid] = (update,
                                self.task.nominal_work_seconds(ds))
        return results
