"""Vectorized client execution — one XLA dispatch per round.

The seed trained each selected client with an eager Python loop (N
clients × E epochs × B batches of separate jitted step calls).  This
module groups same-shape clients and runs their *entire* local training
through one ``jax.vmap``-of-``lax.scan`` dispatch:

  * each client's shuffled epoch schedule is materialised as an index
    matrix (replicating `data.loader.batches` draw-for-draw, so results
    match the per-client loop);
  * partial trailing batches are padded to the full batch size with a
    per-sample mask — the masked mean-CE loss makes padded samples
    contribute exactly zero gradient, so padding is numerically inert;
  * clients with the same (dataset size, sample shape, step count) stack
    into a ``(K, T, B, ...)`` batch and train under ``vmap`` over K, with
    per-client Adam states vmapped alongside the params;
  * K is padded up to a power-of-two bucket (duplicating the last
    client's stack; padded rows are discarded on the way out) so the
    compiled executable is reused across rounds whose cohort sizes
    differ — XLA compiles once per (bucket, step-shape), not once per K.

The controller feeds the resulting updates to the event engine as the
round's precomputed work cache; the per-client `ClientPool.work_fn` path
remains for incremental invocation and as the parity reference.

With the device pipeline enabled (``REPRO_DEVICE_PIPELINE``, default on)
the trained stack never leaves the device: `run_group_batch` flattens it
into the ``(K, P)`` ravel-layout matrix with one extra jitted dispatch
and hands downstream consumers a `core.device_batch.DeviceUpdateBatch` —
per-client pytrees and host loss scalars are materialized lazily.  The
flatten is a *separate* dispatch from the training jit on purpose: XLA
never gets the chance to rearrange training math around it, so enabling
the pipeline cannot perturb training numerics.

Multi-device (``mesh``): given a 1-axis ``("clients",)`` mesh
(`launch.mesh.make_clients_mesh`), the same vmapped scan runs under
``shard_map`` with the cohort (K) dim split across the mesh — each
device trains its slice of the bucket (per-client Adam states live on
the owning device because ``optimizer.init`` runs *inside* the mapped
body), and the (K, P) flatten inherits the row sharding, composing with
the P-sharded merge (`kernels/fed_agg.fed_agg_apply_sharded`) so a round
never funnels through one device.  A ``None`` or size-1 mesh takes the
*identical* single-device vmap code path — bitwise-inert by
construction, not by tolerance.

Overlapped dispatch (``REPRO_OVERLAP_DISPATCH``, default on): the group
dispatch is launched but not blocked on — JAX's async dispatch returns
unready device arrays, so event-engine bookkeeping, trace IO, and
scheduler `propose` for the round overlap device compute; the only host
syncs left are the existing single batched loss fetch and the merge
read-back.  ``0`` blocks right here until the trained stack is ready.
Virtual time never reads the wall clock, so traces are byte-identical
either way.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.flatten_util import ravel_pytree
from jax.sharding import NamedSharding, PartitionSpec as P

from ..analysis import gates
from ..core.device_batch import DeviceUpdateBatch, pipeline_enabled
from ..optim import apply_updates, proximal_grad
from ..sharding.rules import cohort_spec

Pytree = Any


def _batch_indices(n: int, batch_size: int, epochs: int,
                   rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
    """(T, B) index + mask matrices reproducing `loader.batches` order.

    Trailing partial batches are padded with index 0 / mask 0.

    Vectorized: one ``rng.permuted`` over a tiled arange draws all E
    epoch permutations at once — bit-identical, draw-for-draw, to E
    sequential ``rng.permutation(n)`` calls (both reduce to E row-wise
    Fisher–Yates passes over the same bit stream), without the
    O(E·n/B) per-batch Python loop.
    """
    orders = rng.permuted(np.tile(np.arange(n), (epochs, 1)), axis=1)
    per_epoch = -(-n // batch_size)             # batches per epoch
    pad = per_epoch * batch_size - n
    if pad:
        orders = np.concatenate(
            [orders, np.zeros((epochs, pad), dtype=orders.dtype)], axis=1)
    idx = orders.reshape(epochs * per_epoch, batch_size)
    mask = np.ones((epochs, per_epoch * batch_size), dtype=np.float32)
    if pad:
        mask[:, n:] = 0.0
    return idx, mask.reshape(epochs * per_epoch, batch_size)


def _bucket(k: int, multiple: int = 1) -> int:
    """Next power of two ≥ k, rounded up to a ``multiple`` (the mesh
    device count) so the cohort dim always divides the ``clients`` axis.
    With ``multiple=1`` this is exactly the historical bucket."""
    b = 1 << (k - 1).bit_length() if k > 1 else 1
    if multiple > 1 and b % multiple:
        b = -(-b // multiple) * multiple
    return b


def _normalize_mesh(mesh):
    """A missing or size-1 mesh is *no* mesh: the executor falls back to
    the plain vmap path, keeping single-device runs bitwise-identical."""
    if mesh is None or int(mesh.size) <= 1:
        return None
    return mesh


class VectorizedExecutor:
    """Runs the local epochs of a group of clients as one vmapped scan."""

    def __init__(self, task, mesh=None):
        self.task = task
        self.mesh = _normalize_mesh(mesh)
        # (mu, mesh key) -> compiled group fn: a mesh change must never
        # reuse a function traced for a different device layout
        self._jit_cache: Dict[tuple, Any] = {}
        # stacked-tree → (K, P) ravel-layout flatten; its own dispatch so
        # the training jit's numerics are untouched by the pipeline
        self._flatten = jax.jit(self._flatten_stacked)
        self._unravel_cache: Dict[Any, Callable] = {}
        # recompile accounting: one entry per distinct dispatch signature
        # (mu + mesh shape + bucketed operand shapes).  compile_count
        # going flat across rounds is the "compilation is a non-event"
        # invariant the round-pipeline tests assert — tracked *per mesh*,
        # so switching device counts registers as new compiles instead of
        # silently reusing a stale bucket.
        self._dispatch_keys: set = set()
        self._compile_counts: Dict[Any, int] = {}
        # telemetry (wall-clock, never fed back into virtual time): when
        # enabled, each group dispatch's launch latency is recorded and
        # stamped onto the packaged ClientUpdates as ``dispatch_s``
        self.collect_timing = False
        self.last_dispatch_s: Optional[float] = None

    # ------------------------------------------------------------------
    def configure_mesh(self, mesh) -> None:
        """Point subsequent dispatches at ``mesh`` (size-1 → vmap path).

        Compiled functions and dispatch keys are retained per mesh, so
        flipping back restores the previously compiled executables."""
        self.mesh = _normalize_mesh(mesh)

    def _mesh_key(self) -> Optional[tuple]:
        """Hashable mesh identity for jit-cache / compile accounting."""
        if self.mesh is None:
            return None
        return tuple(self.mesh.shape.items())

    @property
    def compile_count(self) -> int:
        """Compile count for the *current* mesh — the per-mesh invariant
        tests assert flat across rounds (a mesh switch starts its own
        counter instead of inflating this one)."""
        return self._compile_counts.get(self._mesh_key(), 0)

    @property
    def compile_count_total(self) -> int:
        """Cumulative compiles across every mesh this executor has used."""
        return sum(self._compile_counts.values())

    # ------------------------------------------------------------------
    def _group_fn(self, mu: float):
        """vmap-over-clients of scan-over-steps local training."""
        cache_key = (mu, self._mesh_key())
        if cache_key in self._jit_cache:
            return self._jit_cache[cache_key]
        task = self.task
        optimizer = task.optimizer

        def masked_loss(params, x, y, m):
            logits = task.model.apply(params, x)
            logp = jax.nn.log_softmax(logits)
            ce = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
            # identical to batch-mean CE when the mask is all ones
            return jnp.sum(ce * m) / jnp.maximum(jnp.sum(m), 1.0)

        def one_client(global_params, xs, ys, ms):
            opt_state = optimizer.init(global_params)

            def step(carry, batch):
                params, opt_state = carry
                x, y, m = batch
                loss, grads = jax.value_and_grad(masked_loss)(params, x, y, m)
                grads = proximal_grad(grads, params, global_params, mu)
                updates, opt_state = optimizer.update(grads, opt_state,
                                                      params)
                return (apply_updates(params, updates), opt_state), loss

            # XLA:CPU executes while-loops serially with poor fusion —
            # unrolling the (short) local-epoch scan is ~15x faster there
            # and harmless on TPU
            unroll = max(1, min(int(xs.shape[0]), 8))
            (params, _), losses = lax.scan(step, (global_params, opt_state),
                                           (xs, ys, ms), unroll=unroll)
            return params, jnp.mean(losses)

        cohort = jax.vmap(one_client, in_axes=(None, 0, 0, 0))
        if self.mesh is not None:
            # split the cohort (K) dim over the 'clients' axis: each
            # device vmaps its own slice, Adam states included (built by
            # optimizer.init inside the mapped body, so they never exist
            # unsharded); global params replicate.  check_rep=False —
            # the replicated-input analysis chokes on the scan carry.
            spec = cohort_spec()
            cohort = shard_map(cohort, mesh=self.mesh,
                               in_specs=(P(), spec, spec, spec),
                               out_specs=(spec, spec), check_rep=False)
        # memoized per (mu, mesh) in _jit_cache (guard at the top), so
        # construction happens once per setting, not per round
        fn = jax.jit(cohort)  # repro-lint: disable=JAX003
        self._jit_cache[cache_key] = fn
        return fn

    # ------------------------------------------------------------------
    @staticmethod
    def _flatten_stacked(stacked: Pytree) -> jnp.ndarray:
        """(K, P) matrix whose row k is exactly
        ``ravel_pytree(tree_map(lambda l: l[k], stacked))[0]``: raveled
        leaves concatenated in tree order, cast to the promoted dtype."""
        leaves = jax.tree_util.tree_leaves(stacked)
        k = leaves[0].shape[0]
        dt = jnp.result_type(*[l.dtype for l in leaves])
        return jnp.concatenate(
            [l.reshape(k, -1).astype(dt) for l in leaves], axis=1)

    def _unravel_for(self, stacked: Pytree) -> Callable:
        """The shared row → pytree inverse (cached per tree structure)."""
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        key = (treedef,
               tuple((l.shape[1:], str(l.dtype)) for l in leaves))
        un = self._unravel_cache.get(key)
        if un is None:
            single = jax.tree_util.tree_unflatten(
                treedef, [jnp.zeros(l.shape[1:], l.dtype) for l in leaves])
            _, un = ravel_pytree(single)
            self._unravel_cache[key] = un
        return un

    def _place(self, arr: np.ndarray) -> jnp.ndarray:
        """Stage one stacked operand on device; with a mesh, pre-shard
        the K dim so the shard_map dispatch never reshards inputs."""
        if self.mesh is None:
            return jnp.asarray(arr)
        return jax.device_put(arr, NamedSharding(self.mesh, cohort_spec()))

    def _train_group(self, cids: Sequence[str], datasets,
                     global_params: Pytree, mu: float,
                     seeds: Sequence[int]) -> Tuple[Pytree, jnp.ndarray]:
        """One bucketed vmap dispatch: (stacked out_params, losses) with
        K padded to the power-of-two bucket (rows ≥ len(cids) are pads;
        on a mesh the bucket also rounds up to the device count)."""
        cfg = self.task.config
        xs, ys, ms = [], [], []
        for cid, ds, seed in zip(cids, datasets, seeds):
            rng = np.random.default_rng(seed)
            idx, mask = _batch_indices(len(ds), cfg.batch_size, cfg.epochs,
                                       rng)
            xs.append(ds.x[idx])        # (T, B, ...)
            ys.append(ds.y[idx])
            ms.append(mask)
        xs, ys, ms = np.stack(xs), np.stack(ys), np.stack(ms)
        devices = int(self.mesh.size) if self.mesh is not None else 1
        pad = _bucket(len(cids), devices) - len(cids)
        if pad:
            xs = np.concatenate([xs, np.repeat(xs[-1:], pad, axis=0)])
            ys = np.concatenate([ys, np.repeat(ys[-1:], pad, axis=0)])
            ms = np.concatenate([ms, np.repeat(ms[-1:], pad, axis=0)])
        mesh_key = self._mesh_key()
        key = (mu, mesh_key, xs.shape, str(xs.dtype), ys.shape,
               str(ys.dtype))
        if key not in self._dispatch_keys:
            self._dispatch_keys.add(key)
            self._compile_counts[mesh_key] = \
                self._compile_counts.get(mesh_key, 0) + 1
        return self._group_fn(mu)(
            global_params, self._place(xs), self._place(ys), self._place(ms))

    def run_group(self, cids: Sequence[str], datasets, global_params: Pytree,
                  mu: float, seeds: Sequence[int]
                  ) -> Dict[str, Tuple[Pytree, float]]:
        """Train one same-shape group; returns cid -> (params, mean loss)."""
        out_params, losses = self._train_group(cids, datasets, global_params,
                                               mu, seeds)
        # one batched transfer for the whole loss vector — K per-scalar
        # float(losses[k]) syncs were K blocking round-trips
        losses_np = np.asarray(losses)
        results = {}
        for k, cid in enumerate(cids):
            params_k = jax.tree_util.tree_map(lambda l: l[k], out_params)
            results[cid] = (params_k, float(losses_np[k]))
        return results

    def run_group_batch(self, cids: Sequence[str], datasets,
                        global_params: Pytree, mu: float,
                        seeds: Sequence[int]) -> DeviceUpdateBatch:
        """Device-pipeline twin of `run_group`: the trained stack is
        flattened on device into the (K_bucket, P) ravel-layout matrix
        and returned as a DeviceUpdateBatch — nothing crosses to the
        host until a consumer materializes a row.  On a mesh the matrix
        rows stay sharded over 'clients', ready for the sharded merge."""
        out_params, losses = self._train_group(cids, datasets, global_params,
                                               mu, seeds)
        return DeviceUpdateBatch(self._flatten(out_params), cids,
                                 self._unravel_for(out_params),
                                 losses=losses)

    # ------------------------------------------------------------------
    def _group(self, pool, cids: Sequence[str]) -> Dict[tuple, List[str]]:
        """Bucket clients by (dataset size, sample shape, dtype)."""
        groups: Dict[tuple, List[str]] = {}
        for cid in cids:
            ds = pool.clients[cid].dataset
            key = (len(ds), ds.x.shape[1:], str(ds.x.dtype))
            groups.setdefault(key, []).append(cid)
        return groups

    def warmup(self, pool, cids: Sequence[str], global_params: Pytree,
               round_number: int = 0) -> int:
        """Compile the train (and flatten) dispatches for the bucket
        shapes `cids` would use, without touching any round state — no
        packaging, no compressor residuals, results discarded.  Returns
        the executor's compile count for the current mesh."""
        for group_cids in self._group(pool, cids).values():
            datasets = [pool.clients[c].dataset for c in group_cids]
            seeds = [pool.client_seed(c, round_number) for c in group_cids]
            out_params, _losses = self._train_group(
                group_cids, datasets, global_params, pool.proximal_mu, seeds)
            if pipeline_enabled():
                self._flatten(out_params).block_until_ready()
        return self.compile_count

    def run_clients(self, pool, cids: Sequence[str], global_params: Pytree,
                    round_number: int) -> Dict[str, tuple]:
        """Group → train → package: cid -> (ClientUpdate, nominal_work_s),
        the same contract as `ClientPool.work_fn` per client.

        Pipeline on: each group's updates stay on device as one
        DeviceUpdateBatch and the packaged ClientUpdates are thin row
        views — and unless ``REPRO_OVERLAP_DISPATCH=0`` the dispatch is
        *not* blocked on, so the caller's bookkeeping overlaps device
        compute.  Pipeline off (``REPRO_DEVICE_PIPELINE=0``): the legacy
        per-client materialize → package path (inherently synchronous)."""
        results: Dict[str, tuple] = {}
        overlap = gates.overlap_dispatch_enabled()
        for group_cids in self._group(pool, cids).values():
            datasets = [pool.clients[c].dataset for c in group_cids]
            seeds = [pool.client_seed(c, round_number) for c in group_cids]
            # wall-clock telemetry only — never folded into virtual time
            t0 = (time.perf_counter()  # repro-lint: disable=DET002
                  if self.collect_timing else None)
            if pipeline_enabled():
                batch = self.run_group_batch(group_cids, datasets,
                                             global_params,
                                             pool.proximal_mu, seeds)
                if not overlap:
                    jax.block_until_ready((batch.mat, batch._losses))
                dispatch_s = self._lap(t0)
                for i, cid in enumerate(group_cids):
                    ds = pool.clients[cid].dataset
                    update = pool.package_update(cid, None, round_number,
                                                 global_params,
                                                 batch=batch, row=i)
                    update.dispatch_s = dispatch_s
                    results[cid] = (update,
                                    self.task.nominal_work_seconds(ds))
                continue
            trained = self.run_group(group_cids, datasets, global_params,
                                     pool.proximal_mu, seeds)
            dispatch_s = self._lap(t0)
            for cid in group_cids:
                params, _loss = trained[cid]
                ds = pool.clients[cid].dataset
                # pool.package_update runs the optional compression stage
                # (same hook as the eager work_fn path)
                update = pool.package_update(cid, params, round_number,
                                             global_params)
                update.dispatch_s = dispatch_s
                results[cid] = (update,
                                self.task.nominal_work_seconds(ds))
        return results

    def _lap(self, t0: Optional[float]) -> Optional[float]:
        """Elapsed wall seconds since ``t0`` when timing is on."""
        if t0 is None:
            return None
        self.last_dispatch_s = \
            time.perf_counter() - t0  # repro-lint: disable=DET002
        return self.last_dispatch_s
