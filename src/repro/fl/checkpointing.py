"""Round-tagged checkpoint/resume of the training driver.

A checkpoint for round *r* (meaning: rounds ``0..r-1`` are done, round
*r* runs next) is two files in one directory::

    round_000004.npz    global model params (checkpoint/checkpoint.py)
    round_000004.json   driver state (TrainingDriver.checkpoint_state():
                        history payload, RNG streams, scheduler state,
                        cost tallies, virtual clock, trailing RoundStats)

Resume rebuilds the experiment wiring from the same config/seed, then
`RoundCheckpointer.restore` loads the params and replays the state into
the fresh driver — the remaining rounds then reproduce an uninterrupted
run exactly, provided no invocation was in flight across the checkpoint
boundary (a straggler still running at the boundary loses its future
arrival; everything billed before the boundary is preserved).  Surface:
``ExperimentConfig.checkpoint_dir``/``checkpoint_every`` to write,
``ExperimentConfig.resume_from`` to resume.
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, List, Optional, Tuple

from ..checkpoint.checkpoint import load_pytree, save_pytree

Pytree = Any


class RoundCheckpointer:
    """Writes/restores round-tagged driver checkpoints with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ---- write --------------------------------------------------------
    def save(self, driver, params: Pytree, next_round: int) -> Path:
        """Snapshot `driver` + `params` as the checkpoint for
        `next_round` (the first round a resumed run will execute)."""
        state = driver.checkpoint_state()
        state["next_round"] = int(next_round)
        save_pytree(params, str(self._params_path(next_round)))
        self._state_path(next_round).write_text(json.dumps(state))
        self._gc()
        return self._state_path(next_round)

    # ---- read ---------------------------------------------------------
    def rounds(self) -> List[int]:
        out = []
        for f in self.dir.glob("round_*.json"):
            m = re.match(r"round_(\d+)\.json$", f.name)
            if m and self._params_path(int(m.group(1))).exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_round(self) -> Optional[int]:
        rounds = self.rounds()
        return rounds[-1] if rounds else None

    def restore(self, driver, like_params: Pytree,
                round_number: Optional[int] = None) -> Tuple[Pytree, int]:
        """Load the checkpoint (latest by default) into `driver` and
        return ``(params, next_round)``."""
        rnd = round_number if round_number is not None else self.latest_round()
        if rnd is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        state = json.loads(self._state_path(rnd).read_text())
        for field, have in (("strategy", driver.strategy.name),
                            ("scheduler_name", driver.scheduler.name),
                            ("mode", driver.mode)):
            want = state.get(field)
            if want is not None and want != have:
                raise ValueError(
                    f"checkpoint was written with {field}={want!r}, "
                    f"driver runs {have!r}")
        params = load_pytree(str(self._params_path(rnd)), like_params)
        driver.restore_state(state)
        return params, int(state["next_round"])

    # ---- internals ----------------------------------------------------
    def _params_path(self, rnd: int) -> Path:
        return self.dir / f"round_{rnd:06d}.npz"

    def _state_path(self, rnd: int) -> Path:
        return self.dir / f"round_{rnd:06d}.json"

    def _gc(self) -> None:
        for rnd in self.rounds()[:-self.keep]:
            self._params_path(rnd).unlink(missing_ok=True)
            self._state_path(rnd).unlink(missing_ok=True)
