"""Full-fidelity checkpoint/resume of the training driver.

A checkpoint tagged *r* is two files in one directory::

    round_000004.npz    arrays: the global params plus every pytree the
                        snapshot references (in-flight rounds' global
                        params, cached client updates, semi-async/FedBuff
                        update buffers) and a `_meta` pair descriptor
    round_000004.json   driver state (TrainingDriver.checkpoint_state():
                        history payload, RNG streams, scheduler state,
                        cost tallies, virtual clock, trailing RoundStats,
                        the pending event queue, the invocation engine's
                        in-flight state, warm pools / fleet routing, and
                        — in async mode — the barrier-free loop state)

Schema v2 checkpoints are **event-queue snapshots**: the pending
timeline (events + seq counter) and every in-flight invocation are part
of the state, so a restored run replays the remaining events
byte-identically to an uninterrupted same-seed run — in-flight
stragglers included.  In barrier modes the tag is the next round to
execute; in async mode there is no round, so `checkpoint_every` counts
*virtual seconds* and the tag is a monotone snapshot index (resume
always continues mid-timeline from the restored loop state).

Both files are written to temp names and moved into place with
``os.replace``, so a crash mid-write can never leave a torn file; the
JSON and npz of one tag carry a matching ``pair`` descriptor (schema,
tag, virtual clock, charge count) that `restore` validates, so a
half-updated pair is rejected loudly instead of silently resumed.

Schema v1 checkpoints (PR 3, round-boundary only) still load: they
migrate to an empty-queue snapshot, which preserves their documented
semantics (any invocation in flight at the boundary loses its future
arrival).  Surface: ``ExperimentConfig.checkpoint_dir`` /
``checkpoint_every`` to write, ``ExperimentConfig.resume_from`` to
resume.
"""
from __future__ import annotations

import json
import os
import re
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..checkpoint.checkpoint import _flatten_with_paths, _path_str, load_pytree

Pytree = Any

SCHEMA_VERSION = 2
_SEP = "|"
_META_KEY = "_meta"


def _flat_entries(prefix: str, tree: Pytree) -> Dict[str, np.ndarray]:
    flat, _ = _flatten_with_paths(tree)
    return {f"{prefix}{_SEP}{k}": v for k, v in flat.items()}


def _flat_entries_raw(prefix: str, tree: Pytree) -> Dict[str, Any]:
    """Like `_flat_entries` but leaves stay device-resident — the caller
    fetches the whole snapshot with one batched `jax.device_get` instead
    of one blocking per-leaf transfer."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {f"{prefix}{_SEP}" + _SEP.join(_path_str(p) for p in kp): leaf
            for kp, leaf in flat}


def _unflatten_like(data, prefix: str, like: Pytree,
                    force_dtype=None) -> Pytree:
    """Rebuild a pytree with `like`'s structure from `prefix|<path>` npz
    entries (shape-checked; dtype restored from `like`, or `force_dtype`
    for state that must not inherit the params dtype — e.g. fp32
    server-optimizer moments under a low-precision model)."""
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, leaf in flat_like:
        key = f"{prefix}{_SEP}" + _SEP.join(_path_str(p) for p in kp)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr.astype(force_dtype or np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _atomic_write_text(path: Path, text: str) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_text(text)
    os.replace(tmp, path)


def _atomic_write_npz(path: Path, entries: Dict[str, np.ndarray]) -> None:
    tmp = path.with_name(path.name + ".tmp")
    # np.savez appends ".npz" to bare filenames; an open handle keeps the
    # temp name exact so os.replace lands on the real target
    with open(tmp, "wb") as fh:
        np.savez(fh, **entries)
    os.replace(tmp, path)


class RoundCheckpointer:
    """Writes/restores tagged full-fidelity checkpoints with retention.

    Retention combines two policies (long async studies would otherwise
    accumulate unbounded npz/json pairs):

    * ``keep_last_n`` — the trailing N tags always survive (the resume
      frontier); ``keep`` is the historical alias for the same knob.
    * ``keep_best`` — additionally keep the top-K tags by a history
      metric: ``best_metric`` names a `RoundStats` field (``accuracy``
      by default, ``eur``/``cost``/… work too) and the score of a save
      is that field's most recent non-None value in the driver's
      trailing stats window; pass a callable ``(driver, params, tag) →
      float`` for custom scoring.  Tags without a score are never
      retained as "best".

    GC deletes a pruned tag's npz *before* its json: `rounds()` only
    lists tags with both files present, so a crash between the two
    unlinks leaves a torn pair that is already invisible to `restore`
    (and cleaned up by the next GC) rather than a loadable half-pair.
    """

    def __init__(self, directory: str, keep: int = 3,
                 keep_last_n: Optional[int] = None, keep_best: int = 0,
                 best_metric="accuracy"):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep if keep_last_n is None else keep_last_n
        self.keep_best = keep_best
        self.best_metric = best_metric
        self._scores: Dict[int, Optional[float]] = {}

    # ---- write --------------------------------------------------------
    def _score(self, driver, params: Pytree, tag: int) -> Optional[float]:
        if not self.keep_best:
            return None
        if callable(self.best_metric):
            return self.best_metric(driver, params, tag)
        for stats in reversed(getattr(driver, "_recent_stats", [])):
            value = getattr(stats, self.best_metric, None)
            if value is not None:
                return float(value)
        return None

    def save(self, driver, params: Pytree, next_round: int) -> Path:
        """Snapshot `driver` + `params` under tag `next_round` (barrier
        modes: the first round a resumed run will execute; async mode:
        the snapshot index — resume continues mid-timeline)."""
        arrays: Dict[str, Pytree] = {}
        state = driver.checkpoint_state(arrays)
        state["schema"] = SCHEMA_VERSION
        state["next_round"] = int(next_round)
        score = self._score(driver, params, next_round)
        if score is not None:
            state["score"] = score
        self._scores[int(next_round)] = score
        # the pair descriptor ties the two files of one save together:
        # clock + charge count make it unique across re-saves of a tag
        pair = {"schema": SCHEMA_VERSION, "tag": int(next_round),
                "clock": float(driver.queue.clock.now),
                "charges": int(driver.cost.invocations)}
        state["pair"] = pair
        state["array_keys"] = sorted(arrays)

        entries = _flat_entries_raw("params", params)
        for key, tree in arrays.items():
            entries.update(_flat_entries_raw(f"extra{_SEP}{key}", tree))
        # one batched host fetch for the whole snapshot — params, server
        # moments, and every cached in-flight update sync together
        fetched = jax.device_get(list(entries.values()))
        entries = {k: np.asarray(v) for k, v in zip(entries, fetched)}
        entries[_META_KEY] = np.array(json.dumps(pair, sort_keys=True))
        _atomic_write_npz(self._params_path(next_round), entries)
        _atomic_write_text(self._state_path(next_round), json.dumps(state))
        self._gc()
        return self._state_path(next_round)

    # ---- read ---------------------------------------------------------
    def rounds(self) -> List[int]:
        out = []
        for f in self.dir.glob("round_*.json"):
            m = re.match(r"round_(\d+)\.json$", f.name)
            if m and self._params_path(int(m.group(1))).exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_round(self) -> Optional[int]:
        rounds = self.rounds()
        return rounds[-1] if rounds else None

    def restore(self, driver, like_params: Pytree,
                round_number: Optional[int] = None) -> Tuple[Pytree, int]:
        """Load the checkpoint (latest by default) into `driver` and
        return ``(params, next_round)`` (async checkpoints return
        ``next_round=0`` — the restored loop state carries the position).
        """
        rnd = round_number if round_number is not None else self.latest_round()
        if rnd is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        state = json.loads(self._state_path(rnd).read_text())
        for field, have in (("strategy", driver.strategy.name),
                            ("scheduler_name", driver.scheduler.name),
                            ("mode", driver.mode)):
            want = state.get(field)
            if want is not None and want != have:
                raise ValueError(
                    f"checkpoint was written with {field}={want!r}, "
                    f"driver runs {have!r}")
        schema = int(state.get("schema", 1))
        if schema > SCHEMA_VERSION:
            raise ValueError(
                f"checkpoint {self._state_path(rnd)} has schema {schema}; "
                f"this build reads up to {SCHEMA_VERSION}")
        if schema >= 2:
            params, arrays = self._load_arrays(rnd, state, like_params)
        else:
            # schema v1 (PR 3): params-only npz, no timeline snapshot —
            # restores with the old round-boundary semantics (in-flight
            # invocations at the boundary lose their future arrival)
            params, arrays = load_pytree(str(self._params_path(rnd)),
                                         like_params), {}
        driver.restore_state(state, arrays)
        if "async" in state:
            return params, 0
        return params, int(state["next_round"])

    def _load_arrays(self, rnd: int, state: dict, like_params: Pytree):
        data = np.load(self._params_path(rnd), allow_pickle=False)
        if _META_KEY not in data:
            raise ValueError(
                f"checkpoint pair mismatch at tag {rnd}: "
                f"{self._params_path(rnd).name} carries no pair "
                f"descriptor (torn or foreign write)")
        meta = json.loads(str(data[_META_KEY]))
        if meta != state.get("pair"):
            raise ValueError(
                f"checkpoint pair mismatch at tag {rnd}: the .json and "
                f".npz descriptors disagree ({state.get('pair')} vs "
                f"{meta}) — the pair is torn (crash mid-write?); delete "
                f"it or resume from an older tag")
        params = _unflatten_like(data, "params", like_params)
        # every extra tree shares the model-params structure (round
        # params, cached client updates, pending/buffered updates);
        # server-optimizer moments and compression error-feedback
        # residuals stay fp32 regardless of params dtype
        arrays = {key: _unflatten_like(
            data, f"extra{_SEP}{key}", like_params,
            force_dtype=(np.float32
                         if key.startswith(("server_opt/", "compress/"))
                         else None))
            for key in state.get("array_keys", [])}
        return params, arrays

    # ---- internals ----------------------------------------------------
    def _params_path(self, rnd: int) -> Path:
        return self.dir / f"round_{rnd:06d}.npz"

    def _state_path(self, rnd: int) -> Path:
        return self.dir / f"round_{rnd:06d}.json"

    def _score_of(self, rnd: int) -> Optional[float]:
        """Score of an on-disk tag (reads the json once; pre-existing
        tags written by an earlier process are scored from their file)."""
        if rnd not in self._scores:
            try:
                state = json.loads(self._state_path(rnd).read_text())
                self._scores[rnd] = state.get("score")
            except (OSError, ValueError):
                self._scores[rnd] = None
        return self._scores[rnd]

    def _gc(self) -> None:
        tags = self.rounds()
        if self.keep:
            survivors = set(tags[-self.keep:])
        elif self.keep_best:
            # keep_last_n=0 with best-K retention: best-only GC — an
            # empty trailing window, not the legacy keep-everything
            survivors = set()
        else:
            # bare keep=0 retains everything (historical `[:-0]` no-op)
            survivors = set(tags)
        if self.keep_best:
            scored = [(self._score_of(t), t) for t in tags]
            ranked = sorted((s, t) for s, t in scored if s is not None)
            survivors.update(t for _, t in ranked[-self.keep_best:])
        for rnd in tags:
            if rnd in survivors:
                continue
            # npz first: the tag disappears from rounds() immediately, so
            # a crash between the two unlinks can't leave a loadable
            # half-pair (torn-pair-safe deletion)
            self._params_path(rnd).unlink(missing_ok=True)
            self._state_path(rnd).unlink(missing_ok=True)
            self._scores.pop(rnd, None)
        # sweep orphan jsons a crashed GC left behind (npz-before-json
        # order means a lone json is always GC litter, never a mid-save)
        for f in self.dir.glob("round_*.json"):
            m = re.match(r"round_(\d+)\.json$", f.name)
            if m and not self._params_path(int(m.group(1))).exists():
                f.unlink(missing_ok=True)
