"""Experiment harness — paper §VI-A4 scenarios.

standard     : deployed functions as-is; round timeout generous enough for
               healthy clients to finish.
straggler(%) : a fixed fraction of clients is made to straggle — half of
               them *slow* (finish after the round deadline: cold starts /
               bandwidth / weak VM) and half *crash* (never respond),
               matching the paper's two failure effects.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from ..core.history import ClientHistoryDB
from ..core.strategies import StrategyConfig, make_strategy
from ..data.synthetic import ArrayDataset
from ..faas.cost import CostMeter
from ..faas.invoker import MockInvoker
from ..faas.platform import ClientProfile, FaaSConfig, SimulatedFaaSPlatform
from ..faas.trace import TraceRecorder
from .client import ClientPool
from .controller import Controller, ExperimentResult
from .tasks import ClassificationTask


@dataclass
class ScenarioConfig:
    straggler_fraction: float = 0.0   # 0.0 → standard scenario
    slow_share: float = 0.5           # of stragglers: slow vs crash
    slow_factor: float = 6.0          # slowdown multiplier for slow clients
    slow_factor_jitter: float = 0.0   # ± uniform jitter on slow_factor —
                                      # heterogeneous speeds make the
                                      # clustering component observable
    round_timeout_s: float = 120.0
    seed: int = 0


@dataclass
class ExperimentConfig:
    strategy: str = "fedlesscan"
    n_rounds: int = 30
    clients_per_round: int = 10
    tau: int = 2
    fedprox_mu: float = 0.001
    eval_every: int = 5
    seed: int = 0
    faas: FaaSConfig = field(default_factory=FaaSConfig)
    scenario: ScenarioConfig = field(default_factory=ScenarioConfig)
    # event-engine surface
    # vectorized client execution (one vmapped dispatch per round):
    # None → auto (on for TPU/GPU, off for CPU where XLA executes the
    # batched conv gradients up to ~10x slower than the eager loop)
    vectorized: Optional[bool] = None
    max_retries: int = 1              # FedLess invoker retry bound
    max_concurrency: Optional[int] = None   # per-round in-flight cap
    platforms: Optional[Dict[str, str]] = None  # client -> provider name
    default_platform: str = "gcf-gen2"
    # training-mode surface (fl/controller.TrainingDriver)
    # None → derived from the strategy: async for barrier-free strategies
    # (fedasync, fedbuff), semi-async/sync otherwise
    mode: Optional[str] = None
    trace_path: Optional[str] = None  # export the JSONL trace here
    # scheduling surface (fl/scheduler.py): None → the strategy's own
    # scheduler (barrier modes) / the rotation (async); a name from
    # make_scheduler ("random", "fedlesscan", "apodotiko", "adaptive",
    # "rotation") overrides the cohort policy in any mode
    scheduler: Optional[str] = None
    # checkpoint/resume surface (fl/checkpointing.py, all three modes):
    # write a full-fidelity snapshot to `checkpoint_dir` every
    # `checkpoint_every` rounds (barrier modes) or virtual *seconds*
    # (async mode — there is no round boundary); `resume_from` restores
    # the latest checkpoint in a directory and replays the remaining
    # timeline exactly, in-flight invocations included
    checkpoint_dir: Optional[str] = None
    checkpoint_every: float = 0
    resume_from: Optional[str] = None
    # retention: keep the trailing N tags plus the top-K by a RoundStats
    # metric (fl/checkpointing.RoundCheckpointer) so long async studies
    # don't accumulate unbounded npz/json pairs
    checkpoint_keep_last_n: int = 3
    checkpoint_keep_best: int = 0
    checkpoint_best_metric: str = "accuracy"
    # barrier-free strategy knobs (core/strategies.StrategyConfig)
    buffer_k: int = 4
    async_alpha: float = 0.6
    server_lr: float = 0.7
    staleness_exponent: float = 0.5
    # server optimizer on the merge pipeline (core/merge.py): "sgd"
    # (identity — byte-identical legacy behaviour), "fedavgm",
    # "fedadagrad", "fedadam", or "fedyogi", with its hyperparameters
    server_opt: str = "sgd"
    server_opt_lr: float = 1.0
    server_opt_momentum: float = 0.0
    server_opt_b1: float = 0.9
    server_opt_b2: float = 0.99
    server_opt_eps: float = 1e-3
    # client update compression (core/compress.UpdateCompressor): "none"
    # (dense — byte-identical legacy traces), "topk" (top-k magnitude
    # sparsification of the delta), or "int8" (per-chunk-scaled int8
    # quantization), with error-feedback residuals on by default;
    # REPRO_COMPRESS=0 force-disables any scheme at run time
    compress_scheme: str = "none"
    compress_topk_ratio: float = 0.01
    compress_chunk: int = 256
    compress_error_feedback: bool = True
    # mesh-sharded merge: shard the aggregation/server-update kernels
    # over this many host devices (0/1 → single-device; >1 requires
    # XLA_FLAGS=--xla_force_host_platform_device_count≥N or real devices)
    merge_devices: int = 0
    # cohort-sharded executor: split the vectorized executor's K (cohort)
    # dim over this many devices on a 1-axis ("clients",) mesh
    # (launch/mesh.make_clients_mesh).  0/1 → the plain single-device
    # vmap path, bitwise-identical to pre-mesh builds; >1 requires
    # forced host devices or real accelerators and composes with
    # merge_devices so a round never funnels through one device.  Only
    # meaningful when `vectorized` resolves on.
    executor_devices: int = 0
    # stamp each executor group dispatch's wall-clock launch latency onto
    # its ClientUpdates / attempt trace records as `dispatch_s`
    # (only-when-set: default traces stay byte-identical)
    dispatch_timing: bool = False
    # round-pipeline compilation surface (launch/compile_cache.py):
    # a directory enables JAX's persistent compilation cache, so repeat
    # runs (and CI) skip XLA compiles entirely; executor_warmup runs one
    # throwaway vectorized dispatch before round 0 so compilation never
    # lands inside the timed loop (off by default — warm-up itself costs
    # one cohort's training compute)
    compilation_cache_dir: Optional[str] = None
    executor_warmup: bool = False


def make_straggler_profiles(client_ids, scenario: ScenarioConfig
                            ) -> Dict[str, ClientProfile]:
    """Randomly designate `straggler_fraction` of clients as stragglers at
    experiment start (paper §VI-A4), split between slow and crashing."""
    rng = np.random.default_rng(scenario.seed)
    ids = list(client_ids)
    n_strag = int(round(scenario.straggler_fraction * len(ids)))
    chosen = rng.choice(ids, size=n_strag, replace=False) if n_strag else []
    profiles: Dict[str, ClientProfile] = {}
    for i, cid in enumerate(chosen):
        if i < int(round(n_strag * scenario.slow_share)):
            f = scenario.slow_factor
            if scenario.slow_factor_jitter:
                f += float(rng.uniform(-scenario.slow_factor_jitter,
                                       scenario.slow_factor_jitter))
            profiles[cid] = ClientProfile(slow_factor=max(1.0, f))
        else:
            profiles[cid] = ClientProfile(crash=True)
    return profiles


def run_experiment(task: ClassificationTask,
                   train_partitions: Dict[str, ArrayDataset],
                   test_partitions: Optional[Dict[str, ArrayDataset]],
                   config: ExperimentConfig,
                   initial_params=None,
                   verbose: bool = False) -> ExperimentResult:
    """Wire up platform → invoker → controller and run one experiment."""
    if config.compilation_cache_dir:
        from ..launch.compile_cache import enable_compilation_cache
        enable_compilation_cache(config.compilation_cache_dir)
    history = ClientHistoryDB()
    history.ensure(train_partitions.keys())

    strat_cfg = StrategyConfig(
        clients_per_round=config.clients_per_round,
        max_rounds=config.n_rounds, tau=config.tau,
        fedprox_mu=config.fedprox_mu, buffer_k=config.buffer_k,
        async_alpha=config.async_alpha, server_lr=config.server_lr,
        staleness_exponent=config.staleness_exponent,
        server_opt=config.server_opt,
        server_opt_lr=config.server_opt_lr,
        server_opt_momentum=config.server_opt_momentum,
        server_opt_b1=config.server_opt_b1,
        server_opt_b2=config.server_opt_b2,
        server_opt_eps=config.server_opt_eps)
    strategy = make_strategy(config.strategy, strat_cfg, history,
                             seed=config.seed)

    recorder = TraceRecorder() if config.trace_path else None
    compressor = None
    if config.compress_scheme != "none":
        from ..core.compress import CompressionConfig, UpdateCompressor
        compressor = UpdateCompressor(CompressionConfig(
            scheme=config.compress_scheme,
            topk_ratio=config.compress_topk_ratio,
            chunk=config.compress_chunk,
            error_feedback=config.compress_error_feedback))
    pool = ClientPool(task, train_partitions, test_partitions,
                      proximal_mu=strategy.proximal_mu(), seed=config.seed,
                      compressor=compressor)
    if config.merge_devices and config.merge_devices > 1:
        # shard the merge kernels over host devices; the mesh clamps to
        # however many devices actually exist (single device → fallback)
        from ..launch.mesh import make_host_mesh
        strategy.merger.mesh = make_host_mesh(data=config.merge_devices)
    profiles = make_straggler_profiles(pool.client_ids, config.scenario)
    if config.platforms is not None:
        from ..faas.profiles import MultiPlatformInvoker
        invoker = MultiPlatformInvoker(
            pool.work_fn, config.platforms, profiles,
            default=config.default_platform, seed=config.seed)
        if recorder is not None:
            invoker.fleet.attach_recorder(recorder)
    else:
        platform = SimulatedFaaSPlatform(config.faas, seed=config.seed,
                                         recorder=recorder)
        invoker = MockInvoker(platform, pool.work_fn, profiles)

    vectorized = config.vectorized
    if vectorized is None:
        import jax
        vectorized = jax.default_backend() != "cpu"
    if vectorized:
        # the executor is cached on the task (shared across experiment
        # grids), so both knobs are set unconditionally — a later run
        # with defaults must not inherit a previous run's mesh/timing
        from ..launch.mesh import make_clients_mesh
        mesh = (make_clients_mesh(config.executor_devices)
                if config.executor_devices and config.executor_devices > 1
                else None)
        # shard the cohort dim over a 1-axis ("clients",) mesh; clamps to
        # the devices that exist (a size-1 mesh falls back to the
        # identical single-device vmap path)
        pool.executor.configure_mesh(mesh)
        pool.executor.collect_timing = bool(config.dispatch_timing)

    scheduler = None
    if config.scheduler is not None:
        from .scheduler import make_scheduler
        scheduler = make_scheduler(
            config.scheduler, config.clients_per_round, history=history,
            max_rounds=config.n_rounds, ema_alpha=strat_cfg.ema_alpha,
            client_ids=pool.client_ids,
            timeout_s=config.scenario.round_timeout_s, seed=config.seed)

    controller = Controller(
        strategy, invoker, pool, history, CostMeter(trace=recorder),
        round_timeout_s=config.scenario.round_timeout_s,
        eval_every=config.eval_every, seed=config.seed,
        max_retries=config.max_retries,
        max_concurrency=config.max_concurrency,
        vectorized=vectorized, mode=config.mode, trace=recorder,
        scheduler=scheduler)

    params = (initial_params if initial_params is not None
              else task.init_params(config.seed))

    start_round, checkpointer = 0, None
    if config.checkpoint_dir or config.resume_from:
        from .checkpointing import RoundCheckpointer
    if config.resume_from:
        params, start_round = RoundCheckpointer(
            config.resume_from).restore(controller, params)
    if config.checkpoint_dir:
        checkpointer = RoundCheckpointer(
            config.checkpoint_dir,
            keep_last_n=config.checkpoint_keep_last_n,
            keep_best=config.checkpoint_keep_best,
            best_metric=config.checkpoint_best_metric)

    if config.executor_warmup:
        controller.warmup_executor(params)
    _, result = controller.run(params, config.n_rounds, verbose=verbose,
                               start_round=start_round,
                               checkpointer=checkpointer,
                               checkpoint_every=config.checkpoint_every)
    if recorder is not None:
        recorder.to_jsonl(config.trace_path)
    return result
