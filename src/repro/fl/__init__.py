from .client import ClientPool, ClientState
from .controller import Controller, ExperimentResult, RoundStats
from .executor import VectorizedExecutor
from .metrics import (bias, effective_update_ratio, invocation_distribution,
                      weighted_accuracy)
from .tasks import ClassificationTask, TaskConfig

__all__ = ["ClientPool", "ClientState", "Controller", "ExperimentResult",
           "RoundStats", "VectorizedExecutor",
           "bias", "effective_update_ratio",
           "invocation_distribution", "weighted_accuracy",
           "ClassificationTask", "TaskConfig"]
