from .checkpointing import RoundCheckpointer
from .client import ClientPool, ClientState
from .controller import (Controller, ExperimentResult, RoundStats,
                         TrainingDriver)
from .executor import VectorizedExecutor
from .metrics import (bias, effective_update_ratio, invocation_distribution,
                      time_to_accuracy, trailing_eur,
                      trailing_straggler_ratio, weighted_accuracy,
                      windowed_update_ratio)
from .scheduler import (SCHEDULERS, AdaptiveScheduler, ApodotikoScheduler,
                        FedLesScanScheduler, FullPoolScheduler,
                        RandomScheduler, RotationScheduler, Scheduler,
                        StrategySelectScheduler, make_scheduler)
from .tasks import ClassificationTask, TaskConfig

__all__ = ["ClientPool", "ClientState", "Controller", "ExperimentResult",
           "RoundStats", "TrainingDriver", "VectorizedExecutor",
           "RoundCheckpointer",
           "bias", "effective_update_ratio",
           "invocation_distribution", "weighted_accuracy",
           "windowed_update_ratio", "trailing_eur",
           "trailing_straggler_ratio", "time_to_accuracy",
           "SCHEDULERS", "Scheduler", "RandomScheduler",
           "FullPoolScheduler", "FedLesScanScheduler", "ApodotikoScheduler",
           "AdaptiveScheduler", "RotationScheduler",
           "StrategySelectScheduler", "make_scheduler",
           "ClassificationTask", "TaskConfig"]
