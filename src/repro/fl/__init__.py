from .client import ClientPool, ClientState
from .controller import (Controller, ExperimentResult, RoundStats,
                         TrainingDriver)
from .executor import VectorizedExecutor
from .metrics import (bias, effective_update_ratio, invocation_distribution,
                      weighted_accuracy, windowed_update_ratio)
from .tasks import ClassificationTask, TaskConfig

__all__ = ["ClientPool", "ClientState", "Controller", "ExperimentResult",
           "RoundStats", "TrainingDriver", "VectorizedExecutor",
           "bias", "effective_update_ratio",
           "invocation_distribution", "weighted_accuracy",
           "windowed_update_ratio",
           "ClassificationTask", "TaskConfig"]
