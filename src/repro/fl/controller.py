"""TrainingDriver — mode-agnostic FL runtime on the shared event queue.

The FedLess controller (paper Algorithm 1, Train_Global_Model) is one
point on a sync→async spectrum.  This module runs all of it from a
single event loop over the shared `EventQueue`:

* ``sync`` / ``semi-async`` — today's round-barrier semantics: per round
  the driver asks the Strategy Manager for a cohort, hands it to the
  event-driven `InvocationEngine`, and drains the queue until the round
  closes (deadline, SAFA quorum's k-th success, or last in-time finish).
  Because the queue persists across rounds, a straggler's CLIENT_FINISH
  from round *t* fires during round *t+1* (or later) at its true
  virtual arrival time, and semi-async strategies receive it through
  `Strategy.on_client_finish` exactly then.  The two names share one
  code path; the mode label records whether the strategy accepts late
  updates.

* ``async`` — barrier-free (the Apodotiko / flwr-serverless regime):
  there is no round at all.  The driver keeps `clients_per_round`
  logical slots filled, re-invokes a client the moment a slot frees,
  and delivers every arrival to `Strategy.on_client_finish` with the
  current global model — barrier-free strategies (FedAsync, FedBuff)
  return a *new* global model from the hook and the driver versions it
  continuously.  Each invocation is its own engine ticket with its own
  crash-detection deadline; a slow client past its ticket deadline
  keeps running — its stale update merges on arrival with a
  staleness-damped weight while a replacement keeps throughput up.
  `RoundStats` entries are emitted per *aggregation event*, with EUR
  computed over the window between events (updates delivered /
  invocations resolved — `metrics.windowed_update_ratio`).

Every client-picking decision — sync round cohorts, semi-async refills,
and the async slot rotation with its exponential failure backoff —
lives in the `Scheduler` subsystem (fl/scheduler.py): the driver asks
``scheduler.cohort_size`` how many to invoke, ``scheduler.propose`` whom,
and reports every completion/miss back through ``notify_finish`` /
``notify_miss``.  Each propose is exported as a ``scheduling`` record in
the JSONL trace.  By default the barrier modes use the strategy's own
scheduler (the `Strategy.select` shim's engine) and the async mode a
`RotationScheduler`; pass `scheduler=` to race any policy in any mode.

`Controller` remains as a thin alias and `run_round`/`run` keep their
original signatures, so existing experiments, benchmarks and tests run
unmodified on the new driver.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.history import ClientHistoryDB
from ..core.strategies import Strategy
from ..faas.cost import CostMeter
from ..faas.events import EventKind, EventQueue
from ..faas.invoker import ClientCompletion, InvocationEngine, MockInvoker
from .client import ClientPool
from .metrics import (bias, effective_update_ratio, weighted_accuracy,
                      windowed_update_ratio)
from .scheduler import (RotationScheduler, Scheduler,
                        StrategySelectScheduler,
                        scheduler_supports_exclude)

Pytree = Any

MODES = ("sync", "semi-async", "async")


@dataclass
class RoundStats:
    round_number: int
    selected: List[str]
    successes: List[str]
    late: List[str]
    crashed: List[str]
    duration_s: float
    eur: float
    cost: float
    accuracy: Optional[float] = None
    aggregated_updates: int = 0
    retries: int = 0
    # updates from earlier rounds that physically arrived during this round
    straggler_arrivals: List[str] = field(default_factory=list)


@dataclass
class ExperimentResult:
    strategy: str
    mode: str = "sync"
    rounds: List[RoundStats] = field(default_factory=list)
    final_accuracy: float = 0.0
    accuracy_curve: List[tuple] = field(default_factory=list)
    # cost attribution (CostMeter breakdown), populated by run()
    cost_by_client: Dict[str, float] = field(default_factory=dict)
    cost_by_round: Dict[int, float] = field(default_factory=dict)

    @property
    def total_duration_s(self) -> float:
        return sum(r.duration_s for r in self.rounds)

    @property
    def total_cost(self) -> float:
        return sum(r.cost for r in self.rounds)

    @property
    def mean_eur(self) -> float:
        """Barrier modes: the paper's mean of per-round EURs.  Async mode:
        the run-level merged/resolved ratio — averaging per-window ratios
        would overweight the (tiny, mostly-1.0) merge windows and dilute
        the crash probes concentrated in few windows."""
        if not self.rounds:
            return 1.0
        if self.mode == "async":
            delivered = sum(len(r.successes) for r in self.rounds)
            resolved = delivered + sum(len(r.crashed) for r in self.rounds)
            return windowed_update_ratio(delivered, resolved)
        return float(np.mean([r.eur for r in self.rounds]))

    def invocation_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for r in self.rounds:
            for cid in r.selected:
                counts[cid] = counts.get(cid, 0) + 1
        return counts

    @property
    def bias(self) -> int:
        return bias(self.invocation_counts())


class _AsyncTicket:
    """One logical invocation in barrier-free mode."""

    __slots__ = ("client_id", "version", "deadline", "replaced")

    def __init__(self, client_id: str, version: int, deadline):
        self.client_id = client_id
        self.version = version          # model version the client trains on
        # crash-detection ROUND_DEADLINE event — None after a restore when
        # the deadline had already fired (late-but-alive ticket)
        self.deadline = deadline
        self.replaced = False           # slot already refilled at deadline?

    def cancel_deadline(self) -> None:
        if self.deadline is not None:
            self.deadline.cancel()


class TrainingDriver:
    """Mode-agnostic training runtime (see module docstring)."""

    def __init__(self, strategy: Strategy, invoker: MockInvoker,
                 pool: ClientPool, history: ClientHistoryDB,
                 cost_meter: Optional[CostMeter] = None,
                 round_timeout_s: float = 120.0,
                 eval_every: int = 5, eval_fraction: float = 0.2,
                 seed: int = 0, max_retries: int = 1,
                 max_concurrency: Optional[int] = None,
                 vectorized: bool = False,
                 mode: Optional[str] = None, trace=None,
                 scheduler: Optional[Scheduler] = None):
        self.strategy = strategy
        self.invoker = invoker
        self.pool = pool
        self.history = history
        self.cost = cost_meter or CostMeter()
        self.round_timeout_s = round_timeout_s
        self.eval_every = eval_every
        self.eval_fraction = eval_fraction
        self.rng = np.random.default_rng(seed)
        self.vectorized = vectorized
        self.platform = invoker.platform
        if mode is None:
            mode = ("async" if getattr(strategy, "barrier_free", False)
                    else "semi-async" if strategy.semi_async else "sync")
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; available: {MODES}")
        if mode == "async" and not getattr(strategy, "barrier_free", False):
            raise ValueError(
                f"strategy {strategy.name!r} has a round barrier; async "
                f"mode needs a barrier-free strategy (fedasync, fedbuff)")
        self.mode = mode
        self.trace = trace
        # all cohort decisions route through one Scheduler: the strategy's
        # own (via the Strategy.select shim's engine) in barrier modes,
        # the deterministic rotation in barrier-free mode — or any policy
        # injected by the caller
        if scheduler is not None:
            self.scheduler = scheduler
        elif self.mode == "async":
            self.scheduler = RotationScheduler(
                strategy.config.clients_per_round, pool.client_ids,
                timeout_s=round_timeout_s, seed=seed)
        elif type(strategy).select is not Strategy.select:
            # legacy subclass with a hand-written select override: its
            # policy keeps winning over the default scheduler
            self.scheduler = StrategySelectScheduler(strategy)
        else:
            self.scheduler = strategy.scheduler
        self._recent_stats: List[RoundStats] = []   # cohort_size telemetry
        # legacy Strategy subclasses may override aggregate() without the
        # global_params kwarg (pre-merge-pipeline signature): detect once
        # and call them the old way — they keep their exact behaviour
        import inspect
        agg_params = inspect.signature(strategy.aggregate).parameters
        self._agg_takes_global = (
            "global_params" in agg_params
            or any(p.kind is p.VAR_KEYWORD for p in agg_params.values()))
        # one event queue on the platform's clock, shared across rounds —
        # straggler events survive round boundaries
        self.queue = EventQueue(self.platform.clock, recorder=trace)
        self.engine = InvocationEngine(invoker, max_retries=max_retries,
                                       max_concurrency=max_concurrency,
                                       recorder=trace)
        # barrier-free bookkeeping (tickets never collide with round ids);
        # a plain int so the counter position is checkpointable
        self._next_ticket = 1 << 20
        # mid-run async state: live during _run_async (the checkpoint
        # reads it), pre-loaded by restore_state for a resumed run
        self._async_live: Optional[Dict[str, Any]] = None
        self._async_resume: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------------
    def _evaluate(self, params: Pytree) -> float:
        """Paper §VI-A5: accuracy on a random subset of clients' test sets,
        weighted by test cardinality."""
        clients = getattr(self.pool, "clients", {})
        ids = [cid for cid in self.pool.client_ids
               if getattr(clients.get(cid), "test_dataset", None) is not None]
        if not ids:
            return 0.0
        k = max(1, int(len(ids) * self.eval_fraction))
        sample = self.rng.choice(ids, size=min(k, len(ids)), replace=False)
        per_client = []
        for cid in sample:
            ds = self.pool.clients[cid].test_dataset
            acc, _ = self.pool.task.evaluate(params, ds)
            per_client.append((acc, len(ds)))
        return weighted_accuracy(per_client)

    def _print_progress(self, label: str, stats: RoundStats) -> None:
        acc = f" acc={stats.accuracy:.3f}" if stats.accuracy else ""
        print(f"[{self.strategy.name}] {label} {stats.round_number:3d} "
              f"eur={stats.eur:.2f} dur={stats.duration_s:6.1f}s "
              f"cost=${stats.cost:.4f}{acc}")

    def _record_aggregation(self, time: float, round_number: int,
                            merged: int, payload_bytes: Optional[int] = None,
                            dense_bytes: Optional[int] = None) -> None:
        if self.trace is None:
            return
        extra = {}
        merger = getattr(self.strategy, "merger", None)
        if merger is not None and not merger.is_identity:
            # server-opt metadata + ‖Δ‖₂ diagnostics ride the aggregation
            # record; the identity default adds nothing, keeping legacy
            # traces byte-identical (a zero-update merge reads norm 0.0)
            extra = {"server_opt": merger.config.name,
                     "server_steps": merger.steps,
                     "update_norm": merger.last_update_norm}
        if payload_bytes is not None:
            # compressed-update telemetry: total encoded wire bytes that
            # fed this merge and the achieved ratio vs dense fp32; dense
            # runs carry no payload and the record keeps its legacy keys
            extra["payload_bytes"] = int(payload_bytes)
            if dense_bytes:
                extra["compression_ratio"] = round(
                    float(dense_bytes) / float(payload_bytes), 4)
        self.trace.aggregation(time=time, round_number=round_number,
                               merged=merged,
                               strategy=self.strategy.name,
                               mode=self.mode, **extra)

    def _record_scheduling(self, time: float, round_number: int, want: int,
                           selected: List[str], pool_size: int) -> None:
        if self.trace is not None:
            self.trace.scheduling(time=time, round_number=round_number,
                                  scheduler=self.scheduler.name,
                                  mode=self.mode, want=want,
                                  selected=list(selected),
                                  pool_size=pool_size,
                                  **self.scheduler.decision_info())

    # ------------------------------------------------------------------
    # barrier path (sync / semi-async)
    # ------------------------------------------------------------------
    def _precompute_updates(self, selected: List[str], global_params: Pytree,
                            round_number: int) -> Optional[Dict[str, tuple]]:
        """Vectorized client execution: run every live selected client's
        local epochs as one vmapped dispatch (fl/executor.py) and feed the
        results to the engine as the per-client work cache."""
        if not (self.vectorized and hasattr(self.pool, "batch_work_fn")):
            return None
        # under a concurrency cap only the first `cap` clients fire at
        # round start — precompute just those; cap-released clients fall
        # back to the per-client work_fn when their slot opens
        cap = self.engine.max_concurrency or len(selected)
        profiles = getattr(self.invoker, "profiles", {})
        alive = [cid for cid in selected[:cap]
                 if not getattr(profiles.get(cid), "crash", False)]
        if not alive:
            return None
        return self.pool.batch_work_fn(alive, global_params, round_number)

    def warmup_executor(self, global_params: Pytree) -> int:
        """Opt-in compile warm-up (ExperimentConfig.executor_warmup):
        dispatch the vectorized executor once for the cohort-bucket
        shapes round 0 would use, so XLA compilation happens before the
        timed loop.  Touches no round state — no packaging, no
        compressor residuals, no history.  Returns the executor's
        cumulative compile count (0 when not vectorized)."""
        if not (self.vectorized and hasattr(self.pool, "batch_work_fn")
                and hasattr(self.pool, "executor")):
            return 0
        want = self.strategy.config.clients_per_round
        cids = list(self.pool.client_ids)[:want]
        if not cids:
            return 0
        return self.pool.executor.warmup(self.pool, cids, global_params)

    def _handle_straggler(self, completion: ClientCompletion,
                          arrival_time: float, current_round: int) -> float:
        """A client from an earlier round finished mid-flight: record its
        (client-side) report now and hand the update to the strategy at
        its true virtual arrival time (Alg. 1 lines 16-27).  Returns the
        egress cost of its (late) update upload."""
        out = completion.outcome
        self.history.client_report(out.client_id, completion.round_number,
                                   out.duration_s)
        self.scheduler.notify_finish(out.client_id, arrival_time,
                                     duration_s=out.duration_s,
                                     cold=out.cold, late=True)
        self.strategy.on_client_finish(
            completion.update, arrival_time=arrival_time,
            producing_round=completion.round_number,
            current_round=current_round)
        return self._charge_egress(completion.update, out.client_id,
                                   current_round)

    def _charge_egress(self, update, client_id: str, round_number) -> float:
        """Bill the update's encoded upload (no-op for dense updates)."""
        if update is None or update.payload_bytes is None:
            return 0.0
        return self.cost.charge_egress(update.payload_bytes,
                                       client_id=client_id,
                                       round_number=round_number)

    def _bill_attempts(self, completion: ClientCompletion,
                       round_number: int) -> float:
        """Every attempt of a retried invocation is billed (FedLess retries
        are real invocations on the provider's meter)."""
        return sum(self.cost.charge(fa.duration_s,
                                    client_id=completion.client_id,
                                    round_number=round_number)
                   for fa in completion.failed_attempts)

    def run_round(self, global_params: Pytree,
                  round_number: int) -> tuple:
        """One Train_Global_Model iteration. Returns (params, RoundStats)."""
        if self.mode == "async":
            raise RuntimeError("run_round is a barrier API; the async mode "
                               "runs barrier-free — use run()")
        clock = self.queue.clock
        t0 = clock.now
        deadline = t0 + self.round_timeout_s

        # the Scheduler owns the cohort decision: how many (adaptive
        # sizing over trailing RoundStats) and whom
        want = self.scheduler.cohort_size(round_number, self._recent_stats)
        selected = self.scheduler.propose(self.pool.client_ids, want, t0,
                                          round_number)
        self.strategy.last_plan = getattr(self.scheduler, "last_plan",
                                          self.strategy.last_plan)
        self._record_scheduling(t0, round_number, want, selected,
                                len(self.pool.client_ids))
        # deferred, not eager: the engine runs the provider when the
        # round's first INVOKE_START fires — with overlapped dispatch
        # (REPRO_OVERLAP_DISPATCH, default on) the vmapped executor
        # launch returns unready device handles and the round's event /
        # trace / billing bookkeeping overlaps the device compute.  Same
        # virtual time, same client order → traces stay byte-identical
        # to the eager precompute.
        self.engine.open_round(
            self.queue, selected, global_params, round_number, t0,
            work_provider=lambda: self._precompute_updates(
                selected, global_params, round_number))
        deadline_ev = self.queue.schedule(deadline, EventKind.ROUND_DEADLINE,
                                          round_number=round_number)

        # SAFA-style dynamic quorum: the round closes at the k-th fastest
        # response instead of a fixed timeout (still capped by it).
        quorum = getattr(self.strategy, "quorum", None)

        successes: List[ClientCompletion] = []
        failed: List[ClientCompletion] = []
        straggler_arrivals: List[str] = []
        round_cost = 0.0
        retries = 0
        close_time = deadline

        while True:
            ev = self.queue.pop()
            if ev is None:
                break
            if ev.kind is EventKind.ROUND_DEADLINE:
                if ev.round_number == round_number:
                    break
                continue
            completion = self.engine.handle(self.queue, ev)
            if completion is None:
                continue
            if completion.round_number != round_number:
                # a straggler from an earlier round arriving mid-flight
                round_cost += self._bill_attempts(completion, round_number)
                if completion.success:
                    straggler_arrivals.append(completion.client_id)
                    round_cost += self._handle_straggler(completion, ev.time,
                                                         round_number)
                continue
            round_cost += self._bill_attempts(completion, round_number)
            retries += completion.attempts - 1
            if completion.success:
                successes.append(completion)
                self.strategy.on_client_finish(
                    completion.update, arrival_time=ev.time,
                    producing_round=round_number,
                    current_round=round_number)
                if quorum and len(successes) >= quorum:
                    close_time = ev.time
                    deadline_ev.cancel()
                    break
                if not failed and len(successes) == len(selected):
                    # everyone answered in time: close at the last finish
                    close_time = ev.time
                    deadline_ev.cancel()
                    break
            else:
                failed.append(completion)
            if (quorum
                    and self.engine.unresolved_count(round_number) == 0):
                # quorum unreachable — every remaining client resolved
                # observably, so the k-th response will never come; close
                # at the last terminal event instead of the full timeout
                close_time = ev.time
                deadline_ev.cancel()
                break

        late_ids, dead_ids, unstarted = self.engine.close_round(round_number,
                                                                close_time)
        duration = close_time - t0
        clock.advance_to(close_time)

        # --- controller-side history + billing (Alg. 1 lines 5-13) -----
        for comp in successes:
            out = comp.outcome
            self.history.mark_success(out.client_id, round_number)
            # client-side report (Alg. 1 lines 16-27) — in-time client
            self.history.client_report(out.client_id, round_number,
                                       out.duration_s)
            self.scheduler.notify_finish(out.client_id, close_time,
                                         duration_s=out.duration_s,
                                         cold=out.cold)
            round_cost += self.cost.charge(out.duration_s,
                                           client_id=out.client_id,
                                           round_number=round_number)
            # compressed runs also pay for shipping the encoded update
            round_cost += self._charge_egress(comp.update, out.client_id,
                                              round_number)
        for cid in late_ids:
            # alive but past the deadline: a miss now; its report and its
            # update arrive with its CLIENT_FINISH event in a later round
            self.history.mark_miss(cid, round_number)
            self.scheduler.notify_miss(cid, close_time, crashed=False)
            round_cost += self.cost.charge_straggler(duration, client_id=cid,
                                                     round_number=round_number)
        for comp in failed:
            self.history.mark_miss(comp.outcome.client_id, round_number)
            self.scheduler.notify_miss(comp.outcome.client_id, close_time)
            round_cost += self.cost.charge_straggler(
                duration, client_id=comp.outcome.client_id,
                round_number=round_number)
        for cid in dead_ids:
            self.history.mark_miss(cid, round_number)
            self.scheduler.notify_miss(cid, close_time)
            round_cost += self.cost.charge_straggler(duration, client_id=cid,
                                                     round_number=round_number)
        for cid in unstarted:
            # never invoked (concurrency cap): a miss, but nothing billed
            self.history.mark_miss(cid, round_number)
            self.scheduler.notify_miss(cid, close_time, crashed=False)

        # --- aggregation runs at round close (virtual now) --------------
        self.strategy.on_round_close(round_number, now=close_time)
        updates = [c.update for c in successes if c.update is not None]
        if self._agg_takes_global:
            new_params = self.strategy.aggregate(
                updates, round_number, now=close_time,
                global_params=global_params)
        else:                       # legacy pre-pipeline override
            new_params = self.strategy.aggregate(updates, round_number,
                                                 now=close_time)
        if new_params is None:
            new_params = global_params
        # wire-size telemetry for the aggregation record: every update the
        # strategy received this round (in-time + straggler arrivals);
        # dense updates carry no payload, so legacy records are unchanged
        carried = [u for u in updates if u.payload_bytes is not None]
        payload_total = (sum(u.payload_bytes for u in carried)
                         if carried else None)
        dense_total = sum(u.dense_bytes or 0 for u in carried)
        self._record_aggregation(close_time, round_number,
                                 self.strategy.last_aggregate_count,
                                 payload_bytes=payload_total,
                                 dense_bytes=dense_total)

        crashed_ids = ([c.outcome.client_id for c in failed]
                       + dead_ids + unstarted)
        stats = RoundStats(
            round_number=round_number, selected=list(selected),
            successes=[c.outcome.client_id for c in successes],
            late=late_ids, crashed=crashed_ids,
            duration_s=float(duration),
            eur=effective_update_ratio(len(successes), len(selected)),
            cost=round_cost,
            aggregated_updates=self.strategy.last_aggregate_count,
            retries=retries,
            straggler_arrivals=straggler_arrivals)
        # trailing telemetry window for Scheduler.cohort_size
        self._recent_stats.append(stats)
        del self._recent_stats[:-16]
        return new_params, stats

    # ------------------------------------------------------------------
    # barrier-free path (async)
    # ------------------------------------------------------------------
    def _run_async(self, global_params: Pytree, n_rounds: int,
                   verbose: bool = False, checkpointer=None,
                   checkpoint_every: float = 0.0) -> tuple:
        """Barrier-free loop: deliver `n_rounds × clients_per_round`
        updates (the same update budget a clean sync run would get),
        emitting one RoundStats window per aggregation event.

        All loop state lives in one dict `S` so a checkpoint can snapshot
        it between events: with a `checkpointer`, an event-horizon
        snapshot is written every `checkpoint_every` *virtual seconds*
        (there is no round boundary to count), and `restore_state`
        pre-loads `S` for a resumed run to continue mid-timeline."""
        cohort_size = self.strategy.config.clients_per_round
        # the vmapped executor batches a round cohort; one-client tickets
        # have no cohort, so async always trains through the per-client
        # work_fn (vectorized is a barrier-mode knob)
        clock = self.queue.clock
        S, self._async_resume = self._async_resume, None
        if S is not None:
            S["params"] = global_params      # restored by the checkpointer
        else:
            target = n_rounds * cohort_size
            S = {
                "target": target,
                "version": 0,        # global model version (bumps per merge)
                "delivered_total": 0,
                "next_eval": (self.eval_every * cohort_size
                              if self.eval_every else 0),
                # hard budget so a fully-dead population terminates instead
                # of probing forever: the queue drains once nothing new is
                # issued
                "issue_budget": (target * 20
                                 + 10 * len(self.pool.client_ids)),
                "issued_total": 0,
                "snapshots": 0,
                "tickets": {},       # tid -> _AsyncTicket
                "in_flight": set(),
                "window": self._fresh_window(clock.now),
                "result": ExperimentResult(strategy=self.strategy.name,
                                           mode=self.mode),
                "params": global_params,
            }
        self._async_live = S
        result = S["result"]
        tickets: Dict[int, _AsyncTicket] = S["tickets"]
        in_flight: set = S["in_flight"]
        next_ckpt = (clock.now + checkpoint_every
                     if checkpointer is not None and checkpoint_every > 0
                     else None)

        def issue(cid: str, when: float) -> None:
            if S["issued_total"] >= S["issue_budget"]:
                return
            S["issued_total"] += 1
            tid = self._next_ticket
            self._next_ticket += 1
            if self.trace is not None:
                # attempt records join billing/aggregation on model version
                self.trace.alias_round(tid, S["version"])
            self.engine.open_round(self.queue, [cid], S["params"], tid, when)
            dl = self.queue.schedule(when + self.round_timeout_s,
                                     EventKind.ROUND_DEADLINE,
                                     round_number=tid)
            tickets[tid] = _AsyncTicket(cid, S["version"], dl)
            in_flight.add(cid)
            S["window"]["issued"].append(cid)

        takes_exclude = scheduler_supports_exclude(self.scheduler)

        def propose(want: int, now: float) -> List[str]:
            """Ask the Scheduler for the next slot fill(s): the eligible
            pool excludes in-flight clients; rotation order, failure
            backoff, and any scoring live inside the scheduler.  With an
            exclude-aware scheduler the full population is passed and
            in-flight filtering happens vectorized inside — no O(N)
            eligible list per refill (in_flight ⊆ pool, so the reported
            pool size is unchanged)."""
            pool_ids = self.pool.client_ids
            if takes_exclude:
                picks = self.scheduler.propose(pool_ids, want, now,
                                               S["version"],
                                               exclude=in_flight)
                pool_size = len(pool_ids) - len(in_flight)
            else:
                eligible = [cid for cid in pool_ids
                            if cid not in in_flight]
                picks = self.scheduler.propose(eligible, want, now,
                                               S["version"])
                pool_size = len(eligible)
            self._record_scheduling(now, S["version"], want, picks,
                                    pool_size)
            return picks

        def refill(now: float) -> None:
            for cid in propose(1, now):
                issue(cid, now)

        def close_window(now: float, merged: int,
                         aggregated: bool = True) -> None:
            window = S["window"]
            stats = RoundStats(
                round_number=len(result.rounds),
                selected=list(window["issued"]),
                successes=list(window["delivered"]),
                late=list(window["late"]), crashed=list(window["crashed"]),
                duration_s=float(now - window["start"]),
                # denominator: invocations *resolved* this window (every
                # one of them was issued) — delivered updates plus wasted
                # crash/failure probes; telescopes to merged/issued over
                # the run without in-flight overhang distortion
                eur=windowed_update_ratio(
                    len(window["delivered"]),
                    len(window["delivered"]) + len(window["crashed"])),
                cost=self.cost.total - window["cost0"],
                aggregated_updates=merged, retries=window["retries"],
                straggler_arrivals=list(window["straggler_arrivals"]))
            if aggregated:
                # payload counters only exist in windows that saw at least
                # one encoded update (.get keeps restored pre-compression
                # window snapshots loading unchanged)
                self._record_aggregation(
                    now, stats.round_number, merged,
                    payload_bytes=window.get("payload_bytes"),
                    dense_bytes=window.get("dense_bytes"))
            # eval cadence matches the barrier modes: every eval_every
            # rounds' worth of delivered updates, not every window (a
            # FedAsync window is a single update)
            if S["next_eval"] and S["delivered_total"] >= S["next_eval"]:
                stats.accuracy = self._evaluate(S["params"])
                result.accuracy_curve.append((stats.round_number,
                                              stats.accuracy))
                S["next_eval"] += self.eval_every * cohort_size
            result.rounds.append(stats)
            if verbose:
                self._print_progress("merge", stats)
            S["window"] = self._fresh_window(now)

        if S["issued_total"] == 0:
            # fresh run: honor the per-round in-flight cap in async mode
            # too — the cap bounds the standing slot count (a late
            # ticket's replacement can exceed it transiently, as in
            # barrier mode's overlapping rounds)
            slots = cohort_size
            if self.engine.max_concurrency is not None:
                slots = min(slots, self.engine.max_concurrency)
            for cid in propose(slots, clock.now):
                issue(cid, clock.now)

        while S["delivered_total"] < S["target"]:
            if next_ckpt is not None and clock.now >= next_ckpt:
                # event-horizon snapshot: between events, every layer's
                # state is self-consistent (tickets, queue, engine, cost)
                S["snapshots"] += 1
                checkpointer.save(self, S["params"], S["snapshots"])
                next_ckpt = clock.now + checkpoint_every
            ev = self.queue.pop()
            if ev is None:
                break                       # population exhausted
            # refresh the trace alias to the *current* version before the
            # engine records anything for this ticket: attempt records
            # then share the resolution-time version space with billing
            # records (the "ticket" field keeps the issue identity)
            if (self.trace is not None and ev.round_number in tickets):
                self.trace.alias_round(ev.round_number, S["version"])
            if ev.kind is EventKind.ROUND_DEADLINE:
                info = tickets.get(ev.round_number)
                if info is None:
                    continue
                # single-client tickets: `unstarted` cannot occur (the
                # engine cap is per-ticket and each ticket fires one client)
                late, dead, _unstarted = self.engine.close_round(
                    ev.round_number, ev.time)
                for cid in dead:
                    # never produced an observable event: crash profile or
                    # an unobserved timeout kill — the deadline discovers it
                    tickets.pop(ev.round_number, None)
                    in_flight.discard(cid)
                    self.history.mark_miss(cid, info.version)
                    self.cost.charge_straggler(self.round_timeout_s,
                                               client_id=cid,
                                               round_number=S["version"])
                    self.scheduler.notify_miss(cid, ev.time)
                    S["window"]["crashed"].append(cid)
                    refill(ev.time)
                for cid in late:
                    # alive but slow: let it keep running — its update will
                    # merge on arrival, staleness-damped — and refill the
                    # slot so throughput holds
                    info.replaced = True
                    self.history.mark_miss(cid, info.version)
                    self.scheduler.notify_miss(cid, ev.time, crashed=False)
                    S["window"]["late"].append(cid)
                    refill(ev.time)
                continue

            completion = self.engine.handle(self.queue, ev)
            if completion is None:
                continue
            info = tickets.pop(completion.round_number, None)
            if info is None:
                continue                    # cross-mode leftovers
            info.cancel_deadline()
            cid = completion.client_id
            in_flight.discard(cid)
            S["window"]["retries"] += completion.attempts - 1
            # two number spaces, deliberately: charges key on the current
            # model version = the accumulating window's index (so
            # cost_by_round joins RoundStats.round_number), while history
            # keys on the ticket's *issue* version (what the client
            # actually trained against — the staleness base)
            self._bill_attempts(completion, S["version"])

            if not completion.success:
                # paper §VI-C straggler convention, as in barrier mode:
                # a terminal failure is charged for its whole (ticket)
                # window, keeping cross-mode cost comparisons apples-to-
                # apples; the earlier retried attempts were billed above
                self.cost.charge_straggler(self.round_timeout_s,
                                           client_id=cid,
                                           round_number=S["version"])
                self.history.mark_miss(cid, info.version)
                self.scheduler.notify_miss(cid, ev.time)
                S["window"]["crashed"].append(cid)
                if not info.replaced:
                    refill(ev.time)
                continue

            out = completion.outcome
            self.cost.charge(out.duration_s, client_id=cid,
                             round_number=S["version"])
            self._charge_egress(completion.update, cid, S["version"])
            # client-side report corrects the miss a late ticket recorded
            self.history.client_report(cid, info.version, out.duration_s)
            if not info.replaced:
                self.history.mark_success(cid, info.version)
                refill(ev.time)             # issue lands in this window
            else:
                S["window"]["straggler_arrivals"].append(cid)
            # an arrived update clears the client's failure backoff
            self.scheduler.notify_finish(cid, ev.time,
                                         duration_s=out.duration_s,
                                         cold=out.cold,
                                         late=info.replaced)

            S["delivered_total"] += 1
            S["window"]["delivered"].append(cid)
            upd = completion.update
            if upd is not None and upd.payload_bytes is not None:
                # wire-size tally for this window's aggregation record —
                # keys appear only when compression is on, so dense-run
                # windows (and their checkpoints) keep their legacy shape
                w = S["window"]
                w["payload_bytes"] = (w.get("payload_bytes", 0)
                                      + upd.payload_bytes)
                w["dense_bytes"] = (w.get("dense_bytes", 0)
                                    + (upd.dense_bytes or 0))
            new_params = self.strategy.on_client_finish(
                completion.update, arrival_time=ev.time,
                producing_round=info.version, current_round=S["version"],
                global_params=S["params"])
            if new_params is not None:
                S["params"] = new_params
                S["version"] += 1
                close_window(ev.time, self.strategy.last_aggregate_count)

        # abandoned in-flight invocations are still launched work: the
        # provider bills them whether or not we keep listening, so drain
        # and charge them before closing the books (they land in the
        # trailing accounting window)
        for tid, info in sorted(tickets.items()):
            info.cancel_deadline()
            if self.trace is not None:
                self.trace.alias_round(tid, S["version"])
            for cid, billed_s in self.engine.drain_round(tid, clock.now):
                self.cost.charge(billed_s, client_id=cid,
                                 round_number=S["version"],
                                 kind="abandoned")
        tickets.clear()

        # flush partially-buffered strategy state (FedBuff's trailing <K
        # buffer) so every delivered update reaches the final model …
        final = self.strategy.finalize(S["params"],
                                       current_round=S["version"])
        if final is not None:
            S["params"] = final
            S["version"] += 1
            close_window(clock.now, self.strategy.last_aggregate_count)
        elif (S["window"]["delivered"] or S["window"]["crashed"]
                or S["window"]["late"]
                or self.cost.total > S["window"]["cost0"]):
            # … and account the trailing activity (charges, deliveries,
            # crash probes) that landed after the last aggregation event
            close_window(clock.now, 0, aggregated=False)

        result.final_accuracy = self._evaluate(S["params"])
        result.cost_by_client = dict(self.cost.by_client)
        result.cost_by_round = dict(self.cost.rounds)
        self._async_live = None
        return S["params"], result

    def _fresh_window(self, now: float) -> Dict[str, Any]:
        return {"start": now, "issued": [], "delivered": [], "late": [],
                "crashed": [], "straggler_arrivals": [], "retries": 0,
                "cost0": self.cost.total}

    # ------------------------------------------------------------------
    # checkpoint surface (fl/checkpointing.py)
    # ------------------------------------------------------------------
    def checkpoint_state(self, arrays: Optional[Dict[str, Any]] = None
                         ) -> dict:
        """Full-fidelity snapshot of the driver's mutable state.

        Beyond the round-boundary state (history, every RNG stream,
        scheduler state, cost tallies, virtual clock, trailing RoundStats
        telemetry), the snapshot captures the *pending timeline*: every
        live event in the queue with its seq counter, the engine's
        in-flight invocations (plans, retry counters, cached updates),
        warm-instance pools (single platform or the whole fleet), rolling
        routing telemetry, and the semi-async/FedBuff update buffers.  A
        restored run therefore replays the remaining events byte-
        identically to an uninterrupted same-seed run — in-flight
        stragglers included — which is also what makes the barrier-free
        mode checkpointable: `_run_async` exposes its loop state here and
        snapshots at event horizons instead of round boundaries.

        Pytree-valued state (per-round global params, cached client
        updates, pending/buffered updates) is deposited into `arrays`;
        the checkpointer saves it alongside the global params.
        """
        arrays = {} if arrays is None else arrays
        state = {
            "mode": self.mode,
            "strategy": self.strategy.name,
            "scheduler_name": self.scheduler.name,
            "clock": self.queue.clock.now,
            "history": self.history.to_payload(),
            "driver_rng": self.rng.bit_generator.state,
            "strategy_state": self.strategy.state_dict(arrays),
            "scheduler": self.scheduler.state_dict(),
            "cost": self.cost.state_dict(),
            "recent_stats": [asdict(r) for r in self._recent_stats],
            "queue": self.queue.state_dict(),
            "engine": self.engine.state_dict(arrays),
            "next_ticket": self._next_ticket,
        }
        compressor = getattr(self.pool, "compressor", None)
        if compressor is not None and compressor.config.active:
            # client-side error-feedback residuals ride the checkpoint's
            # array store like server-opt moments; dense runs add nothing
            state["compressor"] = compressor.state_dict(arrays)
        fleet = getattr(self.invoker, "fleet", None)
        if fleet is not None:
            # multi-provider runs: every platform's RNG/warm pool plus
            # the routing decisions, not just the default platform
            state["fleet"] = fleet.state_dict()
        elif hasattr(self.platform, "state_dict"):
            state["platform"] = self.platform.state_dict()
        if self.trace is not None:
            state["telemetry"] = self.trace.telemetry_state_dict()
            state["trace_offset"] = getattr(self.trace, "record_count",
                                            len(self.trace.records))
        if self.mode == "async":
            state["async"] = self._async_checkpoint_state()
        return state

    def _async_checkpoint_state(self) -> dict:
        """Snapshot `_run_async`'s live loop state (event-horizon path)."""
        S = self._async_live
        if S is None:
            raise RuntimeError(
                "async checkpoints are event-horizon snapshots taken "
                "inside a running _run_async loop (checkpoint_every "
                "virtual seconds); there is no driver-idle state to save")
        result: ExperimentResult = S["result"]
        return {
            "target": S["target"], "version": S["version"],
            "delivered_total": S["delivered_total"],
            "next_eval": S["next_eval"],
            "issue_budget": S["issue_budget"],
            "issued_total": S["issued_total"],
            "snapshots": S["snapshots"],
            "in_flight": sorted(S["in_flight"]),
            "tickets": {str(tid): {
                "client_id": t.client_id, "version": t.version,
                "replaced": t.replaced,
                "deadline_seq": (None if t.deadline is None
                                 or t.deadline.cancelled
                                 else t.deadline.seq)}
                for tid, t in S["tickets"].items()},
            "window": S["window"],
            "rounds": [asdict(r) for r in result.rounds],
            "accuracy_curve": [list(t) for t in result.accuracy_curve],
        }

    def restore_state(self, state: dict,
                      arrays: Optional[Dict[str, Any]] = None) -> None:
        """Inverse of `checkpoint_state` (same driver wiring assumed)."""
        arrays = {} if arrays is None else arrays
        self.queue.clock.advance_to(float(state["clock"]))
        events_by_seq = self.queue.load_state_dict(state.get("queue", {}))
        self.engine.load_state_dict(state.get("engine", {}), events_by_seq,
                                    arrays)
        self.history.load_payload(state["history"])
        self.rng.bit_generator.state = state["driver_rng"]
        if "strategy_state" in state:
            self.strategy.load_state_dict(state["strategy_state"], arrays)
        elif "strategy_rng" in state:     # schema-v1 checkpoints
            self.strategy.rng.bit_generator.state = state["strategy_rng"]
        self.scheduler.load_state_dict(state.get("scheduler", {}))
        self.cost.load_state_dict(state.get("cost", {}))
        self._recent_stats = [RoundStats(**d)
                              for d in state.get("recent_stats", [])]
        self._next_ticket = int(state.get("next_ticket", self._next_ticket))
        if "compressor" in state:
            compressor = getattr(self.pool, "compressor", None)
            if compressor is not None:
                compressor.load_state_dict(state["compressor"], arrays)
        fleet = getattr(self.invoker, "fleet", None)
        if "fleet" in state and fleet is not None:
            fleet.load_state_dict(state["fleet"])
        elif "platform" in state and hasattr(self.platform,
                                             "load_state_dict"):
            self.platform.load_state_dict(state["platform"])
        if "telemetry" in state and self.trace is not None:
            self.trace.load_telemetry_state(state["telemetry"])
        if "async" in state:
            self._async_resume = self._rebuild_async(state["async"],
                                                     events_by_seq)

    def _rebuild_async(self, a: dict, events_by_seq: dict) -> dict:
        """Rebuild `_run_async`'s loop state from its snapshot, re-linking
        ticket deadlines to the restored queue's event objects (a ticket
        whose deadline already fired — late-but-alive — gets None)."""
        result = ExperimentResult(strategy=self.strategy.name,
                                  mode=self.mode)
        result.rounds = [RoundStats(**d) for d in a.get("rounds", [])]
        result.accuracy_curve = [tuple(t)
                                 for t in a.get("accuracy_curve", [])]
        tickets: Dict[int, _AsyncTicket] = {}
        for tid, t in a.get("tickets", {}).items():
            seq = t.get("deadline_seq")
            ticket = _AsyncTicket(t["client_id"], int(t["version"]),
                                  events_by_seq.get(seq)
                                  if seq is not None else None)
            ticket.replaced = bool(t.get("replaced", False))
            tickets[int(tid)] = ticket
        window = dict(a.get("window", {}))
        return {
            "target": int(a["target"]), "version": int(a["version"]),
            "delivered_total": int(a["delivered_total"]),
            "next_eval": a.get("next_eval", 0),
            "issue_budget": int(a["issue_budget"]),
            "issued_total": int(a["issued_total"]),
            "snapshots": int(a.get("snapshots", 0)),
            "tickets": tickets,
            "in_flight": set(a.get("in_flight", [])),
            "window": window,
            "result": result,
        }

    # ------------------------------------------------------------------
    def run(self, global_params: Pytree, n_rounds: int,
            verbose: bool = False, start_round: int = 0,
            checkpointer=None, checkpoint_every: float = 0) -> tuple:
        if self.mode == "async":
            if start_round:
                raise ValueError(
                    "start_round is a barrier-mode concept; async resume "
                    "restores mid-timeline state via "
                    "RoundCheckpointer.restore")
            # async cadence: checkpoint_every is in *virtual seconds*
            return self._run_async(global_params, n_rounds, verbose=verbose,
                                   checkpointer=checkpointer,
                                   checkpoint_every=float(checkpoint_every
                                                          or 0.0))
        result = ExperimentResult(strategy=self.strategy.name, mode=self.mode)
        params = global_params
        ck_every = int(checkpoint_every or 0)
        if ck_every != (checkpoint_every or 0):
            raise ValueError(
                f"checkpoint_every={checkpoint_every!r} must be a whole "
                f"number of rounds in barrier modes (virtual seconds are "
                f"an async-mode unit)")
        for rnd in range(start_round, n_rounds):
            params, stats = self.run_round(params, rnd)
            if self.eval_every and (rnd + 1) % self.eval_every == 0:
                stats.accuracy = self._evaluate(params)
                result.accuracy_curve.append((rnd, stats.accuracy))
            result.rounds.append(stats)
            if verbose:
                self._print_progress("round", stats)
            if (checkpointer is not None and ck_every
                    and (rnd + 1) % ck_every == 0):
                checkpointer.save(self, params, rnd + 1)
        result.final_accuracy = self._evaluate(params)
        result.cost_by_client = dict(self.cost.by_client)
        result.cost_by_round = dict(self.cost.rounds)
        return params, result


# Back-compat: the pre-refactor name; every call site keeps working.
Controller = TrainingDriver
