"""FedLess controller — paper Algorithm 1, Train_Global_Model.

The controller is a lightweight process (the paper removed the K8s/OW
dependency, §IV-A): per round it asks the Strategy Manager for a client
subset, invokes them through the (mock) invoker, waits until the round
deadline on the virtual clock, updates the behavioural history, runs the
strategy's aggregation, and meters time + cost.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.history import ClientHistoryDB
from ..core.strategies import Strategy
from ..faas.cost import CostMeter
from ..faas.invoker import MockInvoker
from .client import ClientPool
from .metrics import bias, effective_update_ratio, weighted_accuracy

Pytree = Any


@dataclass
class RoundStats:
    round_number: int
    selected: List[str]
    successes: List[str]
    late: List[str]
    crashed: List[str]
    duration_s: float
    eur: float
    cost: float
    accuracy: Optional[float] = None
    aggregated_updates: int = 0


@dataclass
class ExperimentResult:
    strategy: str
    rounds: List[RoundStats] = field(default_factory=list)
    final_accuracy: float = 0.0
    accuracy_curve: List[tuple] = field(default_factory=list)

    @property
    def total_duration_s(self) -> float:
        return sum(r.duration_s for r in self.rounds)

    @property
    def total_cost(self) -> float:
        return sum(r.cost for r in self.rounds)

    @property
    def mean_eur(self) -> float:
        return float(np.mean([r.eur for r in self.rounds])) if self.rounds else 1.0

    def invocation_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for r in self.rounds:
            for cid in r.selected:
                counts[cid] = counts.get(cid, 0) + 1
        return counts

    @property
    def bias(self) -> int:
        return bias(self.invocation_counts())


class Controller:
    def __init__(self, strategy: Strategy, invoker: MockInvoker,
                 pool: ClientPool, history: ClientHistoryDB,
                 cost_meter: Optional[CostMeter] = None,
                 round_timeout_s: float = 120.0,
                 eval_every: int = 5, eval_fraction: float = 0.2,
                 seed: int = 0):
        self.strategy = strategy
        self.invoker = invoker
        self.pool = pool
        self.history = history
        self.cost = cost_meter or CostMeter()
        self.round_timeout_s = round_timeout_s
        self.eval_every = eval_every
        self.eval_fraction = eval_fraction
        self.rng = np.random.default_rng(seed)
        self.platform = invoker.platform

    # ------------------------------------------------------------------
    def _evaluate(self, params: Pytree) -> float:
        """Paper §VI-A5: accuracy on a random subset of clients' test sets,
        weighted by test cardinality."""
        ids = [cid for cid in self.pool.client_ids
               if self.pool.clients[cid].test_dataset is not None]
        if not ids:
            return 0.0
        k = max(1, int(len(ids) * self.eval_fraction))
        sample = self.rng.choice(ids, size=min(k, len(ids)), replace=False)
        per_client = []
        for cid in sample:
            ds = self.pool.clients[cid].test_dataset
            acc, _ = self.pool.task.evaluate(params, ds)
            per_client.append((acc, len(ds)))
        return weighted_accuracy(per_client)

    # ------------------------------------------------------------------
    def run_round(self, global_params: Pytree,
                  round_number: int) -> tuple:
        """One Train_Global_Model iteration. Returns (params, RoundStats)."""
        clock = self.platform.clock
        t0 = clock.now
        deadline = t0 + self.round_timeout_s

        selected = self.strategy.select(self.pool.client_ids, round_number)
        results = self.invoker.invoke_clients(
            selected, global_params, round_number, t0)

        # SAFA-style dynamic quorum: the round closes at the k-th fastest
        # response instead of a fixed timeout (still capped by it).
        quorum = getattr(self.strategy, "quorum", None)
        if quorum:
            finishes = sorted(r.outcome.finish_time for r in results
                              if not r.outcome.crashed)
            if finishes:
                kth = finishes[min(quorum, len(finishes)) - 1]
                deadline = min(deadline, kth)

        successes, late, crashed = [], [], []
        round_cost = 0.0
        for res in results:
            out = res.outcome
            if not out.crashed and out.finish_time <= deadline:
                successes.append(res)
            elif not out.crashed:
                late.append(res)
            else:
                crashed.append(res)

        # Round duration: slowest in-time client, or the deadline if anyone
        # missed (synchronous server waits until the deadline, §VI-C; with
        # a SAFA quorum the deadline is the k-th fastest response).
        if late or crashed:
            duration = deadline - t0
        elif successes:
            duration = max(r.outcome.finish_time for r in successes) - t0
        else:
            duration = deadline - t0

        # --- controller-side history updates (Alg. 1 lines 5-13) -------
        for res in successes:
            cid = res.outcome.client_id
            self.history.mark_success(cid, round_number)
            # client-side report (Alg. 1 lines 16-27) — in-time client
            self.history.client_report(cid, round_number,
                                       res.outcome.duration_s)
            round_cost += self.cost.charge(res.outcome.duration_s)
        for res in late:
            cid = res.outcome.client_id
            self.history.mark_miss(cid, round_number)
            # the late client eventually finishes: corrects its missed
            # round + reports its time (client-side), and its update is
            # cached for the next aggregation when semi-async.
            self.history.client_report(cid, round_number,
                                       res.outcome.duration_s)
            if self.strategy.semi_async and res.update is not None:
                self.strategy.accept_late_update(
                    res.update, arrival_time=res.outcome.finish_time)
            round_cost += self.cost.charge_straggler(duration)
        for res in crashed:
            cid = res.outcome.client_id
            self.history.mark_miss(cid, round_number)
            round_cost += self.cost.charge_straggler(duration)

        # --- aggregation runs at the round deadline (virtual now) -------
        updates = [r.update for r in successes if r.update is not None]
        new_params = self.strategy.aggregate(updates, round_number,
                                             now=t0 + duration)
        if new_params is None:
            new_params = global_params

        clock.advance_to(t0 + duration)

        stats = RoundStats(
            round_number=round_number, selected=list(selected),
            successes=[r.outcome.client_id for r in successes],
            late=[r.outcome.client_id for r in late],
            crashed=[r.outcome.client_id for r in crashed],
            duration_s=float(duration),
            eur=effective_update_ratio(len(successes), len(selected)),
            cost=round_cost,
            aggregated_updates=len(updates) + len(self.strategy.update_store))
        return new_params, stats

    # ------------------------------------------------------------------
    def run(self, global_params: Pytree, n_rounds: int,
            verbose: bool = False) -> tuple:
        result = ExperimentResult(strategy=self.strategy.name)
        params = global_params
        for rnd in range(n_rounds):
            params, stats = self.run_round(params, rnd)
            if self.eval_every and (rnd + 1) % self.eval_every == 0:
                stats.accuracy = self._evaluate(params)
                result.accuracy_curve.append((rnd, stats.accuracy))
            result.rounds.append(stats)
            if verbose:
                acc = f" acc={stats.accuracy:.3f}" if stats.accuracy else ""
                print(f"[{self.strategy.name}] round {rnd:3d} "
                      f"eur={stats.eur:.2f} dur={stats.duration_s:6.1f}s "
                      f"cost=${stats.cost:.4f}{acc}")
        result.final_accuracy = self._evaluate(params)
        return params, result
