"""FedLess controller — paper Algorithm 1, Train_Global_Model.

The controller is a lightweight process (the paper removed the K8s/OW
dependency, §IV-A).  It is now an *event consumer*: per round it asks the
Strategy Manager for a client subset, hands it to the event-driven
`InvocationEngine`, and drains the shared event queue until the round
closes — at the round deadline, at the SAFA quorum's k-th success, or at
the last in-time finish.  Because the queue persists across rounds, a
straggler's CLIENT_FINISH from round *t* fires during round *t+1* (or
later) at its true virtual arrival time, and semi-async strategies
receive it through `Strategy.on_client_finish` exactly then — genuine
overlapping rounds instead of the old "cache at round close"
approximation.

`run_round`/`run` keep their original signatures as thin adapters, so
experiments, benchmarks and examples run unmodified on the new engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.history import ClientHistoryDB
from ..core.strategies import Strategy
from ..faas.cost import CostMeter
from ..faas.events import EventKind, EventQueue
from ..faas.invoker import ClientCompletion, InvocationEngine, MockInvoker
from .client import ClientPool
from .metrics import bias, effective_update_ratio, weighted_accuracy

Pytree = Any


@dataclass
class RoundStats:
    round_number: int
    selected: List[str]
    successes: List[str]
    late: List[str]
    crashed: List[str]
    duration_s: float
    eur: float
    cost: float
    accuracy: Optional[float] = None
    aggregated_updates: int = 0
    retries: int = 0
    # updates from earlier rounds that physically arrived during this round
    straggler_arrivals: List[str] = field(default_factory=list)


@dataclass
class ExperimentResult:
    strategy: str
    rounds: List[RoundStats] = field(default_factory=list)
    final_accuracy: float = 0.0
    accuracy_curve: List[tuple] = field(default_factory=list)

    @property
    def total_duration_s(self) -> float:
        return sum(r.duration_s for r in self.rounds)

    @property
    def total_cost(self) -> float:
        return sum(r.cost for r in self.rounds)

    @property
    def mean_eur(self) -> float:
        return float(np.mean([r.eur for r in self.rounds])) if self.rounds else 1.0

    def invocation_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for r in self.rounds:
            for cid in r.selected:
                counts[cid] = counts.get(cid, 0) + 1
        return counts

    @property
    def bias(self) -> int:
        return bias(self.invocation_counts())


class Controller:
    def __init__(self, strategy: Strategy, invoker: MockInvoker,
                 pool: ClientPool, history: ClientHistoryDB,
                 cost_meter: Optional[CostMeter] = None,
                 round_timeout_s: float = 120.0,
                 eval_every: int = 5, eval_fraction: float = 0.2,
                 seed: int = 0, max_retries: int = 1,
                 max_concurrency: Optional[int] = None,
                 vectorized: bool = False):
        self.strategy = strategy
        self.invoker = invoker
        self.pool = pool
        self.history = history
        self.cost = cost_meter or CostMeter()
        self.round_timeout_s = round_timeout_s
        self.eval_every = eval_every
        self.eval_fraction = eval_fraction
        self.rng = np.random.default_rng(seed)
        self.vectorized = vectorized
        self.platform = invoker.platform
        # one event queue on the platform's clock, shared across rounds —
        # straggler events survive round boundaries
        self.queue = EventQueue(self.platform.clock)
        self.engine = InvocationEngine(invoker, max_retries=max_retries,
                                       max_concurrency=max_concurrency)

    # ------------------------------------------------------------------
    def _evaluate(self, params: Pytree) -> float:
        """Paper §VI-A5: accuracy on a random subset of clients' test sets,
        weighted by test cardinality."""
        ids = [cid for cid in self.pool.client_ids
               if self.pool.clients[cid].test_dataset is not None]
        if not ids:
            return 0.0
        k = max(1, int(len(ids) * self.eval_fraction))
        sample = self.rng.choice(ids, size=min(k, len(ids)), replace=False)
        per_client = []
        for cid in sample:
            ds = self.pool.clients[cid].test_dataset
            acc, _ = self.pool.task.evaluate(params, ds)
            per_client.append((acc, len(ds)))
        return weighted_accuracy(per_client)

    # ------------------------------------------------------------------
    def _precompute_updates(self, selected: List[str], global_params: Pytree,
                            round_number: int) -> Optional[Dict[str, tuple]]:
        """Vectorized client execution: run every live selected client's
        local epochs as one vmapped dispatch (fl/executor.py) and feed the
        results to the engine as the per-client work cache."""
        if not (self.vectorized and hasattr(self.pool, "batch_work_fn")):
            return None
        # under a concurrency cap only the first `cap` clients fire at
        # round start — precompute just those; cap-released clients fall
        # back to the per-client work_fn when their slot opens
        cap = self.engine.max_concurrency or len(selected)
        profiles = getattr(self.invoker, "profiles", {})
        alive = [cid for cid in selected[:cap]
                 if not getattr(profiles.get(cid), "crash", False)]
        if not alive:
            return None
        return self.pool.batch_work_fn(alive, global_params, round_number)

    def _handle_straggler(self, completion: ClientCompletion,
                          arrival_time: float, current_round: int) -> None:
        """A client from an earlier round finished mid-flight: record its
        (client-side) report now and hand the update to the strategy at
        its true virtual arrival time (Alg. 1 lines 16-27)."""
        out = completion.outcome
        self.history.client_report(out.client_id, completion.round_number,
                                   out.duration_s)
        self.strategy.on_client_finish(
            completion.update, arrival_time=arrival_time,
            producing_round=completion.round_number,
            current_round=current_round)

    def _bill_attempts(self, completion: ClientCompletion) -> float:
        """Every attempt of a retried invocation is billed (FedLess retries
        are real invocations on the provider's meter)."""
        return sum(self.cost.charge(fa.duration_s)
                   for fa in completion.failed_attempts)

    # ------------------------------------------------------------------
    def run_round(self, global_params: Pytree,
                  round_number: int) -> tuple:
        """One Train_Global_Model iteration. Returns (params, RoundStats)."""
        clock = self.queue.clock
        t0 = clock.now
        deadline = t0 + self.round_timeout_s

        selected = self.strategy.select(self.pool.client_ids, round_number)
        precomputed = self._precompute_updates(selected, global_params,
                                               round_number)
        self.engine.open_round(self.queue, selected, global_params,
                               round_number, t0, precomputed=precomputed)
        deadline_ev = self.queue.schedule(deadline, EventKind.ROUND_DEADLINE,
                                          round_number=round_number)

        # SAFA-style dynamic quorum: the round closes at the k-th fastest
        # response instead of a fixed timeout (still capped by it).
        quorum = getattr(self.strategy, "quorum", None)

        successes: List[ClientCompletion] = []
        failed: List[ClientCompletion] = []
        straggler_arrivals: List[str] = []
        round_cost = 0.0
        retries = 0
        close_time = deadline

        while True:
            ev = self.queue.pop()
            if ev is None:
                break
            if ev.kind is EventKind.ROUND_DEADLINE:
                if ev.round_number == round_number:
                    break
                continue
            completion = self.engine.handle(self.queue, ev)
            if completion is None:
                continue
            if completion.round_number != round_number:
                # a straggler from an earlier round arriving mid-flight
                round_cost += self._bill_attempts(completion)
                if completion.success:
                    straggler_arrivals.append(completion.client_id)
                    self._handle_straggler(completion, ev.time, round_number)
                continue
            round_cost += self._bill_attempts(completion)
            retries += completion.attempts - 1
            if completion.success:
                successes.append(completion)
                self.strategy.on_client_finish(
                    completion.update, arrival_time=ev.time,
                    producing_round=round_number,
                    current_round=round_number)
                if quorum and len(successes) >= quorum:
                    close_time = ev.time
                    deadline_ev.cancel()
                    break
                if not failed and len(successes) == len(selected):
                    # everyone answered in time: close at the last finish
                    close_time = ev.time
                    deadline_ev.cancel()
                    break
            else:
                failed.append(completion)
            if (quorum
                    and self.engine.unresolved_count(round_number) == 0):
                # quorum unreachable — every remaining client resolved
                # observably, so the k-th response will never come; close
                # at the last terminal event instead of the full timeout
                close_time = ev.time
                deadline_ev.cancel()
                break

        late_ids, dead_ids, unstarted = self.engine.close_round(round_number,
                                                                close_time)
        duration = close_time - t0
        clock.advance_to(close_time)

        # --- controller-side history + billing (Alg. 1 lines 5-13) -----
        for comp in successes:
            out = comp.outcome
            self.history.mark_success(out.client_id, round_number)
            # client-side report (Alg. 1 lines 16-27) — in-time client
            self.history.client_report(out.client_id, round_number,
                                       out.duration_s)
            round_cost += self.cost.charge(out.duration_s)
        for cid in late_ids:
            # alive but past the deadline: a miss now; its report and its
            # update arrive with its CLIENT_FINISH event in a later round
            self.history.mark_miss(cid, round_number)
            round_cost += self.cost.charge_straggler(duration)
        for comp in failed:
            self.history.mark_miss(comp.outcome.client_id, round_number)
            round_cost += self.cost.charge_straggler(duration)
        for cid in dead_ids:
            self.history.mark_miss(cid, round_number)
            round_cost += self.cost.charge_straggler(duration)
        for cid in unstarted:
            # never invoked (concurrency cap): a miss, but nothing billed
            self.history.mark_miss(cid, round_number)

        # --- aggregation runs at round close (virtual now) --------------
        self.strategy.on_round_close(round_number, now=close_time)
        updates = [c.update for c in successes if c.update is not None]
        new_params = self.strategy.aggregate(updates, round_number,
                                             now=close_time)
        if new_params is None:
            new_params = global_params

        crashed_ids = ([c.outcome.client_id for c in failed]
                       + dead_ids + unstarted)
        stats = RoundStats(
            round_number=round_number, selected=list(selected),
            successes=[c.outcome.client_id for c in successes],
            late=late_ids, crashed=crashed_ids,
            duration_s=float(duration),
            eur=effective_update_ratio(len(successes), len(selected)),
            cost=round_cost,
            aggregated_updates=self.strategy.last_aggregate_count,
            retries=retries,
            straggler_arrivals=straggler_arrivals)
        return new_params, stats

    # ------------------------------------------------------------------
    def run(self, global_params: Pytree, n_rounds: int,
            verbose: bool = False) -> tuple:
        result = ExperimentResult(strategy=self.strategy.name)
        params = global_params
        for rnd in range(n_rounds):
            params, stats = self.run_round(params, rnd)
            if self.eval_every and (rnd + 1) % self.eval_every == 0:
                stats.accuracy = self._evaluate(params)
                result.accuracy_curve.append((rnd, stats.accuracy))
            result.rounds.append(stats)
            if verbose:
                acc = f" acc={stats.accuracy:.3f}" if stats.accuracy else ""
                print(f"[{self.strategy.name}] round {rnd:3d} "
                      f"eur={stats.eur:.2f} dur={stats.duration_s:6.1f}s "
                      f"cost=${stats.cost:.4f}{acc}")
        result.final_accuracy = self._evaluate(params)
        return params, result
