"""Client runtime — paper Algorithm 1, Client_Update.

Each FL client is (conceptually) a FaaS function: stateless between
invocations, loading the global model, training on its local shard, and
pushing the update + its measured training time back to the database.
`ClientPool.work_fn` is what the MockInvoker executes per invocation.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

from ..core.aggregation import ClientUpdate
from ..data.synthetic import ArrayDataset
from .tasks import ClassificationTask

Pytree = Any


@dataclass
class ClientState:
    dataset: ArrayDataset
    test_dataset: Optional[ArrayDataset] = None


class ClientPool:
    """Holds every client's local shard + the shared task definition."""

    def __init__(self, task: ClassificationTask,
                 datasets: Dict[str, ArrayDataset],
                 test_datasets: Optional[Dict[str, ArrayDataset]] = None,
                 proximal_mu: float = 0.0, seed: int = 0,
                 compressor=None):
        self.task = task
        self.clients = {
            cid: ClientState(ds, (test_datasets or {}).get(cid))
            for cid, ds in datasets.items()
        }
        self.proximal_mu = proximal_mu
        self.seed = seed
        # optional core.compress.UpdateCompressor — when set, updates are
        # encoded (top-k / int8 + error feedback) on the way out of local
        # training and the ClientUpdate carries the simulated wire size
        self.compressor = compressor
        self._executor = None
        # membership is fixed after construction, so the sorted id list is
        # computed once — callers (and the interners memoizing on list
        # identity) see one stable object instead of a fresh O(N log N)
        # sort per access
        self._client_ids = sorted(self.clients)

    @property
    def client_ids(self):
        return self._client_ids

    def num_samples(self, cid: str) -> int:
        return len(self.clients[cid].dataset)

    def client_seed(self, cid: str, round_number: int) -> int:
        """Per-(client, round) training seed — the single source of truth
        shared by the eager loop and the vectorized executor, so both
        replay identical batch permutations.  CRC32 rather than hash():
        Python salts string hashes per interpreter, which would make
        training trajectories differ between processes."""
        return zlib.crc32(
            f"{cid}:{round_number}:{self.seed}".encode()) % (2 ** 31)

    # ------------------------------------------------------------------
    def package_update(self, cid: str, params: Pytree,
                       round_number: int, global_params: Pytree,
                       batch=None, row: int = -1) -> ClientUpdate:
        """Wrap trained params into the wire-format ClientUpdate: with a
        compressor the params become the server-side decode and the
        simulated payload/dense byte counts ride along; without one the
        update is the plain dense pytree (byte-identical legacy path).

        Device-pipeline variant: pass ``batch``/``row`` (a
        ``DeviceUpdateBatch`` from the vectorized executor) instead of
        ``params`` — compression then reads/writes the flat row in place
        (``encode_flat``) and the returned ClientUpdate is a thin view
        whose ``.params`` materializes lazily on first access."""
        payload_bytes = dense_bytes = None
        if batch is not None:
            if self.compressor is not None:
                new_row, payload_bytes, dense_bytes = \
                    self.compressor.encode_flat(cid, batch.row(row),
                                                global_params)
                if payload_bytes is not None:
                    batch.set_row(row, new_row)
            return ClientUpdate(
                client_id=cid,
                num_samples=len(self.clients[cid].dataset),
                round_number=round_number,
                payload_bytes=payload_bytes, dense_bytes=dense_bytes,
                batch=batch, batch_row=row)
        if self.compressor is not None:
            params, payload_bytes, dense_bytes = self.compressor.encode(
                cid, params, global_params)
        return ClientUpdate(
            client_id=cid, params=params,
            num_samples=len(self.clients[cid].dataset),
            round_number=round_number,
            payload_bytes=payload_bytes, dense_bytes=dense_bytes)

    def work_fn(self, cid: str, global_params: Pytree,
                round_number: int) -> Tuple[ClientUpdate, float]:
        """Client_Update body: train locally, return the update and the
        nominal training duration for the virtual clock."""
        state = self.clients[cid]
        params, _loss = self.task.local_train(
            global_params, state.dataset, mu=self.proximal_mu,
            seed=self.client_seed(cid, round_number))
        update = self.package_update(cid, params, round_number,
                                     global_params)
        return update, self.task.nominal_work_seconds(state.dataset)

    # ------------------------------------------------------------------
    @property
    def executor(self):
        """The shared VectorizedExecutor (created on first use; the
        controller's warm-up pass reaches it through here)."""
        if self._executor is None:
            from .executor import VectorizedExecutor
            # cache on the task: its jit cache then survives across pools
            # (one experiment grid shares one task ⇒ compile once)
            self._executor = getattr(self.task, "_vec_executor", None)
            if self._executor is None:
                self._executor = VectorizedExecutor(self.task)
                self.task._vec_executor = self._executor
        return self._executor

    def batch_work_fn(self, cids, global_params: Pytree,
                      round_number: int) -> Dict[str, tuple]:
        """Vectorized Client_Update: same contract as `work_fn` but for a
        whole round's cohort in one vmapped dispatch (fl/executor.py)."""
        return self.executor.run_clients(self, cids, global_params,
                                         round_number)
