"""Client runtime — paper Algorithm 1, Client_Update.

Each FL client is (conceptually) a FaaS function: stateless between
invocations, loading the global model, training on its local shard, and
pushing the update + its measured training time back to the database.
`ClientPool.work_fn` is what the MockInvoker executes per invocation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

from ..core.aggregation import ClientUpdate
from ..data.synthetic import ArrayDataset
from .tasks import ClassificationTask

Pytree = Any


@dataclass
class ClientState:
    dataset: ArrayDataset
    test_dataset: Optional[ArrayDataset] = None


class ClientPool:
    """Holds every client's local shard + the shared task definition."""

    def __init__(self, task: ClassificationTask,
                 datasets: Dict[str, ArrayDataset],
                 test_datasets: Optional[Dict[str, ArrayDataset]] = None,
                 proximal_mu: float = 0.0, seed: int = 0):
        self.task = task
        self.clients = {
            cid: ClientState(ds, (test_datasets or {}).get(cid))
            for cid, ds in datasets.items()
        }
        self.proximal_mu = proximal_mu
        self.seed = seed

    @property
    def client_ids(self):
        return sorted(self.clients)

    def num_samples(self, cid: str) -> int:
        return len(self.clients[cid].dataset)

    # ------------------------------------------------------------------
    def work_fn(self, cid: str, global_params: Pytree,
                round_number: int) -> Tuple[ClientUpdate, float]:
        """Client_Update body: train locally, return the update and the
        nominal training duration for the virtual clock."""
        state = self.clients[cid]
        params, _loss = self.task.local_train(
            global_params, state.dataset, mu=self.proximal_mu,
            seed=hash((cid, round_number, self.seed)) % (2 ** 31))
        update = ClientUpdate(
            client_id=cid, params=params, num_samples=len(state.dataset),
            round_number=round_number)
        return update, self.task.nominal_work_seconds(state.dataset)
