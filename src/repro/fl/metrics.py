"""Strategy-quality metrics — paper §VI-A5.

EUR (effective update ratio): successful / selected clients in a round.
In barrier-free (async) mode there is no round cohort, so the per-round
ratio is degenerate; `windowed_update_ratio` is the async-comparable
form — updates merged / invocations issued over a window of virtual
time (the span between consecutive aggregation events).
Bias: difference between the invocation counts of the most- and
least-invoked clients over the whole session.
Weighted accuracy: per-client test accuracy weighted by test-set
cardinality (the paper's federated evaluation).
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def effective_update_ratio(n_success: int, n_selected: int) -> float:
    return n_success / n_selected if n_selected else 1.0


def windowed_update_ratio(n_merged: int, n_resolved: int) -> float:
    """Async-mode EUR: updates merged into the global model per
    invocation *resolved* during a wall-clock (virtual-time) window —
    every resolved invocation was issued, so summed over a run this
    telescopes to merged/issued without crediting or debiting the
    invocations still in flight at the window edge.  Windows with no
    resolutions report 1.0 (nothing was wasted)."""
    return effective_update_ratio(n_merged, n_resolved)


def trailing_eur(stats: Sequence, window: int = 3) -> float:
    """Mean EUR over the trailing `window` RoundStats — the adaptive
    scheduler's grow/shrink signal."""
    recent = list(stats)[-window:]
    if not recent:
        return 1.0
    return float(np.mean([r.eur for r in recent]))


def trailing_straggler_ratio(stats: Sequence, window: int = 3) -> float:
    """Fraction of selected clients that were late or crashed over the
    trailing `window` RoundStats."""
    recent = list(stats)[-window:]
    selected = sum(len(r.selected) for r in recent)
    if not selected:
        return 0.0
    wasted = sum(len(r.late) + len(r.crashed) for r in recent)
    return wasted / selected


class TrailingMetricsCache:
    """Identity-keyed memo for the adaptive scheduler's trailing window.

    `trailing_eur` / `trailing_straggler_ratio` only depend on the last
    `window` RoundStats objects, so the pair is computed once per
    distinct window and replayed for free on repeated `cohort_size`
    calls against unchanged telemetry (async refills, re-entrant
    sizing).  Delegates to the module functions — values are identical.
    """

    __slots__ = ("window", "_key", "_value")

    def __init__(self, window: int = 3):
        self.window = window
        self._key: tuple = ()
        self._value = (1.0, 0.0)

    def compute(self, stats: Sequence) -> tuple:
        """(trailing_eur, trailing_straggler_ratio) over `stats`."""
        recent = list(stats)[-self.window:]
        key = tuple(map(id, recent))
        if key != self._key or not key:
            self._value = (trailing_eur(recent, self.window),
                           trailing_straggler_ratio(recent, self.window))
            self._key = key
        return self._value


def time_to_accuracy(accuracy_curve: Sequence[tuple],
                     round_durations: Sequence[float],
                     target: float) -> float:
    """Virtual seconds until the evaluated accuracy first reaches
    `target` (inf if it never does).  `accuracy_curve` is the
    ExperimentResult's [(round, accuracy), ...] and `round_durations`
    the per-round duration list."""
    for rnd, acc in accuracy_curve:
        if acc >= target:
            return float(sum(round_durations[:rnd + 1]))
    return float("inf")


def bias(invocations: Dict[str, int]) -> int:
    if not invocations:
        return 0
    counts = list(invocations.values())
    return int(max(counts) - min(counts))


def invocation_distribution(invocations: Dict[str, int]) -> np.ndarray:
    return np.array(sorted(invocations.values()), dtype=np.int64)


def weighted_accuracy(per_client: Sequence[tuple]) -> float:
    """per_client: iterable of (accuracy, test_cardinality)."""
    accs = np.array([a for a, _ in per_client], dtype=np.float64)
    card = np.array([c for _, c in per_client], dtype=np.float64)
    if card.sum() == 0:
        return float(accs.mean()) if len(accs) else 0.0
    return float(np.sum(accs * card) / card.sum())
