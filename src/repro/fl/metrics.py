"""Strategy-quality metrics — paper §VI-A5.

EUR (effective update ratio): successful / selected clients in a round.
Bias: difference between the invocation counts of the most- and
least-invoked clients over the whole session.
Weighted accuracy: per-client test accuracy weighted by test-set
cardinality (the paper's federated evaluation).
"""
from __future__ import annotations

from typing import Dict, Iterable, Sequence

import numpy as np


def effective_update_ratio(n_success: int, n_selected: int) -> float:
    return n_success / n_selected if n_selected else 1.0


def bias(invocations: Dict[str, int]) -> int:
    if not invocations:
        return 0
    counts = list(invocations.values())
    return int(max(counts) - min(counts))


def invocation_distribution(invocations: Dict[str, int]) -> np.ndarray:
    return np.array(sorted(invocations.values()), dtype=np.int64)


def weighted_accuracy(per_client: Sequence[tuple]) -> float:
    """per_client: iterable of (accuracy, test_cardinality)."""
    accs = np.array([a for a, _ in per_client], dtype=np.float64)
    card = np.array([c for _, c in per_client], dtype=np.float64)
    if card.sum() == 0:
        return float(accs.mean()) if len(accs) else 0.0
    return float(np.sum(accs * card) / card.sum())
