"""Production mesh definition (TPU v5e pods).

single-pod : (16, 16)    axes ("data", "model")        = 256 chips
multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init,
while smoke tests see the 1 real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Tiny mesh over however many (CPU) devices exist — used by tests."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"))
