"""Mesh construction — the single place device meshes are built.

Production (TPU v5e pods):
single-pod : (16, 16)    axes ("data", "model")        = 256 chips
multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Host meshes (tests / CPU-forced device counts):
make_host_mesh    : ("data", "model") over however many devices exist —
                    the P-sharded merge (kernels/fed_agg.*_sharded)
                    splits the flat model dim across every axis of it.
make_clients_mesh : 1-axis ("clients",) mesh the vectorized executor
                    shards the cohort (K) dim over (fl/executor.py).

Axis names come from the declared vocabulary in ``sharding/rules.py``
(``MESH_AXES``) — repro-lint's JAX004 rule keeps ad-hoc axis literals
out of shard_map / psum call sites.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init,
while smoke tests see the 1 real CPU device.
"""
from __future__ import annotations

import jax

from ..sharding.rules import CLIENT_AXIS


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Tiny mesh over however many (CPU) devices exist — used by tests."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return jax.make_mesh((data, model), ("data", "model"))


def make_clients_mesh(clients: int = 1):
    """1-axis ``("clients",)`` mesh for cohort-sharded local training.

    Clamps to however many devices exist, so asking for 8 on a
    single-device host yields a size-1 mesh — which the executor treats
    as "no mesh" (bitwise-inert fallback to the plain vmap path)."""
    n = max(1, min(int(clients), len(jax.devices())))
    return jax.make_mesh((n,), (CLIENT_AXIS,))
