"""Datacenter-style pjit pretraining driver.

Runs the real distributed train step (the same one the dry-run lowers at
512 devices) on the host mesh with actual data, checkpointing, and a
cosine LR schedule — the end-to-end training path of deliverable (b).
On this CPU container use a reduced arch; on TPU point it at a full
config and the production mesh.

  PYTHONPATH=src python -m repro.launch.pretrain --arch mamba2-130m \
      --reduced --steps 100 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config
from ..data.synthetic import make_token_lm
from ..models import make_train_step
from ..sharding import batch_specs, opt_specs, param_specs, to_named
from .mesh import make_host_mesh, make_production_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(learning_rate=args.lr, efficient_ce=True)

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())

    train_step, init_state = make_train_step(cfg)
    rng = jax.random.PRNGKey(0)

    with mesh:
        state_struct = jax.eval_shape(lambda: init_state(rng))
        p_specs = param_specs(state_struct["params"], mesh)
        o_specs = opt_specs(state_struct["opt"], p_specs, mesh)
        state_specs = {"params": p_specs, "opt": o_specs}
        state_sh = to_named(state_specs, mesh)

        jit_init = jax.jit(init_state, out_shardings=state_sh)
        state = jit_init(rng)

        dummy_batch = {
            "tokens": jnp.zeros((args.batch, args.seq), jnp.int32),
            "labels": jnp.zeros((args.batch, args.seq), jnp.int32)}
        b_specs = batch_specs(dummy_batch, mesh)
        jit_step = jax.jit(train_step,
                           in_shardings=(state_sh, to_named(b_specs, mesh)),
                           out_shardings=(state_sh, None),
                           donate_argnums=(0,))

        data = make_token_lm(args.steps * args.batch * (args.seq + 1) * 2,
                             vocab=cfg.vocab, seq_len=args.seq, seed=0)
        n_seq = data.x.shape[0]

        ckpt = (CheckpointManager(args.ckpt_dir)
                if args.ckpt_dir else None)
        losses = []
        t0 = time.time()
        for step in range(args.steps):
            idx = (np.arange(args.batch) + step * args.batch) % n_seq
            batch = {"tokens": jnp.asarray(data.x[idx]),
                     "labels": jnp.asarray(data.y[idx])}
            state, loss = jit_step(state, batch)
            losses.append(float(loss))
            if (step + 1) % args.log_every == 0:
                rate = (step + 1) * args.batch * args.seq / (
                    time.time() - t0)
                print(f"step {step+1:5d} loss {losses[-1]:.4f} "
                      f"(mean10 {np.mean(losses[-10:]):.4f}) "
                      f"{rate:,.0f} tok/s")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(state, step + 1)

        print(f"\nfinal: loss {losses[-1]:.4f} "
              f"(first10 {np.mean(losses[:10]):.4f} → "
              f"last10 {np.mean(losses[-10:]):.4f}) "
              f"in {time.time()-t0:.1f}s")
        if ckpt:
            ckpt.save(state, args.steps)
            print(f"checkpoints: {sorted(ckpt.steps())} in {ckpt.dir}")


if __name__ == "__main__":
    main()
