"""Persistent JAX compilation cache — recompile-free repeat runs.

The round pipeline makes compilation a non-event *within* a process
(power-of-two cohort buckets + the executor warm-up pass); this module
extends that across processes: with a cache directory set, XLA
executables are serialized to disk on first compile and deserialized on
every later run with the same dispatch signature — a fresh CI worker or
a re-launched study skips straight to execution.

Wired into ``ExperimentConfig.compilation_cache_dir`` (fl/experiment.py)
and usable standalone by benchmarks.  Enabling is idempotent and
best-effort: JAX builds without the feature (or with a read-only
filesystem) degrade to normal in-memory compilation with a warning.
"""
from __future__ import annotations

import os
from typing import Optional

_enabled_dir: Optional[str] = None


def enable_compilation_cache(path: str) -> bool:
    """Point JAX's persistent compilation cache at ``path`` (created if
    missing).  Returns True when the cache is active.

    The min-size/min-time floors are dropped to zero so the small
    interpret-mode kernels and group-train dispatches this repo compiles
    are all eligible — the defaults only persist "expensive" compiles.
    """
    global _enabled_dir
    path = os.path.abspath(os.path.expanduser(path))
    if _enabled_dir == path:
        return True
    try:
        import jax

        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", path)
        # both knobs postdate the cache itself — absence is fine
        for knob, val in (("jax_persistent_cache_min_entry_size_bytes", -1),
                          ("jax_persistent_cache_min_compile_time_secs", 0)):
            try:
                jax.config.update(knob, val)
            except (AttributeError, ValueError):
                pass
        _enabled_dir = path
        return True
    except Exception as e:                      # pragma: no cover
        import warnings
        warnings.warn(f"persistent compilation cache unavailable "
                      f"({e}); continuing without it")
        return False


def cache_dir() -> Optional[str]:
    """The active cache directory, or None when not enabled."""
    return _enabled_dir
