import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, record memory/cost analysis + collective bytes.

One pair per invocation (subprocess isolation keeps compile memory
bounded); --all drives the sweep and skips pairs already recorded.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_pair(arch_id: str, shape_name: str, mesh_kind: str,
             variant_name: str = "baseline") -> dict:
    import jax
    from ..configs import INPUT_SHAPES, get_config
    from ..launch.hlo_analysis import (Roofline, active_param_count,
                                       collective_summary, loop_aware_costs,
                                       model_flops, parse_collectives)
    from ..launch.mesh import make_production_mesh
    from ..launch.specs import build_step, resolve_config
    from ..launch.variants import VARIANTS

    variant = VARIANTS[variant_name]
    cfg = variant.apply(get_config(arch_id))
    shape = INPUT_SHAPES[shape_name]
    record: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                    "kind": shape.kind, "variant": variant_name,
                    "hypothesis": variant.hypothesis}

    if shape.name == "long_500k" and not cfg.supports_long_context:
        record.update(status="skipped",
                      reason="full-attention arch; O(S^2) at 524288 tokens "
                             "excluded by assignment rule (DESIGN.md)")
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = len(jax.devices())
    t0 = time.time()
    with mesh:
        jf, args = build_step(cfg, shape, mesh, variant.sharding)
        lowered = jf.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))

    hlo_text = compiled.as_text()
    ops = parse_collectives(hlo_text)
    coll = collective_summary(ops)
    # XLA:CPU cost_analysis counts while bodies once (verified) — use the
    # loop-aware HLO estimate for roofline terms; keep the raw numbers too.
    la = loop_aware_costs(hlo_text)

    rcfg = resolve_config(cfg, shape)
    n_active = active_param_count(rcfg)
    mf = model_flops(rcfg, shape, n_active)
    roof = Roofline(flops=la["flops"], hbm_bytes=la["bytes"],
                    wire_bytes=coll["total_wire_bytes"],
                    model_flops=mf, chips=chips)

    record.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        memory_analysis=_mem_dict(mem),
        cost_analysis={"flops": flops, "bytes_accessed": hbm_bytes,
                       "note": "XLA:CPU counts while bodies once"},
        loop_aware={"flops": la["flops"], "bytes": la["bytes"]},
        collectives=coll,
        active_params=n_active,
        roofline=roof.as_dict(),
    )
    return record


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes"):
        try:
            out[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    if not out:
        out["repr"] = str(mem)
    return out


def result_path(arch: str, shape: str, mesh: str,
                variant: str = "baseline") -> Path:
    suffix = "" if variant == "baseline" else f"__{variant}"
    return RESULTS_DIR / f"{arch}__{shape}__{mesh}{suffix}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline",
                    help="named optimization variant (launch/variants.py)")
    ap.add_argument("--all", action="store_true",
                    help="drive the full sweep via subprocesses")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        from ..configs import INPUT_SHAPES, list_architectures
        meshes = (["single", "multi"] if args.mesh == "both"
                  else [args.mesh])
        pairs = [(a, s, m) for a in list_architectures()
                 for s in INPUT_SHAPES for m in meshes]
        for arch, shape, mesh in pairs:
            out = result_path(arch, shape, mesh)
            if out.exists() and not args.force:
                print(f"skip (cached): {arch} {shape} {mesh}")
                continue
            print(f"== {arch} × {shape} × {mesh} ==", flush=True)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh]
            try:
                rc = subprocess.run(cmd, timeout=args.timeout).returncode
            except subprocess.TimeoutExpired:
                out.write_text(json.dumps({
                    "arch": arch, "shape": shape, "mesh": mesh,
                    "status": "timeout", "timeout_s": args.timeout}))
                print("   TIMEOUT")
                continue
            if rc != 0 and not out.exists():
                out.write_text(json.dumps({
                    "arch": arch, "shape": shape, "mesh": mesh,
                    "status": "crashed", "returncode": rc}))
        return

    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --all)")

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for mesh_kind in meshes:
        out = result_path(args.arch, args.shape, mesh_kind, args.variant)
        try:
            record = run_pair(args.arch, args.shape, mesh_kind,
                              args.variant)
        except Exception as e:  # record the failure — it's a bug to fix
            record = {"arch": args.arch, "shape": args.shape,
                      "mesh": mesh_kind, "variant": args.variant,
                      "status": "error",
                      "error": f"{type(e).__name__}: {e}",
                      "traceback": traceback.format_exc()[-4000:]}
        out.write_text(json.dumps(record, indent=1))
        status = record.get("status")
        if status == "ok":
            r = record["roofline"]
            print(f"{args.arch} {args.shape} {mesh_kind} "
                  f"[{args.variant}]: OK "
                  f"compute={r['compute_s']:.3e}s "
                  f"memory={r['memory_s']:.3e}s "
                  f"collective={r['collective_s']:.3e}s "
                  f"dominant={r['dominant']} "
                  f"useful={r['useful_flops_ratio']:.2f} "
                  f"(lower {record['lower_s']}s, "
                  f"compile {record['compile_s']}s)")
            ma = record.get("memory_analysis", {})
            print("  memory_analysis:", json.dumps(ma))
            print("  collectives:", json.dumps(record["collectives"]))
        else:
            print(f"{args.arch} {args.shape} {mesh_kind}: {status}: "
                  f"{record.get('reason', record.get('error', ''))}")
            if record.get("traceback"):
                print(record["traceback"][-1500:])


if __name__ == "__main__":
    main()
