"""Hillclimb variants (§Perf): named bundles of config + sharding changes.

Each variant states its hypothesis; the dry-run lowers the same
(arch × shape) under the variant and the roofline delta confirms or
refutes it.  `baseline` is the paper-faithful configuration every pair is
first recorded with.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from ..models.config import ArchConfig
from ..sharding.rules import ShardingOptions


@dataclass(frozen=True)
class Variant:
    name: str
    hypothesis: str
    config_overrides: Dict = field(default_factory=dict)
    sharding: ShardingOptions = ShardingOptions()

    def apply(self, cfg: ArchConfig) -> ArchConfig:
        return cfg.replace(**self.config_overrides) if \
            self.config_overrides else cfg


VARIANTS: Dict[str, Variant] = {v.name: v for v in [
    Variant(
        "baseline",
        "paper-faithful defaults: fp32 master params, remat on, "
        "FSDP+TP sharding, log-softmax CE"),
    Variant(
        "bf16-params",
        "bf16 param storage halves every param collective (FSDP gathers, "
        "grad reductions) and param HBM reads; Adam m/v stay fp32 → "
        "collective term ≈ ×0.5 on param-dominated pairs",
        config_overrides=dict(param_dtype="bfloat16")),
    Variant(
        "no-remat",
        "remat recomputes the forward inside the backward: bytes-accessed "
        "≈ ×1.3, flops ≈ ×1.33; disabling trades temp memory for both "
        "terms on pairs that fit without checkpointing",
        config_overrides=dict(remat=False)),
    Variant(
        "efficient-ce",
        "logsumexp CE avoids materialising the fp32 log-softmax tensor "
        "(B·S·V); on a 262k-vocab model that tensor is the single largest "
        "HBM consumer of the loss → memory term down on big-vocab pairs",
        config_overrides=dict(efficient_ce=True)),
    Variant(
        "attn-replicate",
        "archs with < mesh-model-size heads (gemma3: 4q/1kv) currently "
        "shard head_dim, forcing SPMD 'involuntary full remat' reshards "
        "every layer; replicating attention weights over 'model' keeps "
        "attention local per data shard → kills the reshard collectives",
        sharding=ShardingOptions(attn_model=False)),
    Variant(
        "dp-only",
        "a model whose optimizer state fits on one chip (130M Mamba2: "
        "~1.6 GB) gains nothing from 16-way TP — all its model-axis "
        "collectives are overhead. Pure DP over all 256 chips leaves only "
        "the gradient all-reduce → collective term ≈ grads·2(n−1)/n/ICI",
        sharding=ShardingOptions(use_model_axis=False,
                                 batch_over_model=True)),
    Variant(
        "opt-combo",
        "bf16 params + efficient CE + attention replication together "
        "(the per-pair winning moves composed)",
        config_overrides=dict(param_dtype="bfloat16", efficient_ce=True),
        sharding=ShardingOptions(attn_model=False)),
    Variant(
        "dp-bf16",
        "pure DP + bf16 params: grad all-reduce also halves",
        config_overrides=dict(param_dtype="bfloat16"),
        sharding=ShardingOptions(use_model_axis=False,
                                 batch_over_model=True)),
    Variant(
        "bf16-ce",
        "bf16 params + logsumexp CE (no attention-sharding change)",
        config_overrides=dict(param_dtype="bfloat16", efficient_ce=True)),
    Variant(
        "moe-small-group",
        "MoE one-hot dispatch costs 2·T·g·k·cf·D flops+bytes — LINEAR in "
        "group size g (expert matmuls are g-independent). Shrinking "
        "g 4096→1024 should cut dispatch flops/bytes ≈ 4× on MoE pairs",
        config_overrides=dict(moe_group_size=1024)),
    Variant(
        "moe-small-group-bf16-ce",
        "compose the MoE dispatch shrink with bf16 params + logsumexp CE",
        config_overrides=dict(moe_group_size=1024,
                              param_dtype="bfloat16", efficient_ce=True)),
    Variant(
        "no-remat-bf16-ce",
        "remat off + bf16 params + logsumexp CE: trade temp memory for "
        "~25% bytes and ~25% flops (backward no longer recomputes fwd)",
        config_overrides=dict(remat=False, param_dtype="bfloat16",
                              efficient_ce=True)),
    Variant(
        "dp-replicated",
        "dp-only REFUTED because FSDP-sharding params over 'data' while "
        "batch also uses 'data' forces pathological reshards. True pure "
        "DP: REPLICATE params (130M fp32 + Adam ≈ 1.6 GB/chip fits), "
        "batch over all 256 chips → only collective left is the gradient "
        "all-reduce ≈ 2·0.5 GB·(n−1)/n / 50 GB/s ≈ 0.02 s",
        sharding=ShardingOptions(replicate_params=True,
                                 batch_over_model=True)),
    Variant(
        "dp-replicated-bf16",
        "pure replicated DP + bf16 params (halves the grad all-reduce)",
        config_overrides=dict(param_dtype="bfloat16"),
        sharding=ShardingOptions(replicate_params=True,
                                 batch_over_model=True)),
    Variant(
        "moe-big-group",
        "moe-small-group REFUTED: arctic's memory term is expert-weight "
        "RE-STREAMING — the group scan re-reads 8 experts × 3·D·F ≈ "
        "3.3 GB/layer for EVERY group (256 groups × 35 layers). Weight "
        "reads ∝ T/g, dispatch tensor ∝ g; balance at g ≈ sqrt(W/5) ≈ "
        "26k → use g=32768: weight stream ÷8, dispatch still sub-"
        "dominant → memory term several× down",
        config_overrides=dict(moe_group_size=32768)),
    Variant(
        "moe-big-group-bf16-ce",
        "compose the group-size fix with bf16 params (halves the weight "
        "stream again) + logsumexp CE",
        config_overrides=dict(moe_group_size=32768,
                              param_dtype="bfloat16", efficient_ce=True)),
    Variant(
        "bf16-softmax",
        "per-op byte profile showed arctic's memory is dominated by fp32 "
        "softmax tensors (B,K,G,Sq,Sk) at k=140 (35 layers × 4 q-chunks) "
        "— 56 heads don't divide the 16-way model axis so scores are "
        "full-size per device. bf16 softmax halves that traffic (the "
        "Pallas flash kernel removes it entirely on real TPU)",
        config_overrides=dict(attn_fp32_softmax=False)),
    Variant(
        "bf16-softmax-ce",
        "bf16 softmax + bf16 params + logsumexp CE composed",
        config_overrides=dict(attn_fp32_softmax=False,
                              param_dtype="bfloat16", efficient_ce=True)),
    Variant(
        "dp-replicated-best",
        "replicated pure-DP + bf16 params + no remat + logsumexp CE: the "
        "winning small-model configuration fully composed (remat off "
        "should shave another ~25% of bytes on top of the 100× DP win)",
        config_overrides=dict(param_dtype="bfloat16", remat=False,
                              efficient_ce=True),
        sharding=ShardingOptions(replicate_params=True,
                                 batch_over_model=True)),
    Variant(
        "arctic-best",
        "compose every confirmed arctic win: no-remat (−20%) + bf16 "
        "softmax (−10%) + bf16 params + logsumexp CE",
        config_overrides=dict(remat=False, param_dtype="bfloat16",
                              efficient_ce=True, attn_fp32_softmax=False)),
]}
