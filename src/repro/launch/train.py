"""FL training driver — the paper's controller as a CLI.

  PYTHONPATH=src python -m repro.launch.train \
      --dataset mnist --strategy fedlesscan --rounds 20 \
      --clients 30 --clients-per-round 8 --stragglers 0.3

Datasets are the synthetic analogues of the paper's four (see
data/synthetic.py); `--arch <id>` instead federates a reduced assigned
architecture on a token LM task.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


from ..data import (label_sorted_shards, make_char_lm,
                    make_image_classification, make_speech_commands)
from ..data.synthetic import ArrayDataset
from ..fl.experiment import (ExperimentConfig, ScenarioConfig,
                             run_experiment)
from ..fl.tasks import ClassificationTask, TaskConfig
from ..models.small import make_char_lstm, make_cnn, make_speech_cnn


def build_dataset(name: str, n_clients: int, seed: int = 0):
    """Returns (task, train_partitions, test_partitions) mirroring the
    paper's per-dataset hyperparameters (Table I)."""
    if name == "mnist":
        full = make_image_classification(n_clients * 220, 28, 10, seed=seed)
        model = make_cnn(28, 1, 10, 512, "mnist_cnn")
        tcfg = TaskConfig(epochs=5, batch_size=10, learning_rate=1e-3,
                          per_sample_time_s=0.02)
    elif name == "femnist":
        full = make_image_classification(n_clients * 240, 28, 62, seed=seed)
        model = make_cnn(28, 1, 62, 2048, "femnist_cnn")
        tcfg = TaskConfig(epochs=5, batch_size=10, learning_rate=1e-3,
                          per_sample_time_s=0.03)
    elif name == "shakespeare":
        full = make_char_lm(n_clients * 160, seq_len=80, vocab=82, seed=seed)
        model = make_char_lstm(82, 8, 256)
        tcfg = TaskConfig(epochs=1, batch_size=32, learning_rate=0.8,
                          optimizer="sgd", per_sample_time_s=0.05)
    elif name == "speech":
        full = make_speech_commands(n_clients * 200, 32, 32, 35, seed=seed)
        model = make_speech_cnn(32, 32, 35)
        tcfg = TaskConfig(epochs=5, batch_size=5, learning_rate=1e-3,
                          per_sample_time_s=0.02)
    else:
        raise ValueError(f"unknown dataset {name!r}")

    n = len(full)
    cut = int(n * 0.85)
    train = ArrayDataset(full.x[:cut], full.y[:cut])
    test = ArrayDataset(full.x[cut:], full.y[cut:])
    parts = label_sorted_shards(train, n_clients, 2, seed=seed)
    test_parts = label_sorted_shards(test, n_clients, 2, seed=seed)
    return ClassificationTask(model, tcfg), parts, test_parts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="mnist",
                    choices=["mnist", "femnist", "shakespeare", "speech"])
    ap.add_argument("--strategy", default="fedlesscan",
                    choices=["fedavg", "fedprox", "fedlesscan"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=30)
    ap.add_argument("--clients-per-round", type=int, default=8)
    ap.add_argument("--stragglers", type=float, default=0.0,
                    help="straggler fraction (0 = standard scenario)")
    ap.add_argument("--round-timeout", type=float, default=120.0)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write result JSON here")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    task, parts, test_parts = build_dataset(args.dataset, args.clients,
                                            args.seed)
    cfg = ExperimentConfig(
        strategy=args.strategy, n_rounds=args.rounds,
        clients_per_round=args.clients_per_round, tau=args.tau,
        seed=args.seed, eval_every=5,
        scenario=ScenarioConfig(straggler_fraction=args.stragglers,
                                round_timeout_s=args.round_timeout,
                                seed=args.seed))
    res = run_experiment(task, parts, test_parts, cfg, verbose=args.verbose)

    summary = {
        "dataset": args.dataset, "strategy": args.strategy,
        "rounds": args.rounds, "stragglers": args.stragglers,
        "final_accuracy": res.final_accuracy,
        "mean_eur": res.mean_eur,
        "total_duration_s": res.total_duration_s,
        "total_cost_usd": res.total_cost,
        "bias": res.bias,
        "accuracy_curve": res.accuracy_curve,
    }
    print(json.dumps(summary, indent=2))
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(summary, indent=2))


if __name__ == "__main__":
    main()
