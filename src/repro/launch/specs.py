"""input_specs() + step builders for the dry-run.

Every model input is a jax.ShapeDtypeStruct (weak-type-correct, shardable,
no device allocation); parameter/optimizer/cache structures come from
jax.eval_shape over the real init functions, so the dry-run lowers exactly
the production step functions.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.shapes import InputShape
from ..models import decode_step, init_cache, make_train_step, prefill
from ..models.config import ArchConfig
from ..sharding import (batch_specs, cache_specs, data_axes, opt_specs,
                        param_specs, to_named)
from ..sharding.rules import DEFAULT_OPTIONS, ShardingOptions

Pytree = Any


def resolve_config(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """long_500k runs the long-context variant (attn → sliding window)."""
    if shape.name == "long_500k":
        return cfg.long_context()
    return cfg


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the batch of a train/prefill step."""
    B, S = shape.global_batch, shape.seq_len
    tok_shape = (B, cfg.n_codebooks, S) if cfg.n_codebooks else (B, S)
    batch = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
    if cfg.n_patches:
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return batch


def decode_input_specs(cfg: ArchConfig, shape: InputShape) -> Tuple:
    """(cache, tokens, pos) ShapeDtypeStructs for a serve_step."""
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S, jnp.bfloat16))
    tok_shape = (B, cfg.n_codebooks, 1) if cfg.n_codebooks else (B, 1)
    tokens = jax.ShapeDtypeStruct(tok_shape, jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    return cache, tokens, pos


def _logits_struct_spec(struct, mesh: Mesh) -> P:
    """Logits (B, S, V): batch over data axes, vocab over model when
    divisible."""
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    dspec = daxes if len(daxes) > 1 else daxes[0]
    shape = struct.shape
    spec = [None] * len(shape)
    if shape[0] % dsize == 0:
        spec[0] = dspec
    if shape[-1] % mesh.shape["model"] == 0:
        spec[-1] = "model"
    return P(*spec)


def build_train_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                     opts: ShardingOptions = DEFAULT_OPTIONS):
    """Returns (jitted_fn, example_args) ready for .lower()."""
    train_step, init_state = make_train_step(cfg)
    state_struct = jax.eval_shape(
        lambda: init_state(jax.random.PRNGKey(0)))
    batch = input_specs(cfg, shape)

    p_specs = param_specs(state_struct["params"], mesh, opts)
    o_specs = opt_specs(state_struct["opt"], p_specs, mesh, opts)
    state_specs = {"params": p_specs, "opt": o_specs}
    b_specs = batch_specs(batch, mesh, opts)

    jf = jax.jit(
        train_step,
        in_shardings=(to_named(state_specs, mesh), to_named(b_specs, mesh)),
        out_shardings=(to_named(state_specs, mesh),
                       NamedSharding(mesh, P())),
        donate_argnums=(0,))
    return jf, (state_struct, batch)


def build_prefill_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                       opts: ShardingOptions = DEFAULT_OPTIONS):
    from ..models import init_params as _init_params
    params_struct = jax.eval_shape(
        lambda: _init_params(cfg, jax.random.PRNGKey(0)))
    batch = input_specs(cfg, shape)

    def prefill_step(params, batch):
        return prefill(cfg, params, batch)

    out_struct = jax.eval_shape(prefill_step, params_struct, batch)
    logits_spec = _logits_struct_spec(out_struct[0], mesh)
    c_specs = cache_specs(out_struct[1], mesh, opts)

    jf = jax.jit(
        prefill_step,
        in_shardings=(to_named(param_specs(params_struct, mesh, opts), mesh),
                      to_named(batch_specs(batch, mesh, opts), mesh)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       to_named(c_specs, mesh)))
    return jf, (params_struct, batch)


def build_decode_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                      opts: ShardingOptions = DEFAULT_OPTIONS):
    from ..models import init_params as _init_params
    params_struct = jax.eval_shape(
        lambda: _init_params(cfg, jax.random.PRNGKey(0)))
    cache, tokens, pos = decode_input_specs(cfg, shape)

    def serve_step(params, cache, tokens, pos):
        return decode_step(cfg, params, cache, tokens, pos)

    out_struct = jax.eval_shape(serve_step, params_struct, cache, tokens, pos)
    logits_spec = _logits_struct_spec(out_struct[0], mesh)
    c_specs = cache_specs(cache, mesh, opts)

    jf = jax.jit(
        serve_step,
        in_shardings=(to_named(param_specs(params_struct, mesh, opts), mesh),
                      to_named(c_specs, mesh),
                      to_named(batch_specs(tokens, mesh, opts), mesh),
                      to_named(batch_specs(pos, mesh, opts), mesh)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       to_named(cache_specs(out_struct[1], mesh, opts),
                                mesh)),
        donate_argnums=(1,))
    return jf, (params_struct, cache, tokens, pos)


def build_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
               opts: ShardingOptions = DEFAULT_OPTIONS):
    cfg = resolve_config(cfg, shape)
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, opts)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, opts)
    return build_decode_step(cfg, shape, mesh, opts)
