"""Launchers: production mesh, dry-run, FL training driver."""
