"""Roofline terms from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips × peak)      [cost_analysis 'flops']
memory term     = HLO_bytes / (chips × HBM bw)    [cost_analysis 'bytes accessed']
collective term = wire_bytes / (chips × link bw)  [parsed from HLO text]

cost_analysis() on an SPMD-partitioned executable describes the *per-
device* module, so terms divide by peak per chip (not × chips).

Collective parsing: we walk the (partitioned) HLO text, attribute each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
to its enclosing computation, multiply ops inside while-loop bodies by the
loop trip count (recovered from the constant bound in the loop condition —
lax.scan emits `compare(iv, constant(N)), direction=LT`), and convert
tensor bytes to wire bytes with ring-algorithm factors.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List


# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# wire-bytes multiplier on the (output) tensor size, ring algorithms,
# n = participants; applied as factor(n) · tensor_bytes
_WIRE_FACTORS = {
    "all-gather": lambda n: (n - 1) / n,           # on output size
    "all-reduce": lambda n: 2 * (n - 1) / n,       # reduce-scatter + gather
    "reduce-scatter": lambda n: (n - 1) / n,       # on input size
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


@dataclass
class CollectiveOp:
    kind: str
    shape_bytes: int
    participants: int
    computation: str
    trip_count: int = 1

    @property
    def wire_bytes(self) -> float:
        return (_WIRE_FACTORS[self.kind](max(2, self.participants))
                * self.shape_bytes * self.trip_count)


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _parse_result_bytes(line: str) -> int:
    """Total bytes of the op's result shape(s) (handles tuple results)."""
    total = 0
    # result is the text between '=' and the op name; just scan all shapes
    # on the left-hand side of the op name occurrence
    lhs = line.split("=", 1)
    if len(lhs) < 2:
        return 0
    # shapes appear immediately after '=' and before the op name token
    m = re.match(r"\s*(\(?[^)]*?\)?)\s*(?:" + "|".join(_COLLECTIVES) + r")",
                 lhs[1])
    region = m.group(1) if m else lhs[1][:200]
    for dt, dims in _SHAPE_RE.findall(region):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _replica_group_size(line: str) -> int:
    """Participants per group from replica_groups={{0,1,..},{..}} or
    [n,m]<=[...] notation."""
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return 2


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Extract collectives with trip-count attribution."""
    # 1. split into computations
    comp_ops = _split_computations(hlo_text)

    # 2. find while loops: body=%comp, condition=%comp; trip counts from
    # backend_config known_trip_count when present, else the largest int
    # constant in the condition computation (scan: compare(iv, N), LT)
    trip_of_body: Dict[str, int] = {}
    for comp, lines in comp_ops.items():
        for line in lines:
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            if not (mb and mc):
                continue
            mt = re.search(r"known_trip_count[^0-9]*(\d+)", line)
            if mt:
                trip_of_body[mb.group(1)] = int(mt.group(1))
                continue
            best = 1
            for cline in comp_ops.get(mc.group(1), []):
                for c in re.findall(r"constant\((\d+)\)", cline):
                    best = max(best, int(c))
            trip_of_body[mb.group(1)] = best

    def trip_count(comp: str) -> int:
        # nested scans would need transitive multiplication; one level is
        # what our layer-stack scan produces at the collective sites
        return trip_of_body.get(comp, 1)

    # 4. collect collective ops
    ops: List[CollectiveOp] = []
    for comp, lines in comp_ops.items():
        for line in lines:
            for kind in _COLLECTIVES:
                if re.search(rf"\s{kind}(-start)?\(", line):
                    nbytes = _parse_result_bytes(line)
                    if nbytes == 0:
                        continue
                    ops.append(CollectiveOp(
                        kind=kind, shape_bytes=nbytes,
                        participants=_replica_group_size(line),
                        computation=comp, trip_count=trip_count(comp)))
                    break
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, float]:
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for op in ops:
        out[op.kind] += op.wire_bytes
    out["total_wire_bytes"] = sum(out[k] for k in _COLLECTIVES)
    out["n_ops"] = len(ops)
    return out


# ------------------------------------------------------- loop-aware costs
# XLA:CPU cost_analysis() counts each computation ONCE — while-loop bodies
# (lax.scan over layers) are not multiplied by trip count (verified by a
# scan-vs-unroll control: scan flops = exactly 1/N of unrolled).  We
# therefore re-derive flops/bytes from the optimized HLO text ourselves,
# multiplying every computation by the product of enclosing loop trip
# counts.  Flops: dot ops (matmul-dominated workloads). Bytes: operand +
# result bytes at fusion boundaries (ops inside fused computations are
# register/VMEM-resident and not charged).

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(
    r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    comp_ops: Dict[str, List[str]] = {}
    current = "<module>"
    for line in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(line)
        if m:
            current = m.group(1)
            comp_ops.setdefault(current, [])
            continue
        comp_ops.setdefault(current, []).append(line)
    return comp_ops


def _comp_multipliers(comp_ops: Dict[str, List[str]]) -> Dict[str, float]:
    """multiplier(comp) = Σ_callsites mult(parent) · trip_factor."""
    # edges: parent -> (child, trip_factor)
    edges: Dict[str, List] = {}
    trip_cache: Dict[str, int] = {}

    def cond_trip(line: str, cond: str) -> int:
        mt = re.search(r"known_trip_count[^0-9]*(\d+)", line)
        if mt:
            return int(mt.group(1))
        best = 1
        for cline in comp_ops.get(cond, []):
            for c in re.findall(r"constant\((\d+)\)", cline):
                best = max(best, int(c))
        return best

    for comp, lines in comp_ops.items():
        for line in lines:
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            if mb and mc:
                t = cond_trip(line, mc.group(1))
                trip_cache[mb.group(1)] = t
                edges.setdefault(comp, []).append((mb.group(1), t))
                edges.setdefault(comp, []).append((mc.group(1), t))
                continue
            for ref in re.findall(
                    r"(?:calls|to_apply|branch_computations)="
                    r"[{]?%?([\w\.\-{}, %]+)", line):
                for child in re.findall(r"[\w\.\-]+", ref):
                    edges.setdefault(comp, []).append((child, 1))

    mult: Dict[str, float] = {}

    entry = None
    for comp in comp_ops:
        if comp == "<module>":
            continue
        if entry is None:
            entry = comp
    # computations with no incoming edge are roots (entry); others resolved
    # by propagation. Iterate to fixpoint (call graph is a DAG in HLO).
    incoming: Dict[str, List] = {}
    for parent, outs in edges.items():
        for child, t in outs:
            incoming.setdefault(child, []).append((parent, t))
    all_comps = [c for c in comp_ops if c != "<module>"]
    for c in all_comps:
        if c not in incoming:
            mult[c] = 1.0
    for _ in range(len(all_comps) + 2):
        changed = False
        for c in all_comps:
            if c not in incoming:
                continue
            val = 0.0
            ok = True
            for parent, t in incoming[c]:
                if parent not in mult:
                    ok = False
                    break
                val += mult[parent] * t
            if ok and (c not in mult or abs(mult[c] - val) > 1e-9):
                mult[c] = val
                changed = True
        if not changed:
            break
    return mult


def loop_aware_costs(hlo_text: str) -> Dict[str, float]:
    """Returns {'flops': ..., 'bytes': ...} with while-body multiplication.

    flops: 2 · |result| · |contracted| per dot (matmul-dominated models);
    bytes: result + operand bytes at fusion boundaries (ops inside fused
    computations are register/VMEM-resident and not charged).
    """
    comp_ops = _split_computations(hlo_text)
    mult = _comp_multipliers(comp_ops)

    # global name -> (dims list, dtype bytes); HLO names are module-unique
    shapes: Dict[str, tuple] = {}
    for lines in comp_ops.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if m and m.group(2) in _DTYPE_BYTES:
                dims = [int(x) for x in m.group(3).split(",") if x]
                shapes[m.group(1)] = (dims, _DTYPE_BYTES[m.group(2)])

    def nbytes(name: str) -> int:
        if name not in shapes:
            return 0
        dims, b = shapes[name]
        n = b
        for d in dims:
            n *= d
        return n

    flops = 0.0
    bytes_ = 0.0
    for comp, lines in comp_ops.items():
        if comp == "<module>":
            continue
        k = mult.get(comp, 1.0)
        fused = "fused" in comp
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            _, dt, dims_s = m.group(1), m.group(2), m.group(3)
            if re.search(r"\bdot\(", line):  # flops incl. fused comps
                out_elems = _shape_elems(dims_s)
                contract = 1
                mlhs = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                mop = re.search(r"dot\(\s*%?([\w\.\-]+)", line)
                if mlhs and mop and mop.group(1) in shapes:
                    lhs_dims = shapes[mop.group(1)][0]
                    for di in (int(x) for x in mlhs.group(1).split(",")
                               if x):
                        if di < len(lhs_dims):
                            contract *= lhs_dims[di]
                flops += k * 2.0 * out_elems * max(1, contract)
            if fused:
                continue
            if dt in _DTYPE_BYTES and not re.search(
                    r"\b(parameter|constant|get-tuple-element|tuple|"
                    r"bitcast|copy-done|after-all)\b", line):
                out_b = _shape_elems(dims_s) * _DTYPE_BYTES[dt]
                refs = re.findall(r"%([\w\.\-]+)", line)[1:]
                opnd_b = sum(nbytes(r) for r in refs)
                bytes_ += k * (out_b + opnd_b)
    return {"flops": flops, "bytes": bytes_}


# -------------------------------------------------------------- roofline
@dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    wire_bytes: float            # per-device collective wire bytes
    model_flops: float           # analytic useful flops (global)
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips × per-device HLO flops)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "wire_bytes_per_device": self.wire_bytes,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops(cfg, shape, n_params_active: int) -> float:
    """Analytic 'useful' flops: 6·N·D train, 2·N·D inference."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape.global_batch


def active_param_count(cfg) -> int:
    """Params touched per token (MoE: top_k of E experts)."""
    from ..models.config import param_count
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    expert_params = 3 * cfg.d_model * cfg.d_ff      # per expert, per block
    layer_positions = [i for i, k in enumerate(cfg.pattern)
                       if k != "shared_attn"]
    n_moe_blocks = sum(
        1 for li in range(cfg.n_layers)
        if cfg.use_moe(layer_positions[li % len(layer_positions)]))
    inactive = (cfg.n_experts - cfg.top_k) * expert_params * n_moe_blocks
    return total - inactive
