"""Synthetic dataset generators.

The container is offline, so the paper's datasets (MNIST, FEMNIST,
Shakespeare, Google Speech) are replaced by synthetic generators that
preserve what the *scheduling* experiments actually depend on: input/label
shapes, class structure that a small model can learn (so accuracy curves
are meaningful), and per-client heterogeneity statistics.  The
partitioning protocols themselves (label-sorted shards etc.) are faithful
— see partition.py.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class ArrayDataset:
    x: np.ndarray
    y: np.ndarray

    def __len__(self) -> int:
        return self.x.shape[0]

    def subset(self, idx: np.ndarray) -> "ArrayDataset":
        return ArrayDataset(self.x[idx], self.y[idx])


def make_image_classification(n_samples: int, image_size: int = 28,
                              n_classes: int = 10, channels: int = 1,
                              noise: float = 0.35,
                              seed: int = 0) -> ArrayDataset:
    """MNIST-like: one smooth random template per class + pixel noise.

    Learnable by a small CNN within a few epochs; classes are balanced.
    """
    rng = np.random.default_rng(seed)
    # low-frequency class templates: random coarse grids upsampled
    coarse = rng.normal(size=(n_classes, 7, 7, channels))
    reps = image_size // 7
    templates = np.kron(coarse, np.ones((1, reps, reps, 1)))
    templates = templates[:, :image_size, :image_size, :]
    y = rng.integers(0, n_classes, size=n_samples)
    x = templates[y] + noise * rng.normal(
        size=(n_samples, image_size, image_size, channels))
    return ArrayDataset(x.astype(np.float32), y.astype(np.int32))


def make_char_lm(n_samples: int, seq_len: int = 80, vocab: int = 82,
                 order_classes: int = 8, seed: int = 0) -> ArrayDataset:
    """Shakespeare-like next-char prediction: sequences drawn from a
    low-entropy Markov chain (so an LSTM can reduce perplexity).

    x: (N, seq_len) int32 context, y: (N,) int32 next char.
    """
    rng = np.random.default_rng(seed)
    # sparse transition matrix: each char strongly prefers a few successors
    trans = rng.dirichlet(np.full(vocab, 0.05), size=vocab)
    seqs = np.empty((n_samples, seq_len + 1), dtype=np.int32)
    state = rng.integers(0, vocab, size=n_samples)
    for t in range(seq_len + 1):
        seqs[:, t] = state
        # vectorised categorical draw per current state
        u = rng.random(n_samples)
        cdf = np.cumsum(trans[state], axis=1)
        state = (u[:, None] < cdf).argmax(axis=1)
    del order_classes
    return ArrayDataset(seqs[:, :seq_len], seqs[:, seq_len])


def make_speech_commands(n_samples: int, frames: int = 32, mels: int = 32,
                         n_classes: int = 35, noise: float = 0.4,
                         seed: int = 0) -> ArrayDataset:
    """Google-Speech-like keyword spotting: class-dependent spectro-temporal
    patterns (a 'keyword' = a characteristic ridge in the mel spectrogram).
    """
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 1, frames)[None, :, None]
    f = np.linspace(0, 1, mels)[None, None, :]
    freq = rng.uniform(0.1, 0.9, size=(n_classes, 1, 1))
    slope = rng.uniform(-0.5, 0.5, size=(n_classes, 1, 1))
    width = rng.uniform(0.05, 0.2, size=(n_classes, 1, 1))
    ridge = np.exp(-((f - (freq + slope * t)) ** 2) / (2 * width ** 2))
    y = rng.integers(0, n_classes, size=n_samples)
    x = ridge[y] + noise * rng.normal(size=(n_samples, frames, mels))
    return ArrayDataset(x[..., None].astype(np.float32), y.astype(np.int32))


def make_token_lm(n_tokens: int, vocab: int = 32000, seq_len: int = 256,
                  seed: int = 0) -> ArrayDataset:
    """Token stream for pretraining drivers: Zipf-distributed ids with local
    bigram structure. x: (N, seq_len), y = x shifted by one."""
    rng = np.random.default_rng(seed)
    n_seq = max(1, n_tokens // (seq_len + 1))
    base = rng.zipf(1.3, size=(n_seq, seq_len + 1)).astype(np.int64)
    toks = np.minimum(base, vocab - 1).astype(np.int32)
    # inject bigram structure: every even position repeats prev+1 mod vocab
    toks[:, 2::2] = (toks[:, 1:-1:2] + 1) % vocab
    return ArrayDataset(toks[:, :-1], toks[:, 1:])
