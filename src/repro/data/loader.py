"""Minimal batching pipeline for client-local training loops."""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

from .synthetic import ArrayDataset


def batches(ds: ArrayDataset, batch_size: int, rng: np.random.Generator,
            drop_remainder: bool = False) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """One shuffled epoch of (x, y) minibatches."""
    order = rng.permutation(len(ds))
    n = len(order)
    stop = (n // batch_size) * batch_size if drop_remainder else n
    for i in range(0, stop, batch_size):
        idx = order[i:i + batch_size]
        yield ds.x[idx], ds.y[idx]


def num_batches(ds: ArrayDataset, batch_size: int,
                drop_remainder: bool = False) -> int:
    n = len(ds)
    return n // batch_size if drop_remainder else -(-n // batch_size)
