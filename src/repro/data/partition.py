"""Federated partitioning protocols (paper §VI-A1).

- `label_sorted_shards`: the paper's MNIST protocol — sort by label, split
  into shards of fixed size, deal shards to clients (non-IID: most clients
  see only 1-2 classes).
- `dirichlet_partition`: standard non-IID label-skew control (alpha).
- `lognormal_sizes`: statistical heterogeneity in per-client cardinality
  (FEMNIST has ~226 imgs/client, Shakespeare ~3743 — heavy-tailed).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .synthetic import ArrayDataset


def label_sorted_shards(ds: ArrayDataset, n_clients: int,
                        shards_per_client: int = 2,
                        seed: int = 0) -> Dict[str, ArrayDataset]:
    """Sort by label → split into n_clients*shards_per_client shards →
    deal `shards_per_client` random shards to each client."""
    rng = np.random.default_rng(seed)
    order = np.argsort(ds.y, kind="stable")
    n_shards = n_clients * shards_per_client
    shards = np.array_split(order, n_shards)
    perm = rng.permutation(n_shards)
    out = {}
    for c in range(n_clients):
        take = perm[c * shards_per_client:(c + 1) * shards_per_client]
        idx = np.concatenate([shards[s] for s in take])
        out[f"client_{c}"] = ds.subset(idx)
    return out


def dirichlet_partition(ds: ArrayDataset, n_clients: int, alpha: float = 0.5,
                        seed: int = 0) -> Dict[str, ArrayDataset]:
    rng = np.random.default_rng(seed)
    classes = np.unique(ds.y)
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for k in classes:
        idx = np.nonzero(ds.y == k)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for c, chunk in enumerate(np.split(idx, cuts)):
            client_idx[c].extend(chunk.tolist())
    return {f"client_{c}": ds.subset(np.array(sorted(ix), dtype=np.int64))
            for c, ix in enumerate(client_idx)}


def lognormal_sizes(n_clients: int, mean_samples: int, sigma: float = 0.6,
                    min_samples: int = 8, seed: int = 0) -> np.ndarray:
    """Heavy-tailed per-client sample counts summing roughly to
    n_clients*mean_samples."""
    rng = np.random.default_rng(seed)
    raw = rng.lognormal(mean=0.0, sigma=sigma, size=n_clients)
    sizes = np.maximum(min_samples,
                       (raw / raw.sum() * n_clients * mean_samples)).astype(int)
    return sizes


def partition_by_sizes(ds: ArrayDataset, sizes: np.ndarray,
                       seed: int = 0) -> Dict[str, ArrayDataset]:
    """IID split with heterogeneous cardinalities."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(ds))
    out, pos = {}, 0
    for c, s in enumerate(sizes):
        s = int(min(s, len(ds) - pos)) if pos < len(ds) else 0
        idx = order[pos:pos + s] if s > 0 else order[:1]
        out[f"client_{c}"] = ds.subset(idx)
        pos += s
    return out
