from .loader import batches, num_batches
from .partition import (dirichlet_partition, label_sorted_shards,
                        lognormal_sizes, partition_by_sizes)
from .synthetic import (ArrayDataset, make_char_lm, make_image_classification,
                        make_speech_commands, make_token_lm)

__all__ = ["batches", "num_batches", "dirichlet_partition",
           "label_sorted_shards", "lognormal_sizes", "partition_by_sizes",
           "ArrayDataset", "make_char_lm", "make_image_classification",
           "make_speech_commands", "make_token_lm"]
