"""Pallas TPU kernels: client-update compression (encode/decode pair).

FedLess (arXiv:2111.03396) measures update-transfer size as the dominant
serverless FL cost driver; this module shrinks the per-round client
payload 10-50x with two schemes, both exact enough to keep the delta
MergePipeline (Reddi et al., arXiv:2003.00295) parity-correct when
combined with client-side error feedback (core/compress.py):

  int8 per-chunk quantization — the flattened update is cut into fixed
      chunks; each chunk carries one fp32 scale = absmax/127 and int8
      codes q = round(x/scale).  Payload: 1 byte/param + 4 bytes/chunk.
  top-k sparsification — keep the k largest-|x| entries (ties broken
      deterministically toward the LOWEST index, matching lax.top_k), zero
      the rest.  Payload: 8 bytes/kept entry (int32 index + fp32 value).

The kernels operate on the server-side *decode* representation (a dense
(P,) vector) because everything downstream — fed_agg, fed_agg_apply, the
sharded merge — consumes dense flats; the wire format is a simulation
quantity (payload_bytes on ClientUpdate), not a serialized artifact.

Like fed_agg, blocks are 2D (rows × lanes) so Mosaic lowering gets the
(8, 128)-friendly layouts it wants; iota is always built 2D per the
Pallas TPU rules.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COMPRESS_SCHEMES = ("none", "topk", "int8")


# ------------------------------------------------------------ int8
def _int8_encode_kernel(x_ref, q_ref, scale_ref):
    """One (TR, C) block of chunk-rows → int8 codes + per-row scale.

    scale = absmax/127 (1.0 when the chunk is all-zero, so decode is
    exact 0 and no NaN/inf ever enters the payload path); codes use
    round-half-to-even, matching jnp.round in the oracle bit-for-bit.
    """
    x = x_ref[...].astype(jnp.float32)                       # (TR, C)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)      # (TR, 1)
    scale = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("chunk", "tile_r", "interpret"))
def int8_encode(x: jnp.ndarray, chunk: int = 256, tile_r: int = 8,
                interpret: bool = True):
    """x: (P,) float → (q: (n_chunks, chunk) int8, scale: (n_chunks,) f32).

    P is zero-padded up to a whole number of chunks (pad codes decode to
    exact 0 and are sliced away by int8_decode), chunk rows are padded to
    a tile_r multiple for the grid.
    """
    P = x.shape[0]
    n_chunks = -(-P // chunk)
    n_rows = -(-n_chunks // tile_r) * tile_r
    xm = jnp.pad(x.astype(jnp.float32),
                 (0, n_rows * chunk - P)).reshape(n_rows, chunk)

    q, scale = pl.pallas_call(
        _int8_encode_kernel,
        grid=(n_rows // tile_r,),
        in_specs=[pl.BlockSpec((tile_r, chunk), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((tile_r, chunk), lambda i: (i, 0)),
                   pl.BlockSpec((tile_r, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n_rows, chunk), jnp.int8),
                   jax.ShapeDtypeStruct((n_rows, 1), jnp.float32)],
        interpret=interpret,
    )(xm)
    return q[:n_chunks], scale[:n_chunks, 0]


def _int8_decode_kernel(q_ref, scale_ref, out_ref):
    out_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("length", "tile_r", "interpret"))
def int8_decode(q: jnp.ndarray, scale: jnp.ndarray, length: int,
                tile_r: int = 8, interpret: bool = True) -> jnp.ndarray:
    """Inverse of int8_encode: (n_chunks, chunk) int8 + (n_chunks,) f32
    scales → dense (length,) f32."""
    n_chunks, chunk = q.shape
    n_rows = -(-n_chunks // tile_r) * tile_r
    qm = jnp.pad(q, ((0, n_rows - n_chunks), (0, 0)))
    sm = jnp.pad(scale.astype(jnp.float32),
                 (0, n_rows - n_chunks)).reshape(n_rows, 1)

    out = pl.pallas_call(
        _int8_decode_kernel,
        grid=(n_rows // tile_r,),
        in_specs=[pl.BlockSpec((tile_r, chunk), lambda i: (i, 0)),
                  pl.BlockSpec((tile_r, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_r, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, chunk), jnp.float32),
        interpret=interpret,
    )(qm, sm)
    return out.reshape(-1)[:length]


# ------------------------------------------------------------ top-k
def _topk_mask_kernel(scal_ref, idx_ref, x_ref, out_ref):
    """One P-tile: keep x where |x| exceeds the threshold, plus the
    tie-breaking entries |x| == tau at global index ≤ last_keep (lowest-
    index-wins, the lax.top_k order), zero elsewhere."""
    tau = scal_ref[0, 0]
    last_keep = idx_ref[0, 0]
    x = x_ref[...]                                           # (1, TP)
    tp = x.shape[1]
    gidx = (pl.program_id(0) * tp
            + jax.lax.broadcasted_iota(jnp.int32, (1, tp), 1))
    ax = jnp.abs(x)
    keep = (ax > tau) | ((ax == tau) & (gidx <= last_keep))
    out_ref[...] = jnp.where(keep, x, 0.0)


@functools.partial(jax.jit, static_argnames=("tile_p", "interpret"))
def topk_mask(x: jnp.ndarray, tau: jnp.ndarray, last_keep: jnp.ndarray,
              tile_p: int = 2048, interpret: bool = True) -> jnp.ndarray:
    """Dense top-k decode given a threshold: x (P,) f32, tau the k-th
    largest |x|, last_keep the largest kept global index among the
    |x| == tau ties.  Zero-padded tail lanes have |x| = 0 ≤ tau and a
    value of 0 either way, so they never contaminate the output."""
    P = x.shape[0]
    tile_p = min(tile_p, P)
    n_tiles = -(-P // tile_p)
    pad = n_tiles * tile_p - P
    xr = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(1, -1)
    scal = jnp.full((1, 8), tau, jnp.float32)
    idx = jnp.full((1, 8), last_keep, jnp.int32)

    out = pl.pallas_call(
        _topk_mask_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((1, 8), lambda i: (0, 0)),
                  pl.BlockSpec((1, 8), lambda i: (0, 0)),
                  pl.BlockSpec((1, tile_p), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_tiles * tile_p), jnp.float32),
        interpret=interpret,
    )(scal, idx, xr)
    return out[0, :P]


@functools.partial(jax.jit, static_argnames=("k", "tile_p", "interpret"))
def topk_encode(x: jnp.ndarray, k: int, tile_p: int = 2048,
                interpret: bool = True):
    """x: (P,) float → (idx (k,) int32, vals (k,) f32, decoded (P,) f32).

    lax.top_k on |x| supplies the threshold and the deterministic
    tie-break order (equal magnitudes keep the lowest index); the Pallas
    mask kernel then materializes the dense decode in one pass without a
    (P,)-sized scatter.
    """
    P = x.shape[0]
    xf = x.astype(jnp.float32)
    if k >= P:                      # degenerate: keep everything
        idx = jnp.arange(P, dtype=jnp.int32)
        return idx, xf, xf
    mags, idx = jax.lax.top_k(jnp.abs(xf), k)
    tau = mags[k - 1]
    last_keep = jnp.max(jnp.where(mags == tau, idx, -1)).astype(jnp.int32)
    decoded = topk_mask(xf, tau, last_keep, tile_p=tile_p,
                        interpret=interpret)
    return idx.astype(jnp.int32), xf[idx], decoded


@functools.partial(jax.jit, static_argnames=("length",))
def topk_decode(idx: jnp.ndarray, vals: jnp.ndarray,
                length: int) -> jnp.ndarray:
    """Scatter the (idx, vals) wire format back to a dense (length,) f32
    vector — the oracle counterpart of the masked decode."""
    return jnp.zeros((length,), jnp.float32).at[idx].set(
        vals.astype(jnp.float32))
