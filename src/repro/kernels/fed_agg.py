"""Pallas TPU kernels: staleness-weighted federated aggregation.

The FL server's hotspot (paper §V-D, Eq. 3): the weighted sum of K client
updates, w = Σ_k c_k · W_k, where c_k = (t_k/t)·(n_k/n).  On GPU this is a
grid-stride loop; on TPU we tile the stacked update matrix (K, P) into
VMEM blocks along P, broadcast the (K,) coefficient vector, and fuse the
multiply+reduce on the VPU in fp32 regardless of update dtype.

`fed_agg_apply` extends the same (K, P) layout into the full server-side
merge step of the delta pipeline (core/merge.py): one kernel dispatch
computes the weighted sum, forms the pseudo-gradient
Δ = mix·(Σ_k c_k·W_k − w), folds Δ into the server optimizer's moment
buffers (FedAvgM / FedAdagrad / FedAdam / FedYogi — Reddi et al.,
arXiv:2003.00295), and applies the optimizer step to the global model —
plus a per-tile Σ Δ² side output so ‖Δ‖₂ diagnostics cost no extra pass.
The optimizer family is a *static* argument (the branch is resolved at
trace time); the hyperparameters (lr, mix, β₁, β₂, ε) travel as a tiny
runtime vector so staleness-dependent mixing rates never retrace.

`fed_agg_sharded` / `fed_agg_apply_sharded` dispatch the same kernels
under shard_map on a device mesh: the flat P dim is split over every
mesh axis (sharding/rules.merge_axes), each device runs the kernel on
its slab, and only the scalar ‖Δ‖² crosses the mesh (one psum) — the
merge itself is embarrassingly parallel along P.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..sharding.rules import merge_axes

# optimizer families the fused apply kernel can lower; "sgd"/"fedavgm"
# share the heavy-ball branch (momentum 0 reduces to plain server-SGD)
APPLY_OPTS = ("sgd", "fedavgm", "fedadagrad", "fedadam", "fedyogi")


def _fed_agg_kernel(coeff_ref, upd_ref, out_ref):
    """One P-tile: out[tile] = Σ_k coeff[k] · upd[k, tile] (fp32 acc)."""
    upd = upd_ref[...].astype(jnp.float32)          # (K, TP)
    coeff = coeff_ref[...].astype(jnp.float32)      # (K, 1)
    out_ref[...] = jnp.sum(upd * coeff, axis=0,
                           keepdims=True).astype(out_ref.dtype)


def _fed_agg_impl(updates: jnp.ndarray, coeffs: jnp.ndarray,
                  tile_p: int = 2048,
                  interpret: bool = True) -> jnp.ndarray:
    """updates: (K, P); coeffs: (K,) → (P,).

    P is padded to a tile multiple; each grid step owns one P tile with
    the full K rows resident in VMEM (K is tens of clients — a (K, 2048)
    fp32 block is ≤ a few hundred KB, well inside the ~16 MB VMEM).
    """
    K, P = updates.shape
    tile_p = min(tile_p, P)
    n_tiles = -(-P // tile_p)
    pad = n_tiles * tile_p - P
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    coeffs2 = coeffs.reshape(K, 1).astype(jnp.float32)

    out = pl.pallas_call(
        _fed_agg_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, tile_p), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_tiles * tile_p), updates.dtype),
        interpret=interpret,
    )(coeffs2, updates)
    return out[0, :P]


# jit twins: same trace, the donated one hands the (K, P) update matrix's
# buffer back to XLA for in-place reuse.  Donation picks the variant at
# the *python* level so the static signature (and the compiled cache key)
# stays identical whether the caller donates or not.
_fed_agg_jit = jax.jit(_fed_agg_impl,
                       static_argnames=("tile_p", "interpret"))
_fed_agg_donated = jax.jit(_fed_agg_impl,
                           static_argnames=("tile_p", "interpret"),
                           donate_argnums=(0,))


def _can_donate() -> bool:
    """CPU XLA ignores donation (and warns per dispatch) — only offer
    buffers on accelerator backends."""
    return jax.default_backend() != "cpu"


def fed_agg(updates: jnp.ndarray, coeffs: jnp.ndarray,
            tile_p: int = 2048, interpret: bool = True,
            donate: bool = False) -> jnp.ndarray:
    """Weighted sum of K stacked updates; see ``_fed_agg_impl``.

    ``donate=True`` promises ``updates`` is a fresh temporary (e.g. the
    merge matrix gathered from a ``DeviceUpdateBatch``) that the caller
    never touches again, letting XLA recycle the K·P buffer in place.
    """
    fn = _fed_agg_donated if (donate and _can_donate()) else _fed_agg_jit
    return fn(updates, coeffs, tile_p=tile_p, interpret=interpret)


def _make_apply_kernel(opt: str):
    """Build the fused merge kernel body for one optimizer family.

    Per P-tile, entirely on the VPU in fp32:

        s     = Σ_k coeff[k] · upd[k, tile]          (weighted sum)
        Δ     = mix · (s − g)                        (pseudo-gradient)
        m, v  = moment update (family-specific)
        out   = g + lr · step(m, v)

    Zero-padded tail lanes are harmless: upd/g/m/v pads are 0, so Δ, the
    moments, and the Σ Δ² side output all stay 0 there.
    """

    def kernel(scal_ref, coeff_ref, upd_ref, g_ref, m_ref, v_ref,
               out_ref, m_out_ref, v_out_ref, sq_ref):
        lr = scal_ref[0, 0]
        mix = scal_ref[0, 1]
        b1 = scal_ref[0, 2]
        b2 = scal_ref[0, 3]
        eps = scal_ref[0, 4]
        upd = upd_ref[...].astype(jnp.float32)          # (K, TP)
        coeff = coeff_ref[...].astype(jnp.float32)      # (K, 1)
        g = g_ref[...].astype(jnp.float32)              # (1, TP)
        s = jnp.sum(upd * coeff, axis=0, keepdims=True)
        delta = mix * (s - g)
        sq_ref[0, 0] = jnp.sum(delta * delta)
        if opt in ("sgd", "fedavgm"):
            # heavy-ball: m ← β·m + Δ (β = server momentum; 0 → plain Δ)
            m = b1 * m_ref[...] + delta
            v = v_ref[...]
            step = m
        else:
            m = b1 * m_ref[...] + (1.0 - b1) * delta
            dsq = delta * delta
            if opt == "fedadagrad":
                v = v_ref[...] + dsq
            elif opt == "fedadam":
                v = b2 * v_ref[...] + (1.0 - b2) * dsq
            else:                                        # fedyogi
                v0 = v_ref[...]
                v = v0 - (1.0 - b2) * dsq * jnp.sign(v0 - dsq)
            step = m / (jnp.sqrt(v) + eps)
        out_ref[...] = g + lr * step
        m_out_ref[...] = m
        v_out_ref[...] = v

    return kernel


def _fed_agg_apply_impl(updates: jnp.ndarray, coeffs: jnp.ndarray,
                        params: jnp.ndarray, m: jnp.ndarray,
                        v: jnp.ndarray, lr, mix, b1, b2, eps, *,
                        opt: str = "fedadam", tile_p: int = 2048,
                        interpret: bool = True):
    """Fused server-update step on the flattened model.

    updates: (K, P); coeffs: (K,); params/m/v: (P,) fp32 moment buffers.
    Returns ``(new_params, new_m, new_v, update_norm)`` where
    ``update_norm = ‖Δ‖₂`` of the pseudo-gradient Δ = mix·(Σ c·W − w).
    """
    if opt not in APPLY_OPTS:
        raise ValueError(f"unknown server opt {opt!r}; "
                         f"available: {APPLY_OPTS}")
    K, P = updates.shape
    tile_p = min(tile_p, P)
    n_tiles = -(-P // tile_p)
    pad = n_tiles * tile_p - P
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    row = lambda x: jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(1, -1)
    g2, m2, v2 = row(params), row(m), row(v)
    coeffs2 = coeffs.reshape(K, 1).astype(jnp.float32)
    scal = jnp.stack([jnp.float32(lr), jnp.float32(mix), jnp.float32(b1),
                      jnp.float32(b2), jnp.float32(eps),
                      jnp.float32(0.0), jnp.float32(0.0),
                      jnp.float32(0.0)]).reshape(1, 8)

    vec = jax.ShapeDtypeStruct((1, n_tiles * tile_p), jnp.float32)
    vec_spec = pl.BlockSpec((1, tile_p), lambda i: (0, i))
    out, m_new, v_new, sq = pl.pallas_call(
        _make_apply_kernel(opt),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, tile_p), lambda i: (0, i)),
            vec_spec, vec_spec, vec_spec,
        ],
        out_specs=[vec_spec, vec_spec, vec_spec,
                   pl.BlockSpec((1, 1), lambda i: (0, i))],
        out_shape=[vec, vec, vec,
                   jax.ShapeDtypeStruct((1, n_tiles), jnp.float32)],
        interpret=interpret,
    )(scal, coeffs2, updates, g2, m2, v2)
    norm = jnp.sqrt(jnp.sum(sq))
    return out[0, :P], m_new[0, :P], v_new[0, :P], norm


# donation twin: hand back the update matrix (0) and the moment buffers
# m/v (3, 4) — but NEVER params (2): strategies retain global_params, and
# on single-leaf models the raveled view can alias the live tree's leaf.
_fed_agg_apply_jit = jax.jit(
    _fed_agg_apply_impl,
    static_argnames=("opt", "tile_p", "interpret"))
_fed_agg_apply_donated = jax.jit(
    _fed_agg_apply_impl,
    static_argnames=("opt", "tile_p", "interpret"),
    donate_argnums=(0, 3, 4))


def fed_agg_apply(updates: jnp.ndarray, coeffs: jnp.ndarray,
                  params: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray,
                  lr, mix, b1, b2, eps, *, opt: str = "fedadam",
                  tile_p: int = 2048, interpret: bool = True,
                  donate: bool = False):
    """Fused server merge; see ``_fed_agg_apply_impl``.

    ``donate=True`` recycles the update matrix and the flat m/v moment
    buffers in place (the merge pipeline rebuilds fresh flats for the
    next round from its pytree state, so the old ones are dead after the
    dispatch).  ``params`` is never donated.
    """
    fn = (_fed_agg_apply_donated if (donate and _can_donate())
          else _fed_agg_apply_jit)
    return fn(updates, coeffs, params, m, v, lr, mix, b1, b2, eps,
              opt=opt, tile_p=tile_p, interpret=interpret)


# ------------------------------------------------------------ sharded
def _pad_p(arr: jnp.ndarray, mult: int) -> jnp.ndarray:
    """Zero-pad the trailing (P) dim to a multiple of ``mult``."""
    pad = (-arr.shape[-1]) % mult
    if not pad:
        return arr
    width = [(0, 0)] * (arr.ndim - 1) + [(0, pad)]
    return jnp.pad(arr, width)


def fed_agg_sharded(updates: jnp.ndarray, coeffs: jnp.ndarray, mesh,
                    tile_p: int = 2048,
                    interpret: bool = True) -> jnp.ndarray:
    """fed_agg with the P dim sharded over every axis of ``mesh``.

    updates (K, P) shard as (replicated, all-axes); coeffs replicate; the
    output gathers back to a dense (P,).  Zero padding up to the device
    count is numerically inert (0·c contributes 0).
    """
    axes = merge_axes(mesh)
    n = int(mesh.size)
    if n <= 1:
        return fed_agg(updates, coeffs, tile_p=tile_p, interpret=interpret)
    Pdim = updates.shape[1]
    upd = _pad_p(updates, n)

    f = shard_map(
        functools.partial(fed_agg, tile_p=tile_p, interpret=interpret),
        mesh=mesh,
        in_specs=(P(None, axes), P(None)),
        out_specs=P(axes), check_rep=False)
    return f(upd, coeffs)[:Pdim]


def fed_agg_apply_sharded(updates: jnp.ndarray, coeffs: jnp.ndarray,
                          params: jnp.ndarray, m: jnp.ndarray,
                          v: jnp.ndarray, lr, mix, b1, b2, eps, *,
                          opt: str = "fedadam", mesh,
                          tile_p: int = 2048, interpret: bool = True):
    """fed_agg_apply with the P dim sharded over every axis of ``mesh``.

    Each device owns a P slab of updates/params/moments and runs the
    fused kernel locally; the only cross-device traffic is the scalar
    Σ Δ² psum for the update-norm diagnostic.  Zero-padded slab tails
    keep params/moments/Δ at exact 0 (see the kernel docstring), so the
    sharded result matches the single-device merge to fp32 tolerance.
    """
    axes = merge_axes(mesh)
    n = int(mesh.size)
    if n <= 1:
        return fed_agg_apply(updates, coeffs, params, m, v,
                             lr, mix, b1, b2, eps, opt=opt,
                             tile_p=tile_p, interpret=interpret)
    Pdim = updates.shape[1]
    upd = _pad_p(updates, n)
    g2, m2, v2 = (_pad_p(x.astype(jnp.float32), n) for x in (params, m, v))

    def local(u, c, g, mm, vv):
        out, m_new, v_new, norm = fed_agg_apply(
            u, c, g, mm, vv, lr, mix, b1, b2, eps, opt=opt,
            tile_p=tile_p, interpret=interpret)
        sumsq = jax.lax.psum(norm * norm, axes)
        return out, m_new, v_new, jnp.sqrt(sumsq)

    vec = P(axes)
    f = shard_map(local, mesh=mesh,
                  in_specs=(P(None, axes), P(None), vec, vec, vec),
                  out_specs=(vec, vec, vec, P()), check_rep=False)
    out, m_new, v_new, norm = f(upd, coeffs, g2, m2, v2)
    return out[:Pdim], m_new[:Pdim], v_new[:Pdim], norm
