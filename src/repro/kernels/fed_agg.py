"""Pallas TPU kernel: staleness-weighted federated aggregation.

The FL server's hotspot (paper §V-D, Eq. 3): the weighted sum of K client
updates, w = Σ_k c_k · W_k, where c_k = (t_k/t)·(n_k/n).  On GPU this is a
grid-stride loop; on TPU we tile the stacked update matrix (K, P) into
VMEM blocks along P, broadcast the (K,) coefficient vector, and fuse the
multiply+reduce on the VPU in fp32 regardless of update dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fed_agg_kernel(coeff_ref, upd_ref, out_ref):
    """One P-tile: out[tile] = Σ_k coeff[k] · upd[k, tile] (fp32 acc)."""
    upd = upd_ref[...].astype(jnp.float32)          # (K, TP)
    coeff = coeff_ref[...].astype(jnp.float32)      # (K, 1)
    out_ref[...] = jnp.sum(upd * coeff, axis=0,
                           keepdims=True).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_p", "interpret"))
def fed_agg(updates: jnp.ndarray, coeffs: jnp.ndarray,
            tile_p: int = 2048, interpret: bool = True) -> jnp.ndarray:
    """updates: (K, P); coeffs: (K,) → (P,).

    P is padded to a tile multiple; each grid step owns one P tile with
    the full K rows resident in VMEM (K is tens of clients — a (K, 2048)
    fp32 block is ≤ a few hundred KB, well inside the ~16 MB VMEM).
    """
    K, P = updates.shape
    tile_p = min(tile_p, P)
    n_tiles = -(-P // tile_p)
    pad = n_tiles * tile_p - P
    if pad:
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    coeffs2 = coeffs.reshape(K, 1).astype(jnp.float32)

    out = pl.pallas_call(
        _fed_agg_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, tile_p), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, tile_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_tiles * tile_p), updates.dtype),
        interpret=interpret,
    )(coeffs2, updates)
    return out[0, :P]
