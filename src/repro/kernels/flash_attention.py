"""Pallas TPU kernel: block-tiled flash attention (online softmax).

Grid (B, H, nq, nk) — the kv dimension iterates innermost so the running
(max, sumexp, acc) state lives in VMEM scratch across kv steps.  Supports
causal masking, sliding windows (gemma local layers), logit soft-capping
(gemma2) and GQA via the k/v BlockSpec index map (q head h reads kv head
h // group).  Block shapes are MXU-aligned (q/kv tiles × head_dim).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: float, bq: int, bk: int, nk: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)

    s = q @ k.T                                          # (bq, bk) fp32
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = cols < seq_len
    if causal:
        mask &= rows >= cols
    if window:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]                                  # (bq, 1)
    l_prev = l_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = alpha * l_prev + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: float = 0.0, bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, H, S, d); k/v: (B, Hkv, S, d) with H % Hkv == 0 → (B, H, S, d).

    VMEM working set per grid step: q/k/v tiles (bq+2·bk)·d plus the
    (bq, d) fp32 accumulator — ≈ (128+256)·128·4B + 128·128·4B ≈ 260 KB,
    comfortably inside the ~16 MB v5e VMEM with MXU-aligned 128 tiles.
    """
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    assert H % Hkv == 0
    group = H // Hkv

    bq = min(bq, max(8, S))
    bk = min(bk, max(8, S))
    nq = -(-S // bq)
    nk = -(-S // bk)
    pad_q = nq * bq - S
    pad_k = nk * bk - S
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / (d ** 0.5), causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, nk=nk, seq_len=S)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S, :]
