"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ------------------------------------------------------------ fed_agg
def fed_agg_ref(updates: jnp.ndarray, coeffs: jnp.ndarray) -> jnp.ndarray:
    """Staleness-weighted aggregation (paper Eq. 3 inner sum).

    updates: (K, P) stacked flattened client updates;
    coeffs:  (K,)  staleness × cardinality weights.
    → (P,) aggregated parameter vector, accumulated in fp32.
    """
    acc = jnp.einsum("kp,k->p", updates.astype(jnp.float32),
                     coeffs.astype(jnp.float32))
    return acc.astype(updates.dtype)


def fed_agg_apply_ref(updates: jnp.ndarray, coeffs: jnp.ndarray,
                      params: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray,
                      lr, mix, b1, b2, eps, opt: str = "fedadam"):
    """Oracle for the fused server-update kernel (fed_agg_apply).

    Weighted sum → pseudo-gradient Δ = mix·(Σ c·W − w) → moment update →
    optimizer step, all in fp32.  Returns (out, m, v, ‖Δ‖₂).
    """
    s = jnp.einsum("kp,k->p", updates.astype(jnp.float32),
                   coeffs.astype(jnp.float32))
    g = params.astype(jnp.float32)
    delta = jnp.float32(mix) * (s - g)
    lr, b1, b2, eps = (jnp.float32(x) for x in (lr, b1, b2, eps))
    m = m.astype(jnp.float32)
    v = v.astype(jnp.float32)
    if opt in ("sgd", "fedavgm"):
        m = b1 * m + delta
        step = m
    else:
        m = b1 * m + (1.0 - b1) * delta
        dsq = delta * delta
        if opt == "fedadagrad":
            v = v + dsq
        elif opt == "fedadam":
            v = b2 * v + (1.0 - b2) * dsq
        elif opt == "fedyogi":
            v = v - (1.0 - b2) * dsq * jnp.sign(v - dsq)
        else:
            raise ValueError(f"unknown server opt {opt!r}")
        step = m / (jnp.sqrt(v) + eps)
    return g + lr * step, m, v, jnp.sqrt(jnp.sum(delta * delta))


# ------------------------------------------------------------ compress
def int8_encode_ref(x: jnp.ndarray, chunk: int = 256):
    """Per-chunk int8 quantization oracle: scale = absmax/127 (1.0 for
    all-zero chunks), q = round(x/scale) clipped to ±127.  Returns
    (q (n_chunks, chunk) int8, scale (n_chunks,) f32)."""
    P = x.shape[0]
    n_chunks = -(-P // chunk)
    xm = jnp.pad(x.astype(jnp.float32),
                 (0, n_chunks * chunk - P)).reshape(n_chunks, chunk)
    absmax = jnp.max(jnp.abs(xm), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xm / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale[:, 0]


def int8_decode_ref(q: jnp.ndarray, scale: jnp.ndarray,
                    length: int) -> jnp.ndarray:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[:, None]).reshape(-1)[:length]


def topk_ref(x: jnp.ndarray, k: int):
    """Dense top-k decode oracle via lax.top_k + scatter (lowest index
    wins on magnitude ties).  Returns (idx, vals, decoded)."""
    xf = x.astype(jnp.float32)
    P = xf.shape[0]
    k = min(k, P)
    _, idx = jax.lax.top_k(jnp.abs(xf), k)
    vals = xf[idx]
    decoded = jnp.zeros((P,), jnp.float32).at[idx].set(vals)
    return idx.astype(jnp.int32), vals, decoded


# ------------------------------------------------------------ attention
def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        window: Optional[int] = None,
                        softcap: float = 0.0) -> jnp.ndarray:
    """Reference attention. q: (B, H, S, d); k/v: (B, Hkv, S, d) (GQA:
    H % Hkv == 0).  fp32 softmax, optional sliding window + logit cap."""
    B, H, S, d = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, S, d)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qg, k) / jnp.sqrt(d)
    scores = scores.astype(jnp.float32)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= idx[:, None] >= idx[None, :]
    if window:
        mask &= (idx[:, None] - idx[None, :]) < window
    scores = jnp.where(mask, scores, -2.3819763e38)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, v)
    return out.reshape(B, H, S, d)


# ------------------------------------------------------------ ssd
def ssd_ref(x: jnp.ndarray, a_dt: jnp.ndarray, B: jnp.ndarray,
            C: jnp.ndarray) -> jnp.ndarray:
    """Sequential SSD recurrence (the ground truth the chunked forms must
    match).  x: (b, l, h, p) pre-scaled by dt; a_dt: (b, l, h);
    B, C: (b, l, h, n).  Returns y: (b, l, h, p)."""
    b, l, h, p = x.shape
    n = B.shape[-1]

    def step(state, inp):
        x_t, a_t, B_t, C_t = inp
        state = (state * jnp.exp(a_t)[..., None, None]
                 + x_t[..., :, None] * B_t[..., None, :])
        y_t = jnp.einsum("bhpn,bhn->bhp", state, C_t)
        return state, y_t

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(a_dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(B, 1, 0).astype(jnp.float32),
          jnp.moveaxis(C, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
