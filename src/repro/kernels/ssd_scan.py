"""Pallas TPU kernel: Mamba2 SSD chunked scan.

Grid (B, H, n_chunks) with the chunk dimension innermost — TPU grids run
sequentially, so the (P, N) inter-chunk state lives in VMEM scratch and
is carried across chunk steps (the Pallas analogue of the lax.scan in
models/ssm.py).  Each step computes the intra-chunk quadratic term as
masked matmuls (MXU) plus the decayed contribution of the carried state.

Layout: x (B, H, L, P), a_dt (B, H, L, 1), B/C (B, H, L, N), all blocked
along L by `chunk`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)            # (q, P)
    a = a_ref[0, 0][:, 0].astype(jnp.float32)      # (q,)
    Bm = b_ref[0, 0].astype(jnp.float32)           # (q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)           # (q, N)

    a_cum = jnp.cumsum(a)                           # (q,)
    ss = a_cum[:, None] - a_cum[None, :]            # segsum
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.exp(jnp.where(rows >= cols, ss, NEG))   # (q, q)

    scores = (Cm @ Bm.T) * L                        # (q, q)
    y_diag = scores @ x                             # (q, P)

    state = state_ref[...]                          # (P, N)
    y_off = jnp.exp(a_cum)[:, None] * (Cm @ state.T)   # (q, P)

    decay_out = jnp.exp(a_cum[-1] - a_cum)          # (q,)
    new_state = (jnp.exp(a_cum[-1]) * state
                 + x.T @ (Bm * decay_out[:, None]))  # (P, N)

    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)
    state_ref[...] = new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, a_dt: jnp.ndarray, B: jnp.ndarray,
             C: jnp.ndarray, chunk: int = 128,
             interpret: bool = True) -> jnp.ndarray:
    """Model layout in/out: x (b, l, h, p); a_dt (b, l, h); B/C (b, l, h, n)
    → y (b, l, h, p).  Matches kernels.ref.ssd_ref.

    VMEM per step: x/y chunks 2·(chunk·P) + B/C 2·(chunk·N) + state P·N +
    the (chunk, chunk) score tile — with chunk=128, P=64, N=128 ≈ 200 KB.
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    xt = jnp.moveaxis(x, 2, 1)                      # (b, h, l, p)
    at = jnp.moveaxis(a_dt, 2, 1)[..., None]        # (b, h, l, 1)
    Bt = jnp.moveaxis(B, 2, 1)
    Ct = jnp.moveaxis(C, 2, 1)

    chunk = min(chunk, l)
    nc = -(-l // chunk)
    pad = nc * chunk - l
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        at = jnp.pad(at, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Bt = jnp.pad(Bt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nc * chunk, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xt, at, Bt, Ct)
    return jnp.moveaxis(y[:, :, :l, :], 1, 2)
