"""jit'd public wrappers around the Pallas kernels.

Interpret mode is backend-aware by default: on CPU the kernels run with
interpret=True (the kernel body executes via the interpreter, validating
logic + BlockSpec tiling); on TPU they lower to Mosaic.  Override either
way with REPRO_PALLAS_INTERPRET=0/1 or the per-call `interpret` arg.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..analysis import gates
from .compress import int8_decode as _int8_decode
from .compress import int8_encode as _int8_encode
from .compress import topk_decode as topk_decode  # noqa: F401 (re-export)
from .compress import topk_encode as _topk_encode
from .compress import topk_mask as _topk_mask
from .fed_agg import fed_agg as _fed_agg
from .fed_agg import fed_agg_apply as _fed_agg_apply
from .fed_agg import fed_agg_apply_sharded as _fed_agg_apply_sharded
from .fed_agg import fed_agg_sharded as _fed_agg_sharded
from .flash_attention import flash_attention as _flash_attention
from .ssd_scan import ssd_scan as _ssd_scan

# read once at import (the compiled-call caches key on it); the
# three-state override lives in the central gate registry
_OVERRIDE = gates.pallas_interpret_override()
INTERPRET = (jax.default_backend() == "cpu" if _OVERRIDE is None
             else _OVERRIDE)


def fed_agg(updates: jnp.ndarray, coeffs: jnp.ndarray,
            tile_p: int = 2048,
            interpret: Optional[bool] = None,
            donate: bool = False) -> jnp.ndarray:
    return _fed_agg(updates, coeffs, tile_p=tile_p,
                    interpret=INTERPRET if interpret is None else interpret,
                    donate=donate)


def fed_agg_apply(updates: jnp.ndarray, coeffs: jnp.ndarray,
                  params: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray,
                  lr, mix, b1, b2, eps, *, opt: str = "fedadam",
                  tile_p: int = 2048, interpret: Optional[bool] = None,
                  donate: bool = False):
    return _fed_agg_apply(
        updates, coeffs, params, m, v, lr, mix, b1, b2, eps, opt=opt,
        tile_p=tile_p,
        interpret=INTERPRET if interpret is None else interpret,
        donate=donate)


def fed_agg_sharded(updates: jnp.ndarray, coeffs: jnp.ndarray, mesh,
                    tile_p: int = 2048,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    return _fed_agg_sharded(
        updates, coeffs, mesh, tile_p=tile_p,
        interpret=INTERPRET if interpret is None else interpret)


def fed_agg_apply_sharded(updates: jnp.ndarray, coeffs: jnp.ndarray,
                          params: jnp.ndarray, m: jnp.ndarray,
                          v: jnp.ndarray, lr, mix, b1, b2, eps, *,
                          opt: str = "fedadam", mesh, tile_p: int = 2048,
                          interpret: Optional[bool] = None):
    return _fed_agg_apply_sharded(
        updates, coeffs, params, m, v, lr, mix, b1, b2, eps, opt=opt,
        mesh=mesh, tile_p=tile_p,
        interpret=INTERPRET if interpret is None else interpret)


def int8_encode(x: jnp.ndarray, chunk: int = 256, tile_r: int = 8,
                interpret: Optional[bool] = None):
    return _int8_encode(x, chunk=chunk, tile_r=tile_r,
                        interpret=INTERPRET if interpret is None
                        else interpret)


def int8_decode(q: jnp.ndarray, scale: jnp.ndarray, length: int,
                tile_r: int = 8,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    return _int8_decode(q, scale, length, tile_r=tile_r,
                        interpret=INTERPRET if interpret is None
                        else interpret)


def topk_encode(x: jnp.ndarray, k: int, tile_p: int = 2048,
                interpret: Optional[bool] = None):
    return _topk_encode(x, k, tile_p=tile_p,
                        interpret=INTERPRET if interpret is None
                        else interpret)


def topk_mask(x: jnp.ndarray, tau, last_keep, tile_p: int = 2048,
              interpret: Optional[bool] = None) -> jnp.ndarray:
    return _topk_mask(x, tau, last_keep, tile_p=tile_p,
                      interpret=INTERPRET if interpret is None
                      else interpret)


def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None, softcap: float = 0.0,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    return _flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, bq=bq, bk=bk,
        interpret=INTERPRET if interpret is None else interpret)


def ssd_scan(x, a_dt, B, C, chunk: int = 128,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    return _ssd_scan(x, a_dt, B, C, chunk=chunk,
                     interpret=INTERPRET if interpret is None else interpret)
