"""jit'd public wrappers around the Pallas kernels.

Interpret mode is backend-aware by default: on CPU the kernels run with
interpret=True (the kernel body executes via the interpreter, validating
logic + BlockSpec tiling); on TPU they lower to Mosaic.  Override either
way with REPRO_PALLAS_INTERPRET=0/1 or the per-call `interpret` arg.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from .fed_agg import fed_agg as _fed_agg
from .fed_agg import fed_agg_apply as _fed_agg_apply
from .flash_attention import flash_attention as _flash_attention
from .ssd_scan import ssd_scan as _ssd_scan

_ENV = os.environ.get("REPRO_PALLAS_INTERPRET")
INTERPRET = (jax.default_backend() == "cpu" if _ENV is None
             else _ENV != "0")


def fed_agg(updates: jnp.ndarray, coeffs: jnp.ndarray,
            tile_p: int = 2048,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    return _fed_agg(updates, coeffs, tile_p=tile_p,
                    interpret=INTERPRET if interpret is None else interpret)


def fed_agg_apply(updates: jnp.ndarray, coeffs: jnp.ndarray,
                  params: jnp.ndarray, m: jnp.ndarray, v: jnp.ndarray,
                  lr, mix, b1, b2, eps, *, opt: str = "fedadam",
                  tile_p: int = 2048, interpret: Optional[bool] = None):
    return _fed_agg_apply(
        updates, coeffs, params, m, v, lr, mix, b1, b2, eps, opt=opt,
        tile_p=tile_p,
        interpret=INTERPRET if interpret is None else interpret)


def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None, softcap: float = 0.0,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    return _flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, bq=bq, bk=bk,
        interpret=INTERPRET if interpret is None else interpret)


def ssd_scan(x, a_dt, B, C, chunk: int = 128,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    return _ssd_scan(x, a_dt, B, C, chunk=chunk,
                     interpret=INTERPRET if interpret is None else interpret)
