"""Pallas TPU kernels (+ jnp oracles) for the perf-critical compute:

  flash_attention — block-tiled online-softmax attention
                    (causal / sliding-window / softcap / GQA)
  ssd_scan        — Mamba2 SSD chunked scan with VMEM-carried state
  fed_agg         — staleness-weighted federated aggregation (Eq. 3)
"""
from .ops import fed_agg, flash_attention, ssd_scan
from . import ref

__all__ = ["fed_agg", "flash_attention", "ssd_scan", "ref"]
