"""Pallas TPU kernels (+ jnp oracles) for the perf-critical compute:

  flash_attention — block-tiled online-softmax attention
                    (causal / sliding-window / softcap / GQA)
  ssd_scan        — Mamba2 SSD chunked scan with VMEM-carried state
  fed_agg         — staleness-weighted federated aggregation (Eq. 3)
  fed_agg_apply   — fused weighted-sum → pseudo-gradient → server-
                    optimizer moment update → apply (core/merge.py)
"""
from .fed_agg import APPLY_OPTS
from .ops import fed_agg, fed_agg_apply, flash_attention, ssd_scan
from . import ref

__all__ = ["APPLY_OPTS", "fed_agg", "fed_agg_apply", "flash_attention",
           "ssd_scan", "ref"]
