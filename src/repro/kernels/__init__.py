"""Pallas TPU kernels (+ jnp oracles) for the perf-critical compute:

  flash_attention — block-tiled online-softmax attention
                    (causal / sliding-window / softcap / GQA)
  ssd_scan        — Mamba2 SSD chunked scan with VMEM-carried state
  fed_agg         — staleness-weighted federated aggregation (Eq. 3)
  fed_agg_apply   — fused weighted-sum → pseudo-gradient → server-
                    optimizer moment update → apply (core/merge.py)
  *_sharded       — the same two under shard_map on a device mesh
                    (P dim split over every mesh axis)
  int8_*/topk_*   — client-update compression encode/decode pair
                    (per-chunk int8 quantization, top-k sparsification)
"""
from .compress import COMPRESS_SCHEMES
from .fed_agg import APPLY_OPTS
from .ops import (fed_agg, fed_agg_apply, fed_agg_apply_sharded,
                  fed_agg_sharded, flash_attention, int8_decode,
                  int8_encode, ssd_scan, topk_decode, topk_encode,
                  topk_mask)
from . import ref

__all__ = ["APPLY_OPTS", "COMPRESS_SCHEMES", "fed_agg", "fed_agg_apply",
           "fed_agg_apply_sharded", "fed_agg_sharded", "flash_attention",
           "int8_decode", "int8_encode", "ssd_scan", "topk_decode",
           "topk_encode", "topk_mask", "ref"]
