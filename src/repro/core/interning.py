"""Stable client-id ↔ index interning — the substrate of fleet scale.

Every per-client structure in the hot path (behavioural history,
scheduler score tallies, routing assignments) is a flat NumPy array
indexed by a *stable* integer id.  `ClientInterner` owns the mapping:
a client id is interned once, keeps its index forever (indices are
never reused or compacted), and the arrays hanging off the interner
grow geometrically alongside it.

`indices_for` is the per-call bridge from the driver's id sequences to
array indices.  Converting a million-entry pool to indices costs a
million dict lookups, so the result is memoized per pool *object*: the
training driver passes the same (immutable) population list every
propose, and the memo turns the conversion into an O(1) identity check.
Sequences must therefore not be mutated in place after being passed —
pass a fresh list when the pool composition changes (the drivers do).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


class ClientInterner:
    """Bidirectional client-id ↔ dense-index table with stable indices."""

    __slots__ = ("_index", "_ids", "_pool_cache", "_lex_cache")

    def __init__(self, ids: Optional[Iterable[str]] = None):
        self._index: Dict[str, int] = {}
        self._ids: List[str] = []
        # id(seq) -> (len(seq), size_at_cache, np.ndarray of indices)
        self._pool_cache: Dict[int, Tuple[int, int, np.ndarray]] = {}
        self._lex_cache: Optional[Tuple[int, np.ndarray]] = None
        if ids is not None:
            self.intern_many(ids)

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._ids)

    def __contains__(self, client_id: str) -> bool:
        return client_id in self._index

    @property
    def ids(self) -> List[str]:
        """All interned ids, index order (do not mutate)."""
        return self._ids

    def id_of(self, index: int) -> str:
        return self._ids[index]

    def index_of(self, client_id: str) -> int:
        """Index of an already-interned id (KeyError if unknown)."""
        return self._index[client_id]

    def lookup(self, client_id: str) -> int:
        """Index of `client_id`, or -1 when never interned."""
        return self._index.get(client_id, -1)

    # ------------------------------------------------------------------
    def intern(self, client_id: str) -> int:
        idx = self._index.get(client_id)
        if idx is None:
            idx = len(self._ids)
            self._index[client_id] = idx
            self._ids.append(client_id)
        return idx

    def intern_many(self, client_ids: Iterable[str]) -> np.ndarray:
        get = self._index.get
        out = np.empty(len(client_ids)
                       if hasattr(client_ids, "__len__") else 0, np.int64)
        if out.size:
            for i, cid in enumerate(client_ids):
                idx = get(cid)
                out[i] = self.intern(cid) if idx is None else idx
            return out
        return np.array([self.intern(c) for c in client_ids], np.int64)

    # ------------------------------------------------------------------
    def indices_for(self, client_ids: Sequence[str],
                    intern_missing: bool = True) -> np.ndarray:
        """Index array for a pool sequence, memoized on object identity.

        The memo entry is invalidated when the sequence's length changes
        (cheap guard against in-place mutation) and is only reused when
        no id in it could have been re-interned (indices are stable, so
        growth never invalidates existing entries).
        """
        key = id(client_ids)
        hit = self._pool_cache.get(key)
        if hit is not None and hit[0] == len(client_ids):
            return hit[2]
        if intern_missing:
            idx = self.intern_many(client_ids)
        else:
            get = self._index.get
            idx = np.array([get(c, -1) for c in client_ids], np.int64)
        if len(self._pool_cache) > 8:       # tiny LRU: drop everything
            self._pool_cache.clear()
        self._pool_cache[key] = (len(client_ids), len(self._ids), idx)
        return idx

    def lex_ranks(self) -> np.ndarray:
        """`ranks[i]` = rank of `ids[i]` in lexicographic id order.

        Because ids are unique, sorting by `(key, ranks[i])` is exactly
        sorting by `(key, client_id)` — but with pure integer keys, so
        the scheduler's cohort ordering stays `argpartition`-able at
        fleet scale.  Cached; rebuilt lazily after interner growth.
        """
        n = len(self._ids)
        if self._lex_cache is not None and self._lex_cache[0] == n:
            return self._lex_cache[1]
        order = np.argsort(np.array(self._ids))     # '<U*' array: C compares
        ranks = np.empty(n, np.int64)
        ranks[order] = np.arange(n, dtype=np.int64)
        self._lex_cache = (n, ranks)
        return ranks

    # ---- checkpoint surface ------------------------------------------
    def state_dict(self) -> dict:
        return {"ids": list(self._ids)}

    def load_state_dict(self, state: dict) -> None:
        self._ids = list(state.get("ids", []))
        self._index = {cid: i for i, cid in enumerate(self._ids)}
        self._pool_cache.clear()
        self._lex_cache = None


def grow_to(array: np.ndarray, n: int, fill=0) -> np.ndarray:
    """Return `array` with capacity ≥ n (geometric growth, `fill` for
    the new tail).  No-op when already large enough."""
    if array.shape[0] >= n:
        return array
    cap = max(n, 2 * array.shape[0], 16)
    out = np.full((cap, *array.shape[1:]), fill, dtype=array.dtype)
    out[:array.shape[0]] = array
    return out
