"""Device-resident round pipeline: the stacked (K, P) update batch.

Before this module, the vectorized executor's output took a scenic tour
of the host: each client's params were sliced out of the vmapped stack
one at a time (``tree_map(lambda l: l[k])``), packaged as K separate
pytrees, then immediately re-ravelled and re-stacked by the aggregation
layer before the Pallas ``fed_agg`` kernel saw them — 2·K full-model
reorderings per round that do zero useful work.

``DeviceUpdateBatch`` is the zero-copy alternative: the executor hands
over the *flattened* (K, P) matrix it already holds on device (plus the
``unravel`` handle to rebuild any single client's tree), and everything
downstream — ``ClientPool.package_update``, the event engine's per-round
work cache, ``UpdateCompressor`` (which reads rows directly), and the
``MergePipeline``/``fed_agg_apply`` dispatch — operates on rows of that
one matrix.  Per-client pytrees are materialized *lazily*, only when a
consumer genuinely needs tree structure (trace digests, the eager
``work_fn`` parity path, checkpointed in-flight updates).

The flattened layout is bit-for-bit the ``ravel_pytree`` layout, so a
merge over gathered rows is byte-identical to the legacy
materialize→ravel→stack path — only the redundant transforms disappear.

``REPRO_DEVICE_PIPELINE=0`` reverts every consumer to the legacy
per-client path (the kill switch mirrors ``REPRO_AGG_KERNEL``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..analysis import gates

Pytree = Any


def pipeline_enabled() -> bool:
    """The device-pipeline kill switch (checked at call time, so tests
    can flip it per-case)."""
    return gates.device_pipeline_enabled()


# ----------------------------------------------------------------------
# host-transfer accounting — the benchmark's churn metric.  Counts bytes
# that cross the executor→merge boundary as *per-client* materializations
# (row unravels / full-tree rebuilds); the device pipeline's claim is
# that the dense path drops from 2·K·model-size to ≤ 1·model-size.
# ----------------------------------------------------------------------
_TRANSFER = {"materialize_bytes": 0, "materialize_rows": 0,
             "loss_syncs": 0}


def transfer_stats() -> Dict[str, int]:
    return dict(_TRANSFER)


def reset_transfer_stats() -> None:
    for k in _TRANSFER:
        _TRANSFER[k] = 0


def count_materialization(nbytes: int, rows: int = 1) -> None:
    _TRANSFER["materialize_bytes"] += int(nbytes)
    _TRANSFER["materialize_rows"] += int(rows)


def count_loss_sync() -> None:
    _TRANSFER["loss_syncs"] += 1


class DeviceUpdateBatch:
    """One executor group's trained updates as a device-resident matrix.

    * ``mat`` — (K_bucket, P) flat update matrix (rows beyond
      ``len(cids)`` are vmap-bucket padding and are never addressed);
    * ``cids`` — the real clients, row i of ``mat`` belongs to
      ``cids[i]``;
    * ``unravel`` — the ``ravel_pytree`` inverse for one row (shared by
      every client of the group: same model structure);
    * ``losses`` — (K_bucket,) per-client mean training loss, fetched
      host-side with ONE ``np.asarray`` on first access instead of K
      blocking per-scalar transfers.

    Rows can be *replaced* (``set_row``) — the compression stage swaps a
    row for its server-side decode w + decode(encode(δ)) without ever
    building the per-client pytree.  ``gather`` assembles the merge
    matrix for any subset of rows as a fresh device array (safe to
    donate to the aggregation kernel).
    """

    def __init__(self, mat: jnp.ndarray, cids: Sequence[str],
                 unravel: Callable[[jnp.ndarray], Pytree],
                 losses: Optional[jnp.ndarray] = None):
        if mat.ndim != 2 or mat.shape[0] < len(cids):
            raise ValueError(f"update matrix {mat.shape} cannot hold "
                             f"{len(cids)} client rows")
        self.mat = mat
        self.cids = tuple(cids)
        self.unravel = unravel
        self._losses = losses
        self._losses_np: Optional[np.ndarray] = None
        self._row_override: Dict[int, jnp.ndarray] = {}
        self._trees: Dict[int, Pytree] = {}

    # ------------------------------------------------------------------
    @property
    def num_clients(self) -> int:
        return len(self.cids)

    @property
    def num_params(self) -> int:
        return int(self.mat.shape[1])

    def row(self, i: int) -> jnp.ndarray:
        """Client i's flat (P,) update vector (stays on device)."""
        if not 0 <= i < len(self.cids):
            raise IndexError(f"row {i} out of range for "
                             f"{len(self.cids)} clients")
        override = self._row_override.get(i)
        return override if override is not None else self.mat[i]

    def set_row(self, i: int, flat: jnp.ndarray) -> None:
        """Replace client i's update (compression decode) in place —
        consumers that already materialized the old tree are invalidated."""
        if flat.shape != (self.mat.shape[1],):
            raise ValueError(f"row shape {flat.shape} != "
                             f"({self.mat.shape[1]},)")
        self._row_override[i] = flat
        self._trees.pop(i, None)

    def gather(self, rows: Sequence[int]) -> jnp.ndarray:
        """(len(rows), P) merge matrix — always a fresh device array
        (never an alias of ``mat``), so callers may donate it."""
        rows = list(rows)
        if self._row_override and any(r in self._row_override
                                      for r in rows):
            return jnp.stack([self.row(r) for r in rows])
        return jnp.take(self.mat, jnp.asarray(rows, dtype=jnp.int32),
                        axis=0)

    def tree(self, i: int) -> Pytree:
        """Materialize client i's pytree (lazy; cached per row).  This is
        the only point where per-client structure is rebuilt — trace
        digests, the eager parity path, and checkpointed in-flight
        updates all funnel through here."""
        tree = self._trees.get(i)
        if tree is None:
            flat = self.row(i)
            tree = self.unravel(flat)
            self._trees[i] = tree
            count_materialization(flat.size * flat.dtype.itemsize)
        return tree

    def loss(self, i: int) -> float:
        """Client i's mean training loss — the whole loss vector crosses
        the device boundary once, on first access."""
        if self._losses is None:
            return 0.0
        if self._losses_np is None:
            self._losses_np = np.asarray(self._losses)
            count_loss_sync()
        return float(self._losses_np[i])
