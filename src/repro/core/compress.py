"""Client-update compression with error feedback (the orchestrator).

The kernels (kernels/compress.py) work on flat vectors; this module owns
the FL semantics around them:

* compression acts on the client's *delta* W_k − w, not the raw weights —
  the server reconstructs W̃_k = w + decode(encode(δ_k)), so every
  downstream merge (Eq. 3 staleness weights, the delta MergePipeline and
  its server optimizers) consumes an ordinary ClientUpdate and stays
  parity-correct against Reddi et al. (arXiv:2003.00295);
* **error feedback** keeps a per-client residual: the input to the
  encoder is δ_k + r_k and the new residual is what the encoder dropped,
  r_k' = (δ_k + r_k) − decode(·).  Compression error therefore
  telescopes instead of accumulating — the classic EF-SGD guarantee that
  makes aggressive top-k ratios converge;
* residual pytrees ride the v2 checkpoint array store exactly like the
  server optimizer's moments do (``compress/residual/<cid>`` keys,
  model-params tree structure, fp32-forced on load).

``REPRO_COMPRESS=0`` disables encoding at runtime regardless of config —
the kill switch mirrors ``REPRO_AGG_KERNEL``; the ``none`` scheme (the
default) never touches the update, keeping dense runs byte-identical to
pre-compression builds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from ..analysis import gates

Pytree = Any

SCHEMES = ("none", "topk", "int8")

# simulated wire-format costs (bytes)
_FP32 = 4            # dense value
_TOPK_ENTRY = 8      # int32 index + fp32 value per kept coordinate
_INT8_CODE = 1       # one code byte per parameter
_CHUNK_SCALE = 4     # one fp32 scale per chunk


@dataclass(frozen=True)
class CompressionConfig:
    """Which encoder the client path runs, and how hard it squeezes.

    topk_ratio is the kept fraction (0.01 → top-k@1%, a 50× byte cut at
    8 bytes/entry vs 4 bytes/param dense); chunk is the int8 scale
    granularity (256 params/scale ≈ 1.016 bytes/param on the wire).
    """
    scheme: str = "none"
    topk_ratio: float = 0.01
    chunk: int = 256
    error_feedback: bool = True

    def normalized(self) -> "CompressionConfig":
        if self.scheme not in SCHEMES:
            raise ValueError(f"unknown compression scheme {self.scheme!r}; "
                             f"available: {SCHEMES}")
        if self.scheme == "topk" and not (0.0 < self.topk_ratio <= 1.0):
            raise ValueError(f"topk_ratio must be in (0, 1], "
                             f"got {self.topk_ratio}")
        if self.scheme == "int8" and self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        return self

    @property
    def active(self) -> bool:
        """True when encoding actually runs (scheme set + env not 0)."""
        return self.scheme != "none" and gates.compress_enabled()


class UpdateCompressor:
    """Stateful client-side encoder: per-client error-feedback residuals
    plus the payload-byte arithmetic the simulation bills."""

    def __init__(self, config: Optional[CompressionConfig] = None):
        self.config = (config or CompressionConfig()).normalized()
        # cid -> flat fp32 residual (the coordinates the encoder dropped)
        self._residuals: Dict[str, jnp.ndarray] = {}
        self._unravel32 = None      # cached f32 unravel (model structure)
        # (global_params tree, its flat f32 view) — the global model is
        # one object per round, so K clients share one ravel
        self._flat_g: Optional[Tuple[Pytree, jnp.ndarray]] = None

    # ------------------------------------------------------------------
    def _flat_global(self, global_params: Pytree) -> jnp.ndarray:
        cached = self._flat_g
        if cached is not None and cached[0] is global_params:
            return cached[1]
        flat_g = ravel_pytree(global_params)[0].astype(jnp.float32)
        self._flat_g = (global_params, flat_g)
        return flat_g

    def _ensure_unravel32(self, global_params: Pytree) -> None:
        if self._unravel32 is None:
            _, self._unravel32 = ravel_pytree(
                jax.tree_util.tree_map(
                    lambda l: jnp.zeros(jnp.shape(l), jnp.float32),
                    global_params))

    def _encode_core(self, client_id: str, flat_u32: jnp.ndarray,
                     flat_g: jnp.ndarray):
        """Shared EF encode on flat fp32 vectors: returns the decoded
        delta plus the wire-byte arithmetic, updating the residual."""
        from ..kernels import int8_decode, int8_encode, topk_encode

        P = int(flat_u32.shape[0])
        dense_bytes = P * _FP32
        delta = flat_u32 - flat_g
        residual = self._residuals.get(client_id)
        if self.config.error_feedback and residual is not None:
            inp = delta + residual
        else:
            inp = delta

        if self.config.scheme == "topk":
            k = max(1, min(P, int(round(P * self.config.topk_ratio))))
            _, _, decoded = topk_encode(inp, k)
            payload_bytes = k * _TOPK_ENTRY
        else:                                                   # int8
            q, scale = int8_encode(inp, chunk=self.config.chunk)
            decoded = int8_decode(q, scale, P)
            payload_bytes = (P * _INT8_CODE
                             + int(q.shape[0]) * _CHUNK_SCALE)

        if self.config.error_feedback:
            self._residuals[client_id] = inp - decoded
        return decoded, payload_bytes, dense_bytes

    def encode(self, client_id: str, params: Pytree, global_params: Pytree
               ) -> Tuple[Pytree, Optional[int], Optional[int]]:
        """Compress one client update against the round's global model.

        Returns ``(reconstructed_params, payload_bytes, dense_bytes)`` —
        the reconstruction is the server-side decode W̃ = w + decode(δ̃),
        i.e. exactly what a real server would hold after receiving the
        encoded wire payload.  Inactive config → the update passes
        through untouched with (None, None) byte counts.
        """
        if not self.config.active:
            return params, None, None
        flat_u, unravel = ravel_pytree(params)
        flat_g = self._flat_global(global_params)
        if flat_u.shape != flat_g.shape:
            raise ValueError(
                f"update ravels to {flat_u.shape[0]} params, global model "
                f"to {flat_g.shape[0]} — cannot compress the delta")
        decoded, payload_bytes, dense_bytes = self._encode_core(
            client_id, flat_u.astype(jnp.float32), flat_g)
        self._ensure_unravel32(global_params)
        recon = unravel((flat_g + decoded).astype(flat_u.dtype))
        return recon, payload_bytes, dense_bytes

    def encode_flat(self, client_id: str, flat_u: jnp.ndarray,
                    global_params: Pytree
                    ) -> Tuple[jnp.ndarray, Optional[int], Optional[int]]:
        """``encode`` for one row of a ``DeviceUpdateBatch`` — the update
        never leaves its flat layout (no per-client unflatten/re-ravel).

        Returns ``(reconstructed_flat_row, payload_bytes, dense_bytes)``;
        the row is bitwise the ravel of what ``encode`` would return,
        since ``ravel(unravel(x)) == x`` in the promoted flat dtype.
        """
        if not self.config.active:
            return flat_u, None, None
        flat_g = self._flat_global(global_params)
        if flat_u.shape != flat_g.shape:
            raise ValueError(
                f"update row has {flat_u.shape[0]} params, global model "
                f"ravels to {flat_g.shape[0]} — cannot compress the delta")
        decoded, payload_bytes, dense_bytes = self._encode_core(
            client_id, flat_u.astype(jnp.float32), flat_g)
        self._ensure_unravel32(global_params)
        return ((flat_g + decoded).astype(flat_u.dtype),
                payload_bytes, dense_bytes)

    # ---- checkpoint surface (fl/checkpointing.py) --------------------
    def state_dict(self, arrays: Optional[dict] = None) -> dict:
        """Residuals go into `arrays` as model-structured fp32 pytrees
        (``compress/residual/<cid>``) — the same array-store contract as
        the merge pipeline's server-opt moments."""
        arrays = {} if arrays is None else arrays
        cids = sorted(self._residuals)
        for cid in cids:
            arrays[f"compress/residual/{cid}"] = self._unravel32(
                self._residuals[cid])
        return {"scheme": self.config.scheme, "clients": cids}

    def load_state_dict(self, state: dict,
                        arrays: Optional[dict] = None) -> None:
        """Missing residual state restores as a fresh encoder (residuals
        re-accumulate from the resume point — same migration contract as
        the server optimizer's moments)."""
        arrays = {} if arrays is None else arrays
        if not state:
            return
        scheme = state.get("scheme")
        if scheme is not None and scheme != self.config.scheme:
            raise ValueError(f"checkpoint was written with compression "
                             f"scheme {scheme!r}, run uses "
                             f"{self.config.scheme!r}")
        self._residuals = {}
        for cid in state.get("clients", []):
            tree = arrays[f"compress/residual/{cid}"]
            flat, unravel32 = ravel_pytree(
                jax.tree_util.tree_map(
                    lambda l: jnp.asarray(l, jnp.float32), tree))
            self._residuals[cid] = flat
            if self._unravel32 is None:
                self._unravel32 = unravel32
