"""Training strategies: FedAvg, FedProx, FedLesScan, SAFA, FedAsync, FedBuff.

A Strategy owns (a) a `Scheduler` (fl/scheduler.py) that makes its
client-picking decisions — `Strategy.select` is a compatibility shim
delegating to it, and the training driver consumes the scheduler
directly — (b) the aggregation scheme, and (c) an optional client-side
loss hook (FedProx's proximal term).  The training driver
(fl/controller.py) is strategy-agnostic — this is the paper's `Strategy
Manager` component (§IV-A).

`Strategy.on_client_finish` is the single update-delivery path for every
training mode: the driver calls it whenever a client's update physically
arrives (at its true virtual time).  Barrier strategies return None and
aggregate at round close; barrier-free strategies (`barrier_free = True`)
may return a *new global model* from the hook itself — FedAsync merges
every arrival immediately with a staleness-damped mixing weight, FedBuff
flushes a size-K buffer.

Every merge — barrier round closes included — runs through the shared
delta-based `MergePipeline` (core/merge.py): the strategy supplies the
weighted-sum coefficients and a mixing rate, the pipeline forms the
pseudo-gradient against the current global model and applies it through
the configured server optimizer (`StrategyConfig.server_opt`: plain
server-SGD by default — byte-identical to the historical replace-with-
average — or FedAvgM / FedAdagrad / FedAdam / FedYogi with fp32 server
moments and the fused Pallas `fed_agg_apply` kernel).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from .aggregation import (ClientUpdate, UpdateStore, fedavg_coefficients,
                          staleness_coefficients, update_from_record,
                          update_to_record)
from .history import ClientHistoryDB
from .merge import MergePipeline, ServerOptConfig
from .selection import SelectionPlan

Pytree = Any


@dataclass
class StrategyConfig:
    clients_per_round: int = 10
    max_rounds: int = 50
    tau: int = 2                  # staleness cutoff (FedLesScan, paper §V-D)
    ema_alpha: float = 0.5
    fedprox_mu: float = 0.001     # proximal coefficient (FedProx)
    # barrier-free (async) strategies
    buffer_k: int = 4             # FedBuff aggregation buffer size
    async_alpha: float = 0.6      # FedAsync base mixing rate
    server_lr: float = 0.7        # FedBuff server rate: flush = (1-η)·global
                                  # + η·buffer average (η=1 → pure average)
    staleness_exponent: float = 0.5   # polynomial staleness damping a:
                                  # weight ∝ (staleness+1)^(-a)
    # server optimizer on the merge pipeline (core/merge.py): the default
    # identity (sgd, lr=1, no momentum) replaces the model with the
    # weighted average byte-identically to the pre-pipeline behaviour
    server_opt: str = "sgd"       # sgd|fedavgm|fedadagrad|fedadam|fedyogi
    server_opt_lr: float = 1.0
    server_opt_momentum: float = 0.0  # heavy-ball β (fedavgm defaults 0.9)
    server_opt_b1: float = 0.9
    server_opt_b2: float = 0.99
    server_opt_eps: float = 1e-3

    def server_opt_config(self) -> ServerOptConfig:
        return ServerOptConfig(
            name=self.server_opt, lr=self.server_opt_lr,
            momentum=self.server_opt_momentum, b1=self.server_opt_b1,
            b2=self.server_opt_b2, eps=self.server_opt_eps)


class Strategy:
    """Base class. Subclasses override selection/aggregation behaviour."""

    name = "base"
    uses_history = False          # does selection read behavioural data?
    semi_async = False            # accept late updates into later rounds?
    barrier_free = False          # merge on arrival (no round barrier)?

    def __init__(self, config: StrategyConfig, history: ClientHistoryDB,
                 seed: int = 0):
        self.config = config
        self.history = history
        self.rng = np.random.default_rng(seed)
        self.update_store = UpdateStore(tau=config.tau)
        self.last_plan: Optional[SelectionPlan] = None
        self.last_aggregate_count = 0   # updates actually merged last round
        # every strategy owns a Scheduler (fl/scheduler.py): the training
        # driver consumes it directly, and `select` delegates to it so
        # pre-scheduler call sites keep their exact behaviour (the
        # scheduler shares `self.rng`, preserving the sampling stream)
        self.scheduler = self._default_scheduler()
        # ... and a MergePipeline (core/merge.py): the single server-side
        # merge path for every aggregation this strategy performs
        self.merger = MergePipeline(config.server_opt_config())

    # ---- selection ------------------------------------------------------
    def _default_scheduler(self):
        # local import: core must stay importable before repro.fl loads
        from ..fl.scheduler import RandomScheduler
        return RandomScheduler(self.config.clients_per_round, rng=self.rng)

    def select(self, client_ids: Sequence[str], round_number: int) -> List[str]:
        """Compatibility shim: delegate to the strategy's scheduler."""
        want = self.scheduler.cohort_size(round_number, ())
        selected = self.scheduler.propose(client_ids, want, 0.0, round_number)
        self.last_plan = getattr(self.scheduler, "last_plan", None)
        return selected

    # ---- event hooks (controller is an event consumer) ------------------
    def on_client_finish(self, update: Optional[ClientUpdate],
                         arrival_time: float, producing_round: int,
                         current_round: int,
                         global_params: Optional[Pytree] = None
                         ) -> Optional[Pytree]:
        """A client's update physically arrived at `arrival_time` (virtual).

        This is the single delivery path for every training mode.  In
        barrier modes, same-round arrivals are collected by the driver and
        passed to `aggregate` at round close; an arrival from an *earlier*
        round is a straggler's update landing mid-flight — semi-async
        strategies cache it at its true arrival time, synchronous ones
        discard it.  In barrier-free (async) mode the driver additionally
        passes the current `global_params` and `producing_round`/
        `current_round` are *model versions*: a barrier-free strategy may
        return a new global model immediately (FedAsync) or after its
        buffer fills (FedBuff).  Returning None keeps the current model.
        """
        if (self.semi_async and update is not None
                and producing_round < current_round):
            self.accept_late_update(update, arrival_time=arrival_time)
        return None

    def on_round_close(self, round_number: int,
                       now: Optional[float] = None) -> None:
        """Called at the round's close time, before aggregation."""

    def finalize(self, global_params: Pytree,
                 current_round: int) -> Optional[Pytree]:
        """End of a barrier-free run: flush any partially-buffered state
        into a last global model (or None to keep the current one)."""
        return None

    def _staleness_merge(self, updates: Sequence[ClientUpdate],
                         round_number: int, now: Optional[float],
                         global_params: Optional[Pytree] = None
                         ) -> Optional[Pytree]:
        """Shared semi-async aggregation body: merge the round's in-time
        updates with cached late updates that have arrived by `now`
        (pop_for_round already enforces the τ cutoff), apply Eq. 3
        through the merge pipeline."""
        pending = self.update_store.pop_for_round(round_number, now)
        merged = list(updates) + pending
        self.last_aggregate_count = len(merged)
        fresh = [u for u in merged
                 if (round_number - u.round_number) < self.config.tau]
        if not fresh:
            # zero-update merge: the pipeline keeps the model unchanged
            return self.merger.merge(global_params, [], ())
        return self.merger.merge(global_params, fresh,
                                 staleness_coefficients(fresh, round_number))

    def accept_late_update(self, update: ClientUpdate,
                           arrival_time: float = 0.0) -> None:
        """Semi-async path: a straggler finished after its round closed;
        its update is cached and dampened into a later aggregation."""
        self.update_store.push(update, arrival_time)

    # ---- aggregation ----------------------------------------------------
    def aggregate(self, updates: Sequence[ClientUpdate], round_number: int,
                  now: Optional[float] = None,
                  global_params: Optional[Pytree] = None
                  ) -> Optional[Pytree]:
        """Return the new global model, or the unchanged `global_params`
        (None when the caller didn't pass them) on an empty merge."""
        self.last_aggregate_count = len(updates)
        if not updates:
            return self.merger.merge(global_params, [], ())
        return self.merger.merge(global_params, list(updates),
                                 fedavg_coefficients(updates))

    # ---- client-side hooks ----------------------------------------------
    def proximal_mu(self) -> float:
        """FedProx adds mu/2 ||w - w_global||^2 to the local loss; other
        strategies return 0.0 (no-op)."""
        return 0.0

    # ---- checkpoint surface (fl/checkpointing.py) -----------------------
    def state_dict(self, arrays: Optional[dict] = None) -> dict:
        """JSON-ready snapshot of the strategy's mutable state: the RNG
        stream, the last merge count, and the semi-async update store's
        pending (arrived-but-unmerged / still-in-flight) updates.  Update
        pytrees are deposited into `arrays` under ``strategy/...`` keys
        (they share the global model's tree structure) and saved next to
        the checkpoint params."""
        arrays = {} if arrays is None else arrays
        return {"rng": self.rng.bit_generator.state,
                "last_aggregate_count": self.last_aggregate_count,
                "pending": self.update_store.state_dict(arrays),
                "merger": self.merger.state_dict(arrays)}

    def load_state_dict(self, state: dict,
                        arrays: Optional[dict] = None) -> None:
        arrays = {} if arrays is None else arrays
        if "rng" in state:
            self.rng.bit_generator.state = state["rng"]
        self.last_aggregate_count = int(state.get("last_aggregate_count", 0))
        self.update_store.load_state_dict(state.get("pending", []), arrays)
        # absent in moment-free (pre-pipeline) checkpoints: the optimizer
        # restores fresh and moments re-accumulate from the resume point
        self.merger.load_state_dict(state.get("merger", {}), arrays)


class FedAvg(Strategy):
    """McMahan et al. — random selection (RandomScheduler) +
    cardinality-weighted averaging.  Synchronous: late updates are
    discarded."""

    name = "fedavg"


class FedProx(FedAvg):
    """Sahu/Li et al. — FedAvg + proximal term in the client loss.
    Selection remains random (the paper notes this makes it straggler-
    sensitive)."""

    name = "fedprox"

    def proximal_mu(self) -> float:
        return self.config.fedprox_mu


class FedLesScan(Strategy):
    """The paper's strategy: tiered clustering-based selection (Alg. 2)
    + staleness-aware aggregation (Eq. 3) over a semi-async update store."""

    name = "fedlesscan"
    uses_history = True
    semi_async = True

    def _default_scheduler(self):
        from ..fl.scheduler import FedLesScanScheduler
        return FedLesScanScheduler(
            self.config.clients_per_round, self.history,
            max_rounds=self.config.max_rounds,
            ema_alpha=self.config.ema_alpha, rng=self.rng)

    def aggregate(self, updates, round_number, now=None,
                  global_params=None):
        # include late updates from previous rounds that have ARRIVED by
        # now (in-flight ones stay queued; aged-out ones are dropped)
        return self._staleness_merge(updates, round_number, now,
                                     global_params)


class SAFA(Strategy):
    """Wu et al. [26] — the semi-asynchronous competitor the paper
    contrasts with (§III-B): invoke ALL clients every round, close the
    round at the k-th fastest response (k = clients_per_round), cache
    slower responses for subsequent rounds.  Communication/invocation
    cost is deliberately high — that's the trade-off the paper calls out.
    """

    name = "safa"
    semi_async = True
    invoke_all = True                 # controller invokes every client

    @property
    def quorum(self) -> int:
        return self.config.clients_per_round

    def _default_scheduler(self):
        from ..fl.scheduler import FullPoolScheduler
        return FullPoolScheduler(self.config.clients_per_round, rng=self.rng)

    def aggregate(self, updates, round_number, now=None,
                  global_params=None):
        return self._staleness_merge(updates, round_number, now,
                                     global_params)


def _staleness_weight(staleness: int, exponent: float) -> float:
    """Polynomial staleness damping (Xie et al., FedAsync): an update
    trained `staleness` model versions ago gets weight (s+1)^(-a)."""
    return float(staleness + 1) ** (-exponent)


class FedAsync(Strategy):
    """Xie et al. (arXiv:1903.03934) — fully-asynchronous FL: every
    arriving update is merged into the global model *immediately*,

        w ← (1 − α_s) · w + α_s · w_k,   α_s = α · (s+1)^(-a)

    where s is the update's staleness in model versions.  Barrier-free:
    requires the driver's async mode (the flwr-serverless regime,
    arXiv:2310.15329)."""

    name = "fedasync"
    barrier_free = True

    def on_client_finish(self, update, arrival_time, producing_round,
                         current_round, global_params=None):
        if update is None or global_params is None:
            return super().on_client_finish(
                update, arrival_time, producing_round, current_round)
        staleness = max(0, current_round - producing_round)
        alpha = (self.config.async_alpha
                 * _staleness_weight(staleness, self.config.staleness_exponent))
        self.last_aggregate_count = 1
        # merge pipeline with mix=α_s: identity server-opt folds the
        # global model in as the (1−α) anchor of one weighted sum
        return self.merger.merge(global_params, [update],
                                 np.array([1.0], dtype=np.float64),
                                 mix=alpha)


class FedBuff(Strategy):
    """Nguyen et al. (arXiv:2106.06639) — buffered asynchronous
    aggregation: arrivals accumulate in a size-K buffer; when it fills,
    the new global model is (1−η)·global + η·(staleness- and
    cardinality-weighted buffer average), computed as one weighted sum
    over the anchor + K buffered updates through the Pallas `fed_agg`
    fast path, and the buffer is cleared.  Barrier-free."""

    name = "fedbuff"
    barrier_free = True

    def __init__(self, config: StrategyConfig, history: ClientHistoryDB,
                 seed: int = 0):
        super().__init__(config, history, seed=seed)
        self._buffer: List[Tuple[int, ClientUpdate]] = []  # (staleness base)

    def _flush(self, global_params: Pytree,
               current_round: int) -> Pytree:
        eta = self.config.server_lr
        weights = np.array(
            [u.num_samples * _staleness_weight(
                max(0, current_round - produced),
                self.config.staleness_exponent)
             for produced, u in self._buffer], dtype=np.float64)
        total = weights.sum() or 1.0
        # pipeline with mix=η: identity server-opt reproduces the classic
        # (1−η)·global + η·buffer-average as one anchored weighted sum
        merged = self.merger.merge(global_params,
                                   [u for _, u in self._buffer],
                                   weights / total, mix=eta)
        self.last_aggregate_count = len(self._buffer)
        self._buffer.clear()
        return merged

    def on_client_finish(self, update, arrival_time, producing_round,
                         current_round, global_params=None):
        if update is None or global_params is None:
            return super().on_client_finish(
                update, arrival_time, producing_round, current_round)
        self._buffer.append((producing_round, update))
        if len(self._buffer) < self.config.buffer_k:
            return None
        return self._flush(global_params, current_round)

    def finalize(self, global_params, current_round):
        """Flush the trailing partial buffer so delivered-but-unmerged
        updates still reach the final global model."""
        if not self._buffer:
            return None
        return self._flush(global_params, current_round)

    def state_dict(self, arrays=None):
        """FedBuff's partial buffer is checkpoint state: an async snapshot
        can land with 0 < len(buffer) < K delivered-but-unmerged updates."""
        arrays = {} if arrays is None else arrays
        state = super().state_dict(arrays)
        buffered = []
        for i, (produced, u) in enumerate(self._buffer):
            arrays[f"strategy/buffer/{i}"] = u.params
            rec = update_to_record(u)
            rec["produced"] = produced
            buffered.append(rec)
        state["buffer"] = buffered
        return state

    def load_state_dict(self, state, arrays=None):
        arrays = {} if arrays is None else arrays
        super().load_state_dict(state, arrays)
        self._buffer = [
            (int(rec["produced"]),
             update_from_record(rec, arrays[f"strategy/buffer/{i}"]))
            for i, rec in enumerate(state.get("buffer", []))]


STRATEGIES = {cls.name: cls
              for cls in (FedAvg, FedProx, FedLesScan, SAFA,
                          FedAsync, FedBuff)}


def make_strategy(name: str, config: StrategyConfig,
                  history: ClientHistoryDB, seed: int = 0) -> Strategy:
    try:
        return STRATEGIES[name](config, history, seed=seed)
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"available: {sorted(STRATEGIES)}") from None
