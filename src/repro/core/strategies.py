"""Training strategies: FedAvg, FedProx, FedLesScan.

A Strategy owns (a) client selection for a round, (b) the aggregation
scheme, and (c) an optional client-side loss hook (FedProx's proximal
term).  The controller (fl/controller.py) is strategy-agnostic — this is
the paper's `Strategy Manager` component (§IV-A).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence

import numpy as np

from .aggregation import (ClientUpdate, UpdateStore, fedavg_aggregate,
                          staleness_aggregate)
from .history import ClientHistoryDB
from .selection import SelectionPlan, select_clients, select_random

Pytree = Any


@dataclass
class StrategyConfig:
    clients_per_round: int = 10
    max_rounds: int = 50
    tau: int = 2                  # staleness cutoff (FedLesScan, paper §V-D)
    ema_alpha: float = 0.5
    fedprox_mu: float = 0.001     # proximal coefficient (FedProx)


class Strategy:
    """Base class. Subclasses override selection/aggregation behaviour."""

    name = "base"
    uses_history = False          # does selection read behavioural data?
    semi_async = False            # accept late updates into later rounds?

    def __init__(self, config: StrategyConfig, history: ClientHistoryDB,
                 seed: int = 0):
        self.config = config
        self.history = history
        self.rng = np.random.default_rng(seed)
        self.update_store = UpdateStore(tau=config.tau)
        self.last_plan: Optional[SelectionPlan] = None
        self.last_aggregate_count = 0   # updates actually merged last round

    # ---- selection ------------------------------------------------------
    def select(self, client_ids: Sequence[str], round_number: int) -> List[str]:
        raise NotImplementedError

    # ---- event hooks (controller is an event consumer) ------------------
    def on_client_finish(self, update: Optional[ClientUpdate],
                         arrival_time: float, producing_round: int,
                         current_round: int) -> None:
        """A client's update physically arrived at `arrival_time` (virtual).

        Same-round arrivals are collected by the controller and passed to
        `aggregate` at round close; an arrival from an *earlier* round is a
        straggler's update landing mid-flight — semi-async strategies cache
        it at its true arrival time, synchronous ones discard it.
        """
        if (self.semi_async and update is not None
                and producing_round < current_round):
            self.accept_late_update(update, arrival_time=arrival_time)

    def on_round_close(self, round_number: int,
                       now: Optional[float] = None) -> None:
        """Called at the round's close time, before aggregation."""

    def _staleness_merge(self, updates: Sequence[ClientUpdate],
                         round_number: int,
                         now: Optional[float]) -> Optional[Pytree]:
        """Shared semi-async aggregation body: merge the round's in-time
        updates with cached late updates that have arrived by `now`
        (pop_for_round already enforces the τ cutoff), apply Eq. 3."""
        pending = self.update_store.pop_for_round(round_number, now)
        merged = list(updates) + pending
        self.last_aggregate_count = len(merged)
        if not merged:
            return None
        return staleness_aggregate(merged, round_number,
                                   tau=self.config.tau)

    def accept_late_update(self, update: ClientUpdate,
                           arrival_time: float = 0.0) -> None:
        """Semi-async path: a straggler finished after its round closed;
        its update is cached and dampened into a later aggregation."""
        self.update_store.push(update, arrival_time)

    # ---- aggregation ----------------------------------------------------
    def aggregate(self, updates: Sequence[ClientUpdate], round_number: int,
                  now: Optional[float] = None) -> Optional[Pytree]:
        """Return the new global model or None (keep previous)."""
        self.last_aggregate_count = len(updates)
        if not updates:
            return None
        return fedavg_aggregate(list(updates))

    # ---- client-side hooks ----------------------------------------------
    def proximal_mu(self) -> float:
        """FedProx adds mu/2 ||w - w_global||^2 to the local loss; other
        strategies return 0.0 (no-op)."""
        return 0.0


class FedAvg(Strategy):
    """McMahan et al. — random selection + cardinality-weighted averaging.
    Synchronous: late updates are discarded."""

    name = "fedavg"

    def select(self, client_ids, round_number):
        return select_random(client_ids, self.config.clients_per_round,
                             self.rng)


class FedProx(FedAvg):
    """Sahu/Li et al. — FedAvg + proximal term in the client loss.
    Selection remains random (the paper notes this makes it straggler-
    sensitive)."""

    name = "fedprox"

    def proximal_mu(self) -> float:
        return self.config.fedprox_mu


class FedLesScan(Strategy):
    """The paper's strategy: tiered clustering-based selection (Alg. 2)
    + staleness-aware aggregation (Eq. 3) over a semi-async update store."""

    name = "fedlesscan"
    uses_history = True
    semi_async = True

    def select(self, client_ids, round_number):
        plan = select_clients(
            self.history, client_ids, round_number,
            self.config.max_rounds, self.config.clients_per_round, self.rng,
            ema_alpha=self.config.ema_alpha)
        self.last_plan = plan
        return plan.selected

    def aggregate(self, updates, round_number, now=None):
        # include late updates from previous rounds that have ARRIVED by
        # now (in-flight ones stay queued; aged-out ones are dropped)
        return self._staleness_merge(updates, round_number, now)


class SAFA(Strategy):
    """Wu et al. [26] — the semi-asynchronous competitor the paper
    contrasts with (§III-B): invoke ALL clients every round, close the
    round at the k-th fastest response (k = clients_per_round), cache
    slower responses for subsequent rounds.  Communication/invocation
    cost is deliberately high — that's the trade-off the paper calls out.
    """

    name = "safa"
    semi_async = True
    invoke_all = True                 # controller invokes every client

    @property
    def quorum(self) -> int:
        return self.config.clients_per_round

    def select(self, client_ids, round_number):
        return list(client_ids)

    def aggregate(self, updates, round_number, now=None):
        return self._staleness_merge(updates, round_number, now)


STRATEGIES = {cls.name: cls for cls in (FedAvg, FedProx, FedLesScan, SAFA)}


def make_strategy(name: str, config: StrategyConfig,
                  history: ClientHistoryDB, seed: int = 0) -> Strategy:
    try:
        return STRATEGIES[name](config, history, seed=seed)
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"available: {sorted(STRATEGIES)}") from None
