"""DBSCAN + Calinski–Harabasz, from scratch (no sklearn in this env).

The paper (§V-C) clusters participant clients with DBSCAN on the 2-D
feature matrix, grid-searches ε to maximise the Calinski–Harabasz index,
and treats outliers as one extra cluster.  N ≤ a few thousand clients, so
the O(N²) distance matrix is fine and deterministic.

The ε grid search is the selection hot path (it runs every round for
every FedLesScan cohort), so `cluster_clients` computes the pairwise
squared-distance matrix **once** and shares it across the whole grid
(`dbscan(..., d2=...)`), and scores every candidate labeling with a
vectorized Calinski–Harabasz (`calinski_harabasz_batch`): the total
scatter is a constant of the data, so only the between-cluster term is
computed per labeling, via per-dimension `bincount` group sums — no
per-cluster Python loop.  `calinski_harabasz` remains the scalar
reference the batch path is parity-tested against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

NOISE = -1


def pairwise_sq_dists(x: np.ndarray) -> np.ndarray:
    """(N, N) squared euclidean distances.  Uses the same broadcast
    subtraction as the scalar path (not the Gram-matrix identity) so the
    shared matrix is bit-identical to a per-call recomputation."""
    return np.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1)


def dbscan(x: np.ndarray, eps: float, min_samples: int = 2,
           d2: Optional[np.ndarray] = None) -> np.ndarray:
    """Classic DBSCAN (Ester et al., 1996). Returns labels, -1 = noise.

    Deterministic: points are visited in index order and BFS (FIFO)
    expansion walks sorted neighbour lists.  `d2` optionally supplies a
    precomputed squared-distance matrix so an ε grid search pays for it
    once.
    """
    n = x.shape[0]
    labels = np.full(n, NOISE, dtype=np.int64)
    if n == 0:
        return labels
    if d2 is None:
        d2 = pairwise_sq_dists(x)
    neigh = d2 <= eps * eps  # includes self
    core = neigh.sum(axis=1) >= min_samples

    cluster = 0
    for i in range(n):
        if labels[i] != NOISE or not core[i]:
            continue
        # start a new cluster and expand it breadth-first — whole
        # frontier at once, as boolean matrix ops.  Final labels are
        # identical to the point-at-a-time walk: every point in the
        # connected core component (plus its borders) gets this cluster
        # id, and a border point shared between clusters still goes to
        # whichever cluster the index-ordered outer loop starts first.
        labels[i] = cluster
        active = np.zeros(n, dtype=bool)
        active[i] = True
        while True:
            reach = neigh[active].any(axis=0)
            reach &= labels == NOISE
            if not reach.any():
                break
            labels[reach] = cluster
            active = reach & core
            if not active.any():
                break
        cluster += 1
    return labels


def calinski_harabasz(x: np.ndarray, labels: np.ndarray) -> float:
    """Calinski–Harabasz index (variance-ratio criterion) — scalar
    reference implementation.

    Ratio of between-cluster to within-cluster dispersion, scaled by
    (N − k)/(k − 1).  Higher is better.  Returns -inf when undefined
    (k < 2 or k == N).
    """
    uniq = np.unique(labels)
    k = len(uniq)
    n = x.shape[0]
    if k < 2 or k >= n:
        return float("-inf")
    overall = x.mean(axis=0)
    ssb = 0.0  # between-group dispersion
    ssw = 0.0  # within-group dispersion
    for lab in uniq:
        pts = x[labels == lab]
        mu = pts.mean(axis=0)
        ssb += pts.shape[0] * float(np.sum((mu - overall) ** 2))
        ssw += float(np.sum((pts - mu) ** 2))
    if ssw <= 0.0:
        return float("inf")
    return (ssb / ssw) * ((n - k) / (k - 1.0))


def calinski_harabasz_batch(x: np.ndarray,
                            labelings: np.ndarray) -> np.ndarray:
    """Vectorized CH scores for a batch of labelings (E, N) → (E,).

    Per labeling, the between-cluster dispersion is assembled from
    `bincount` group sums (vectorized over clusters and dimensions);
    the within-cluster term falls out of the total-scatter identity
    ssw = T − ssb, with T computed once for the whole batch.
    """
    labelings = np.asarray(labelings)
    n, dim = x.shape
    overall = x.mean(axis=0)
    centered = x - overall
    total = float(np.sum(centered ** 2))        # T = ssb + ssw, constant
    scores = np.empty(labelings.shape[0], dtype=np.float64)
    for e, labels in enumerate(labelings):
        _, compact = np.unique(labels, return_inverse=True)
        k = int(compact.max()) + 1 if n else 0
        if k < 2 or k >= n:
            scores[e] = float("-inf")
            continue
        counts = np.bincount(compact, minlength=k).astype(np.float64)
        sums = np.stack([np.bincount(compact, weights=centered[:, d],
                                     minlength=k) for d in range(dim)],
                        axis=1)                 # (k, dim) centered sums
        ssb = float(np.sum(sums ** 2 / counts[:, None]))
        ssw = total - ssb
        if ssw <= 0.0:
            scores[e] = float("inf")
        else:
            scores[e] = (ssb / ssw) * ((n - k) / (k - 1.0))
    return scores


@dataclass
class ClusteringResult:
    labels: np.ndarray          # outliers folded into their own cluster id
    eps: float
    score: float
    n_clusters: int
    # sketch-path extras (None on the exact path): positions of the
    # sampled sketch rows in the input and their cluster labels — lets
    # callers order clusters by sketch statistics without a second
    # full-fleet pass
    sketch_pos: Optional[np.ndarray] = None
    sketch_labels: Optional[np.ndarray] = None


def _fold_noise(labels: np.ndarray) -> np.ndarray:
    """Paper: 'for simplicity, we treat outliers as a single cluster'."""
    out = labels.copy()
    if np.any(out == NOISE):
        out[out == NOISE] = out.max() + 1
    return out


def cluster_clients(x: np.ndarray, eps_grid: Optional[Sequence[float]] = None,
                    min_samples: int = 2,
                    n_eps: int = 13) -> ClusteringResult:
    """Grid-search ε for the best Calinski–Harabasz score (paper §V-C).

    The ε grid defaults to `n_eps` quantiles of the pairwise-distance
    distribution, which adapts to the current feature scale without
    extra passes.  One shared distance matrix feeds every DBSCAN run,
    and all candidate labelings are scored in a single vectorized CH
    batch.  (`n_eps` is part of the byte-parity surface — only callers
    with no parity constraint, like the fleet-scale sketch, change it.)
    """
    n = x.shape[0]
    if n == 0:
        return ClusteringResult(np.zeros(0, np.int64), 0.0, 0.0, 0)
    if n == 1:
        return ClusteringResult(np.zeros(1, np.int64), 0.0, 0.0, 1)

    d2 = pairwise_sq_dists(x)
    if eps_grid is None:
        d = np.sqrt(d2)
        pos = d[d > 0]
        if pos.size == 0:  # all identical points → one cluster
            return ClusteringResult(np.zeros(n, np.int64), 0.0, 0.0, 1)
        eps_grid = np.unique(np.quantile(pos,
                                         np.linspace(0.05, 0.95, n_eps)))

    grid = [float(eps) for eps in eps_grid if eps > 0]
    labelings = [_fold_noise(dbscan(x, eps, min_samples, d2=d2))
                 for eps in grid]
    best: Optional[ClusteringResult] = None
    if labelings:
        scores = calinski_harabasz_batch(x, np.stack(labelings))
        for eps, labels, score in zip(grid, labelings, scores):
            cand = ClusteringResult(labels, eps, float(score),
                                    len(np.unique(labels)))
            if best is None or cand.score > best.score:
                best = cand
    if best is None or best.n_clusters < 2 or not np.isfinite(best.score):
        # degenerate data (e.g. all behaviourally identical) → one cluster
        labels = np.zeros(n, np.int64)
        return ClusteringResult(labels, float(eps_grid[-1]), 0.0, 1)
    return best


SKETCH_MAX = 2048
SKETCH_SIZE = 256
_LUT_GRID = 256


def _nearest_centroid_labels(x: np.ndarray, cents: np.ndarray,
                             grid: int = _LUT_GRID) -> np.ndarray:
    """Assign every 2-D point its nearest centroid, via a grid lookup
    table instead of a k-pass scan.

    Scores use the Gram identity: argmin ||x-c||^2 over c equals
    argmax (2x.c - ||c||^2), the ||x||^2 term being constant per point.
    A dense scan pays k passes over the fleet, and k (the sketch cluster
    count) routinely hits 10+ — so instead the bounding box is cut into
    a `grid`x`grid` lattice and each *corner* is scored.  Voronoi
    regions are convex, so a cell whose four corners agree lies entirely
    inside that label's region and the whole cell resolves by table
    lookup; only points in disagreeing (decision-boundary) cells — a
    ~k/grid fraction — get the dense scan.  Total cost is one quantize
    pass + a small-table gather, independent of k.  Exact up to points
    equidistant between two centroids (either label is a nearest
    centroid).  float32 scores and int16 labels: this only runs above
    the byte-parity scale, where results are already sample-approximate.
    """
    n, k = x.shape[0], cents.shape[0]
    if k == 1:
        return np.zeros(n, np.int16)
    two_c = np.ascontiguousarray(2.0 * cents, dtype=np.float32)
    c2 = np.sum(cents ** 2, axis=1).astype(np.float32)

    xt = np.ascontiguousarray(x.T, dtype=np.float32)   # (2, n): contiguous
    x0, x1 = xt[0], xt[1]       # rows — axis-0 min/max on the interleaved
    lo0, hi0 = float(x0.min()), float(x0.max())     # (n, 2) layout is a
    lo1, hi1 = float(x1.min()), float(x1.max())     # strided crawl
    sp0 = (hi0 - lo0) or 1.0
    sp1 = (hi1 - lo1) or 1.0

    # corner lattice scores, (k, grid+1, grid+1) — separable in x/y
    g0 = np.float32(lo0) + np.float32(sp0) * \
        np.arange(grid + 1, dtype=np.float32) / np.float32(grid)
    g1 = np.float32(lo1) + np.float32(sp1) * \
        np.arange(grid + 1, dtype=np.float32) / np.float32(grid)
    sc = (two_c[:, 0, None, None] * g0[None, :, None]
          + two_c[:, 1, None, None] * g1[None, None, :])
    sc -= c2[:, None, None]
    corner = np.argmax(sc, axis=0)                  # first-wins on ties
    nw = corner[:-1, :-1]
    ok = (nw == corner[1:, :-1]) & (nw == corner[:-1, 1:]) \
        & (nw == corner[1:, 1:])
    cell = np.where(ok, nw, -1).astype(np.int16).ravel()

    ix = x0 - np.float32(lo0)
    ix *= np.float32(grid / sp0)
    iy = x1 - np.float32(lo1)
    iy *= np.float32(grid / sp1)
    ii = ix.astype(np.int32)
    jj = iy.astype(np.int32)
    np.minimum(ii, grid - 1, out=ii)    # x == hi lands on index `grid`
    np.minimum(jj, grid - 1, out=jj)
    ii *= grid
    ii += jj
    labels = cell[ii]

    rem = np.flatnonzero(labels < 0)    # boundary cells: dense scan
    if rem.size:
        s0, s1 = x0[rem], x1[rem]
        best = two_c[0, 0] * s0 + two_c[0, 1] * s1 - c2[0]
        lab = np.zeros(rem.size, np.int16)
        for j in range(1, k):
            row = two_c[j, 0] * s0 + two_c[j, 1] * s1 - c2[j]
            lab[row > best] = j         # strict '>' keeps the first
            np.maximum(best, row, out=best)
        labels[rem] = lab
    return labels


def cluster_clients_sketch(x: np.ndarray,
                           eps_grid: Optional[Sequence[float]] = None,
                           min_samples: int = 2,
                           rng: Optional[np.random.Generator] = None,
                           sketch_max: int = SKETCH_MAX,
                           sketch_size: int = SKETCH_SIZE
                           ) -> ClusteringResult:
    """`cluster_clients` with an O(sketch²) cost cap (fleet scale).

    Up to `sketch_max` participants this IS `cluster_clients` — exact
    same labels, no RNG consumed, so small-run results stay byte-stable.
    Beyond it, the ε grid search runs on a uniform behavioural sketch of
    `sketch_size` clients (drawn from `rng`) and every remaining client
    is assigned the label of its nearest sketch-cluster centroid via the
    grid-LUT broadcast — propose latency is then independent of both
    fleet size and the sketch's cluster count.
    """
    n = x.shape[0]
    if n <= sketch_max or rng is None:
        return cluster_clients(x, eps_grid, min_samples)

    pos = rng.choice(n, size=min(sketch_size, n), replace=False)
    pos.sort()                              # keep sketch in pool order
    sketch = x[pos]
    # 7 ε candidates instead of 13: the sketch re-clusters every propose
    # on a fresh sample, so a coarser grid trades negligible ε precision
    # for ~half the DBSCAN runs of the dominant fixed cost
    res = cluster_clients(sketch, eps_grid, min_samples, n_eps=7)

    k = int(res.labels.max()) + 1
    counts = np.bincount(res.labels, minlength=k).astype(np.float64)
    cents = np.stack(
        [np.bincount(res.labels, weights=sketch[:, d], minlength=k)
         for d in range(x.shape[1])], axis=1) / counts[:, None]

    labels = _nearest_centroid_labels(x, cents)
    # n_clusters reports the centroid count: every sketch cluster is a
    # centroid, and recounting occupied labels over the full fleet would
    # cost another O(n) pass for a diagnostic field
    return ClusteringResult(labels, res.eps, res.score, k,
                            sketch_pos=pos, sketch_labels=res.labels)
