"""DBSCAN + Calinski–Harabasz, from scratch (no sklearn in this env).

The paper (§V-C) clusters participant clients with DBSCAN on the 2-D
feature matrix, grid-searches ε to maximise the Calinski–Harabasz index,
and treats outliers as one extra cluster.  N ≤ a few thousand clients, so
the O(N²) distance matrix is fine and deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

NOISE = -1


def dbscan(x: np.ndarray, eps: float, min_samples: int = 2) -> np.ndarray:
    """Classic DBSCAN (Ester et al., 1996). Returns labels, -1 = noise.

    Deterministic: points are visited in index order and BFS expansion uses
    sorted neighbour lists.
    """
    n = x.shape[0]
    labels = np.full(n, NOISE, dtype=np.int64)
    if n == 0:
        return labels
    # pairwise euclidean distances
    d2 = np.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1)
    neigh = d2 <= eps * eps  # includes self
    core = neigh.sum(axis=1) >= min_samples

    cluster = 0
    for i in range(n):
        if labels[i] != NOISE or not core[i]:
            continue
        # start a new cluster, expand via BFS over core points
        labels[i] = cluster
        frontier = [i]
        while frontier:
            p = frontier.pop()
            for q in np.nonzero(neigh[p])[0]:
                if labels[q] == NOISE:
                    labels[q] = cluster
                    if core[q]:
                        frontier.append(int(q))
        cluster += 1
    return labels


def calinski_harabasz(x: np.ndarray, labels: np.ndarray) -> float:
    """Calinski–Harabasz index (variance-ratio criterion).

    Ratio of between-cluster to within-cluster dispersion, scaled by
    (N − k)/(k − 1).  Higher is better.  Returns -inf when undefined
    (k < 2 or k == N).
    """
    uniq = np.unique(labels)
    k = len(uniq)
    n = x.shape[0]
    if k < 2 or k >= n:
        return float("-inf")
    overall = x.mean(axis=0)
    ssb = 0.0  # between-group dispersion
    ssw = 0.0  # within-group dispersion
    for lab in uniq:
        pts = x[labels == lab]
        mu = pts.mean(axis=0)
        ssb += pts.shape[0] * float(np.sum((mu - overall) ** 2))
        ssw += float(np.sum((pts - mu) ** 2))
    if ssw <= 0.0:
        return float("inf")
    return (ssb / ssw) * ((n - k) / (k - 1.0))


@dataclass
class ClusteringResult:
    labels: np.ndarray          # outliers folded into their own cluster id
    eps: float
    score: float
    n_clusters: int


def _fold_noise(labels: np.ndarray) -> np.ndarray:
    """Paper: 'for simplicity, we treat outliers as a single cluster'."""
    out = labels.copy()
    if np.any(out == NOISE):
        out[out == NOISE] = out.max() + 1
    return out


def cluster_clients(x: np.ndarray, eps_grid: Optional[Sequence[float]] = None,
                    min_samples: int = 2) -> ClusteringResult:
    """Grid-search ε for the best Calinski–Harabasz score (paper §V-C).

    The ε grid defaults to quantiles of the pairwise-distance distribution,
    which adapts to the current feature scale without extra passes.
    """
    n = x.shape[0]
    if n == 0:
        return ClusteringResult(np.zeros(0, np.int64), 0.0, 0.0, 0)
    if n == 1:
        return ClusteringResult(np.zeros(1, np.int64), 0.0, 0.0, 1)

    if eps_grid is None:
        d = np.sqrt(np.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1))
        pos = d[d > 0]
        if pos.size == 0:  # all identical points → one cluster
            return ClusteringResult(np.zeros(n, np.int64), 0.0, 0.0, 1)
        eps_grid = np.unique(np.quantile(pos, np.linspace(0.05, 0.95, 13)))

    best: Optional[ClusteringResult] = None
    for eps in eps_grid:
        if eps <= 0:
            continue
        labels = _fold_noise(dbscan(x, float(eps), min_samples))
        score = calinski_harabasz(x, labels)
        k = len(np.unique(labels))
        cand = ClusteringResult(labels, float(eps), score, k)
        if best is None or cand.score > best.score:
            best = cand
    if best is None or best.n_clusters < 2 or not np.isfinite(best.score):
        # degenerate data (e.g. all behaviourally identical) → one cluster
        labels = np.zeros(n, np.int64)
        return ClusteringResult(labels, float(eps_grid[-1]), 0.0, 1)
    return best
