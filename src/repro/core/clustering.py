"""DBSCAN + Calinski–Harabasz, from scratch (no sklearn in this env).

The paper (§V-C) clusters participant clients with DBSCAN on the 2-D
feature matrix, grid-searches ε to maximise the Calinski–Harabasz index,
and treats outliers as one extra cluster.  N ≤ a few thousand clients, so
the O(N²) distance matrix is fine and deterministic.

The ε grid search is the selection hot path (it runs every round for
every FedLesScan cohort), so `cluster_clients` computes the pairwise
squared-distance matrix **once** and shares it across the whole grid
(`dbscan(..., d2=...)`), and scores every candidate labeling with a
vectorized Calinski–Harabasz (`calinski_harabasz_batch`): the total
scatter is a constant of the data, so only the between-cluster term is
computed per labeling, via per-dimension `bincount` group sums — no
per-cluster Python loop.  `calinski_harabasz` remains the scalar
reference the batch path is parity-tested against.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

NOISE = -1


def pairwise_sq_dists(x: np.ndarray) -> np.ndarray:
    """(N, N) squared euclidean distances.  Uses the same broadcast
    subtraction as the scalar path (not the Gram-matrix identity) so the
    shared matrix is bit-identical to a per-call recomputation."""
    return np.sum((x[:, None, :] - x[None, :, :]) ** 2, axis=-1)


def dbscan(x: np.ndarray, eps: float, min_samples: int = 2,
           d2: Optional[np.ndarray] = None) -> np.ndarray:
    """Classic DBSCAN (Ester et al., 1996). Returns labels, -1 = noise.

    Deterministic: points are visited in index order and BFS (FIFO)
    expansion walks sorted neighbour lists.  `d2` optionally supplies a
    precomputed squared-distance matrix so an ε grid search pays for it
    once.
    """
    n = x.shape[0]
    labels = np.full(n, NOISE, dtype=np.int64)
    if n == 0:
        return labels
    if d2 is None:
        d2 = pairwise_sq_dists(x)
    neigh = d2 <= eps * eps  # includes self
    core = neigh.sum(axis=1) >= min_samples

    cluster = 0
    for i in range(n):
        if labels[i] != NOISE or not core[i]:
            continue
        # start a new cluster, expand via BFS over core points
        labels[i] = cluster
        frontier = deque([i])
        while frontier:
            p = frontier.popleft()
            for q in np.nonzero(neigh[p])[0]:
                if labels[q] == NOISE:
                    labels[q] = cluster
                    if core[q]:
                        frontier.append(int(q))
        cluster += 1
    return labels


def calinski_harabasz(x: np.ndarray, labels: np.ndarray) -> float:
    """Calinski–Harabasz index (variance-ratio criterion) — scalar
    reference implementation.

    Ratio of between-cluster to within-cluster dispersion, scaled by
    (N − k)/(k − 1).  Higher is better.  Returns -inf when undefined
    (k < 2 or k == N).
    """
    uniq = np.unique(labels)
    k = len(uniq)
    n = x.shape[0]
    if k < 2 or k >= n:
        return float("-inf")
    overall = x.mean(axis=0)
    ssb = 0.0  # between-group dispersion
    ssw = 0.0  # within-group dispersion
    for lab in uniq:
        pts = x[labels == lab]
        mu = pts.mean(axis=0)
        ssb += pts.shape[0] * float(np.sum((mu - overall) ** 2))
        ssw += float(np.sum((pts - mu) ** 2))
    if ssw <= 0.0:
        return float("inf")
    return (ssb / ssw) * ((n - k) / (k - 1.0))


def calinski_harabasz_batch(x: np.ndarray,
                            labelings: np.ndarray) -> np.ndarray:
    """Vectorized CH scores for a batch of labelings (E, N) → (E,).

    Per labeling, the between-cluster dispersion is assembled from
    `bincount` group sums (vectorized over clusters and dimensions);
    the within-cluster term falls out of the total-scatter identity
    ssw = T − ssb, with T computed once for the whole batch.
    """
    labelings = np.asarray(labelings)
    n, dim = x.shape
    overall = x.mean(axis=0)
    centered = x - overall
    total = float(np.sum(centered ** 2))        # T = ssb + ssw, constant
    scores = np.empty(labelings.shape[0], dtype=np.float64)
    for e, labels in enumerate(labelings):
        _, compact = np.unique(labels, return_inverse=True)
        k = int(compact.max()) + 1 if n else 0
        if k < 2 or k >= n:
            scores[e] = float("-inf")
            continue
        counts = np.bincount(compact, minlength=k).astype(np.float64)
        sums = np.stack([np.bincount(compact, weights=centered[:, d],
                                     minlength=k) for d in range(dim)],
                        axis=1)                 # (k, dim) centered sums
        ssb = float(np.sum(sums ** 2 / counts[:, None]))
        ssw = total - ssb
        if ssw <= 0.0:
            scores[e] = float("inf")
        else:
            scores[e] = (ssb / ssw) * ((n - k) / (k - 1.0))
    return scores


@dataclass
class ClusteringResult:
    labels: np.ndarray          # outliers folded into their own cluster id
    eps: float
    score: float
    n_clusters: int


def _fold_noise(labels: np.ndarray) -> np.ndarray:
    """Paper: 'for simplicity, we treat outliers as a single cluster'."""
    out = labels.copy()
    if np.any(out == NOISE):
        out[out == NOISE] = out.max() + 1
    return out


def cluster_clients(x: np.ndarray, eps_grid: Optional[Sequence[float]] = None,
                    min_samples: int = 2) -> ClusteringResult:
    """Grid-search ε for the best Calinski–Harabasz score (paper §V-C).

    The ε grid defaults to quantiles of the pairwise-distance distribution,
    which adapts to the current feature scale without extra passes.  One
    shared distance matrix feeds every DBSCAN run, and all candidate
    labelings are scored in a single vectorized CH batch.
    """
    n = x.shape[0]
    if n == 0:
        return ClusteringResult(np.zeros(0, np.int64), 0.0, 0.0, 0)
    if n == 1:
        return ClusteringResult(np.zeros(1, np.int64), 0.0, 0.0, 1)

    d2 = pairwise_sq_dists(x)
    if eps_grid is None:
        d = np.sqrt(d2)
        pos = d[d > 0]
        if pos.size == 0:  # all identical points → one cluster
            return ClusteringResult(np.zeros(n, np.int64), 0.0, 0.0, 1)
        eps_grid = np.unique(np.quantile(pos, np.linspace(0.05, 0.95, 13)))

    grid = [float(eps) for eps in eps_grid if eps > 0]
    labelings = [_fold_noise(dbscan(x, eps, min_samples, d2=d2))
                 for eps in grid]
    best: Optional[ClusteringResult] = None
    if labelings:
        scores = calinski_harabasz_batch(x, np.stack(labelings))
        for eps, labels, score in zip(grid, labelings, scores):
            cand = ClusteringResult(labels, eps, float(score),
                                    len(np.unique(labels)))
            if best is None or cand.score > best.score:
                best = cand
    if best is None or best.n_clusters < 2 or not np.isfinite(best.score):
        # degenerate data (e.g. all behaviourally identical) → one cluster
        labels = np.zeros(n, np.int64)
        return ClusteringResult(labels, float(eps_grid[-1]), 0.0, 1)
    return best
