"""Aggregation schemes — FedAvg and the paper's staleness-aware Eq. 3.

    w_{t+1} = Σ_k (t_k / t) · (n_k / n) · w^k_{t_k}

where t is the current round, t_k the round client k's update was produced
in, n_k the client dataset cardinality and n the total cardinality of the
aggregated clients.  Updates with t − t_k ≥ τ are discarded (τ = 2 in the
paper).  For t_k = t the scheme reduces exactly to FedAvg.

Updates are JAX pytrees.  `aggregate` has two paths:

  * the **flattened fast path** (default): every update is ravelled into
    one flat vector, the K vectors stacked into a (K, P) matrix, and the
    whole weighted sum dispatched as a single Pallas `fed_agg` kernel
    call (kernels/fed_agg.py — lowered to Mosaic on TPU; on CPU it runs
    through the Pallas interpreter, which validates the kernel but is
    slower than the reference path), then unravelled back to the
    original tree structure;
  * the per-leaf `tree_map` reference path, kept for validation and as
    the fallback for exotic pytrees.
"""
from __future__ import annotations

from functools import partial
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from ..analysis import gates

Pytree = Any

_KERNEL_WARNED = False


class ClientUpdate:
    """One client's local model update as stored in the parameter server.

    When the client path runs update compression (core/compress.py),
    `params` holds the server-side *decode* — the exact pytree the merge
    consumes — and the wire size travels alongside as `payload_bytes`
    (encoded) / `dense_bytes` (what the plaintext fp32 update would have
    cost).  Both stay None on the uncompressed path so dense runs are
    indistinguishable from pre-compression builds.

    On the device-resident round pipeline (core/device_batch.py) an
    update is born as a *row reference* into its group's stacked (K, P)
    matrix: ``batch``/``batch_row`` are set, the concrete pytree is NOT
    built up front, and ``params`` materializes it lazily on first
    access (trace digests, the eager parity path, checkpointed in-flight
    updates).  The merge fast paths read ``flat_params()`` instead and
    never materialize at all.  Assigning ``params`` detaches the update
    from its batch — the explicit tree becomes authoritative.
    """

    __slots__ = ("client_id", "num_samples", "round_number",
                 "training_time", "payload_bytes", "dense_bytes",
                 "dispatch_s", "batch", "batch_row", "_params")

    def __init__(self, client_id: str, params: Pytree = None,
                 num_samples: int = 0, round_number: int = 0,
                 training_time: float = 0.0,
                 payload_bytes: Optional[int] = None,
                 dense_bytes: Optional[int] = None,
                 dispatch_s: Optional[float] = None,
                 batch=None, batch_row: int = -1):
        self.client_id = client_id
        self._params = params
        self.num_samples = num_samples
        self.round_number = round_number   # t_k — round the update is for
        self.training_time = training_time
        self.payload_bytes = payload_bytes  # encoded wire size (simulated)
        self.dense_bytes = dense_bytes      # uncompressed fp32 wire size
        # wall-clock executor launch latency (telemetry; None unless the
        # executor's timing collection is on — never enters virtual time)
        self.dispatch_s = dispatch_s
        self.batch = batch                  # DeviceUpdateBatch, or None
        self.batch_row = batch_row
        if params is None and batch is None:
            raise ValueError(f"update {client_id!r} needs either concrete "
                             f"params or a device-batch row reference")

    @property
    def params(self) -> Pytree:
        if self._params is None:
            self._params = self.batch.tree(self.batch_row)
        return self._params

    @params.setter
    def params(self, value: Pytree) -> None:
        self._params = value
        self.batch = None           # the explicit tree is now authoritative
        self.batch_row = -1

    def flat_params(self) -> jnp.ndarray:
        """The flat (P,) ravel_pytree view of this update — a zero-copy
        row read on the device pipeline, a ravel otherwise."""
        if self._params is None and self.batch is not None:
            return self.batch.row(self.batch_row)
        return ravel_pytree(self.params)[0]

    def __repr__(self) -> str:
        src = (f"batch_row={self.batch_row}"
               if self._params is None else "params=<tree>")
        return (f"ClientUpdate({self.client_id!r}, {src}, "
                f"n={self.num_samples}, round={self.round_number})")


def update_to_record(update: ClientUpdate) -> dict:
    """JSON-ready metadata of one update (checkpoint surface) — the
    params pytree travels separately in the checkpoint's array store."""
    rec = {"client_id": update.client_id,
           "num_samples": update.num_samples,
           "round_number": update.round_number,
           "training_time": update.training_time}
    # only-when-set: dense checkpoints stay byte-identical to older builds
    if update.payload_bytes is not None:
        rec["payload_bytes"] = update.payload_bytes
        rec["dense_bytes"] = update.dense_bytes
    if update.dispatch_s is not None:
        rec["dispatch_s"] = update.dispatch_s
    return rec


def update_from_record(rec: dict, params: Pytree) -> ClientUpdate:
    return ClientUpdate(params=params, client_id=rec["client_id"],
                        num_samples=rec["num_samples"],
                        round_number=rec["round_number"],
                        training_time=rec.get("training_time", 0.0),
                        payload_bytes=rec.get("payload_bytes"),
                        dense_bytes=rec.get("dense_bytes"),
                        dispatch_s=rec.get("dispatch_s"))


@partial(jax.jit, static_argnums=())
def _weighted_sum(stacked: Pytree, coeffs: jnp.ndarray) -> Pytree:
    """Σ_k coeffs[k] · leaf[k] for every leaf of a stacked pytree."""
    def one(leaf):
        c = coeffs.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(c * leaf, axis=0)
    return jax.tree_util.tree_map(one, stacked)


def _stack(updates: Sequence[Pytree]) -> Pytree:
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *updates)


def fedavg_coefficients(updates: Sequence[ClientUpdate]) -> np.ndarray:
    n = float(sum(u.num_samples for u in updates)) or 1.0
    return np.array([u.num_samples / n for u in updates], dtype=np.float64)


def staleness_coefficients(updates: Sequence[ClientUpdate],
                           current_round: int) -> np.ndarray:
    """Eq. 3 coefficients (t_k/t)·(n_k/n). Round numbers are 0-based in the
    runtime, so the damping ratio uses (t_k+1)/(t+1)."""
    n = float(sum(u.num_samples for u in updates)) or 1.0
    t = float(current_round + 1)
    return np.array(
        [((u.round_number + 1) / t) * (u.num_samples / n) for u in updates],
        dtype=np.float64)


def aggregate_reference(updates: Sequence[ClientUpdate],
                        coeffs: np.ndarray) -> Pytree:
    """Per-leaf tree_map weighted sum (the validation twin)."""
    stacked = _stack([u.params for u in updates])
    return _weighted_sum(stacked, jnp.asarray(coeffs, dtype=jnp.float32))


def flat_update_matrix(updates: Sequence[ClientUpdate]
                       ) -> Tuple[jnp.ndarray, Any]:
    """(K, P) stacked flat updates + the shared ``unravel`` handle.

    Zero-copy on the device pipeline: when every update references the
    same ``DeviceUpdateBatch``, the rows are gathered straight out of
    the executor's matrix — no per-client unflatten/re-ravel.  Mixed or
    legacy updates fall back to per-update ``flat_params()`` (itself a
    row read for batch-backed members, a ravel for concrete ones).  The
    returned matrix is always a fresh device array, safe to donate to
    the aggregation kernel.
    """
    first = updates[0]
    b = getattr(first, "batch", None)
    if (b is not None
            and all(getattr(u, "batch", None) is b for u in updates)):
        return (b.gather([u.batch_row for u in updates]), b.unravel)
    if b is not None:
        # mixed cohort (e.g. straggler arrivals spanning rounds): stay on
        # flat rows — the batch already knows the layout, no need to
        # materialize first's pytree just to recover the unravel handle
        flat0, unravel = first.flat_params(), b.unravel
    else:
        flat0, unravel = ravel_pytree(first.params)
    rows = [flat0] + [u.flat_params().astype(flat0.dtype)
                      for u in updates[1:]]
    return jnp.stack(rows), unravel


def _aggregate_flat(updates: Sequence[ClientUpdate],
                    coeffs: np.ndarray, mesh=None) -> Pytree:
    """Stack K flat updates into a (K, P) matrix (a device-side gather on
    the zero-copy pipeline, a ravel+stack otherwise) and run the weighted
    sum as one Pallas kernel dispatch, then unravel the result.  With a
    `mesh` of >1 devices the dispatch shards the P dim across it
    (kernels.fed_agg_sharded)."""
    from ..kernels import fed_agg, fed_agg_sharded   # deferred: pallas

    mat, unravel = flat_update_matrix(updates)
    out_dtype = mat.dtype
    cf = jnp.asarray(coeffs, dtype=jnp.float32)
    if mesh is not None and int(mesh.size) > 1:
        out = fed_agg_sharded(mat, cf, mesh)
    else:
        # mat is a fresh stack/gather nobody retains — donate it so XLA
        # reuses the K·P buffer in place (no-op on CPU)
        out = fed_agg(mat, cf, donate=True)
    return unravel(out.astype(out_dtype))


def aggregate(updates: Sequence[ClientUpdate], coeffs: np.ndarray,
              use_kernel: Optional[bool] = None, mesh=None) -> Pytree:
    """Weighted sum Σ_k c_k · W_k over client updates."""
    if use_kernel is None:
        # call-time read (REPRO_AGG_KERNEL=0 reverts to tree_map) so a
        # per-test env flip reaches this default like every other gate
        use_kernel = gates.agg_kernel_enabled()
    if use_kernel:
        try:
            return _aggregate_flat(updates, coeffs, mesh=mesh)
        except (TypeError, ValueError) as e:
            # exotic pytrees that ravel_pytree/stack can't flatten
            global _KERNEL_WARNED
            if not _KERNEL_WARNED:
                _KERNEL_WARNED = True
                import warnings
                warnings.warn(f"fed_agg kernel path fell back to the "
                              f"tree_map reference path: {e}")
    return aggregate_reference(updates, coeffs)


def fedavg_aggregate(updates: Sequence[ClientUpdate]) -> Pytree:
    """Plain FedAvg: Σ (n_k/n) w_k."""
    if not updates:
        raise ValueError("fedavg_aggregate needs at least one update")
    return aggregate(updates, fedavg_coefficients(updates))


def staleness_aggregate(updates: Sequence[ClientUpdate], current_round: int,
                        tau: int = 2) -> Optional[Pytree]:
    """Paper Eq. 3 with max-age cutoff τ: drop updates with t − t_k ≥ τ.

    Returns None when every update was discarded (caller keeps the old
    global model for this round).
    """
    fresh = [u for u in updates if (current_round - u.round_number) < tau]
    if not fresh:
        return None
    return aggregate(fresh, staleness_coefficients(fresh, current_round))


class RunningAggregator:
    """FedLess §III-A 'running average model aggregation': accumulate
    updates one by one in O(1) memory instead of stacking all K.

    Eq. 3 factorises as (Σ_k (t_k/t)·n_k·w_k) / (Σ_k n_k), so the server
    can fold each update into a numerator/denominator pair as it arrives
    — the production path when K × model-size doesn't fit the aggregator
    function's memory (paper: 7 GB aggregation function limit).
    """

    def __init__(self, current_round: int, tau: int = 2):
        self.current_round = current_round
        self.tau = tau
        self._num: Optional[Pytree] = None
        self._den: float = 0.0
        self.accepted = 0
        self.rejected = 0

    def add(self, update: ClientUpdate) -> bool:
        """Fold one update in; returns False if discarded by τ."""
        if (self.current_round - update.round_number) >= self.tau:
            self.rejected += 1
            return False
        damp = (update.round_number + 1) / (self.current_round + 1)
        scale = jnp.float32(damp * update.num_samples)

        def fold(acc, leaf):
            return acc + scale * leaf.astype(jnp.float32)

        if self._num is None:
            self._num = jax.tree_util.tree_map(
                lambda l: scale * l.astype(jnp.float32), update.params)
        else:
            self._num = jax.tree_util.tree_map(fold, self._num,
                                               update.params)
        self._den += float(update.num_samples)
        self.accepted += 1
        return True

    def finalize(self) -> Optional[Pytree]:
        if self._num is None or self._den == 0.0:
            return None
        inv = jnp.float32(1.0 / self._den)
        return jax.tree_util.tree_map(lambda l: l * inv, self._num)


class UpdateStore:
    """Parameter-server-side store of pending client updates.

    Slow clients push updates after their round finished (semi-async);
    those stale updates are *included the next time aggregation runs*
    (paper §V-D) and dropped once older than τ.  Each update carries an
    arrival time (the client's virtual finish time): an update is only
    visible to aggregations that happen after it physically arrived —
    very slow clients therefore age across multiple rounds and τ
    genuinely discards them.
    """

    def __init__(self, tau: int = 2):
        self.tau = tau
        self._pending: List[tuple] = []   # (arrival_time, ClientUpdate)

    def push(self, update: ClientUpdate,
             arrival_time: float = 0.0) -> None:
        self._pending.append((arrival_time, update))

    def pop_for_round(self, current_round: int,
                      now: Optional[float] = None) -> List[ClientUpdate]:
        """Return fresh-enough *arrived* updates; keep future arrivals."""
        taken, kept = [], []
        for arrival, u in self._pending:
            if now is not None and arrival > now:
                kept.append((arrival, u))       # still in flight
            elif (current_round - u.round_number) < self.tau:
                taken.append(u)
            # else: aged out — dropped (paper §V-D)
        self._pending = kept
        return taken

    def __len__(self) -> int:
        return len(self._pending)

    # ---- checkpoint surface (fl/checkpointing.py) --------------------
    def state_dict(self, arrays: dict,
                   prefix: str = "strategy/pending") -> List[dict]:
        """Snapshot the pending entries; update pytrees go into `arrays`
        under `prefix`-keyed slots (the store owns its own layout — the
        strategies just forward the call)."""
        out = []
        for i, (arrival, update) in enumerate(self._pending):
            arrays[f"{prefix}/{i}"] = update.params
            rec = update_to_record(update)
            rec["arrival"] = arrival
            out.append(rec)
        return out

    def load_state_dict(self, entries: List[dict], arrays: dict,
                        prefix: str = "strategy/pending") -> None:
        self._pending = [
            (float(rec["arrival"]),
             update_from_record(rec, arrays[f"{prefix}/{i}"]))
            for i, rec in enumerate(entries)]
