"""Clustering features — paper §V-C.

trainingEma      : EMA over the client's recorded training times; a weighted
                   average that gives higher weight to recent rounds.
missedRoundEma   : EMA over (missed_round / current_round) ratios — recent
                   misses penalise more, and a given miss decays as training
                   progresses (the denominator grows).
totalEma (Eq. 2) : trainingEma + missedRoundEma * maxTrainingTime.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .history import ClientRecord


def ema_step(previous: "float | None", value: float,
             alpha: float = 0.5) -> float:
    """One EMA update: `alpha` on the new observation, seeded by the
    first value (matching `ema` over the full sequence)."""
    if previous is None:
        return float(value)
    return alpha * float(value) + (1.0 - alpha) * float(previous)


def normalize01(values: np.ndarray, mask: "np.ndarray | None" = None,
                dtype=np.float64) -> np.ndarray:
    """Min-max normalise to [0, 1] over the entries selected by `mask`
    (all by default); constant input maps to 0.0, unselected entries to
    the midpoint 0.5 (a neutral prior for clients without data).
    `dtype` lets fleet-scale callers run the passes in float32; the
    float64 default is bit-stable with the historical implementation."""
    values = np.asarray(values, dtype=dtype)
    if mask is None:                    # no gather copies on the hot path
        if values.size == 0:
            return np.full(values.shape, 0.5, dtype=dtype)
        lo, hi = float(values.min()), float(values.max())
        if hi <= lo:
            return np.zeros(values.shape, dtype=dtype)
        return (values - lo) / (hi - lo)
    out = np.full(values.shape, 0.5, dtype=dtype)
    sel = np.asarray(mask)
    if not np.any(sel):
        return out
    vsel = values[sel]
    lo, hi = float(vsel.min()), float(vsel.max())
    out[sel] = 0.0 if hi <= lo else (vsel - lo) / (hi - lo)
    return out


def ema(values: Sequence[float], alpha: float = 0.5) -> float:
    """Exponential moving average, most-recent-last.

    alpha is the smoothing factor applied to the newest observation; the
    paper uses an (unspecified-parameter) EMA, we default to 0.5 which
    half-lives one round.
    """
    if len(values) == 0:
        return 0.0
    acc = float(values[0])
    for v in values[1:]:
        acc = alpha * float(v) + (1.0 - alpha) * acc
    return acc


def training_ema(rec: ClientRecord, alpha: float = 0.5) -> float:
    return ema(rec.training_times, alpha)


def missed_round_ema(rec: ClientRecord, current_round: int,
                     alpha: float = 0.5) -> float:
    """EMA over missed-round ratios (paper §V-C).

    Each missed round number is divided by the current round number, so the
    penalty of a specific miss decreases as training progresses.
    """
    if current_round <= 0 or not rec.missed_rounds:
        return 0.0
    ratios = [min(1.0, (m + 1) / (current_round + 1))
              for m in sorted(rec.missed_rounds)]
    return ema(ratios, alpha)


def total_ema(rec: ClientRecord, current_round: int,
              max_training_time: float, alpha: float = 0.5) -> float:
    """Eq. 2: totalEma = trainingEma + missedRoundEma * maxTrainingTime."""
    return (training_ema(rec, alpha)
            + missed_round_ema(rec, current_round, alpha) * max_training_time)


def feature_matrix(records: Sequence[ClientRecord], current_round: int,
                   alpha: float = 0.5) -> np.ndarray:
    """(N, 2) clustering features: [trainingEma, missedRoundEma·maxT].

    maxTrainingTime is taken over the participating records (so the missed-
    round penalty is commensurate with the training-time scale), matching
    Eq. 2's scaling.
    """
    if not records:
        return np.zeros((0, 2), dtype=np.float64)
    t_emas = np.array([training_ema(r, alpha) for r in records])
    max_t = float(np.max([max(r.training_times) if r.training_times else 0.0
                          for r in records])) or 1.0
    m_emas = np.array(
        [missed_round_ema(r, current_round, alpha) for r in records])
    return np.stack([t_emas, m_emas * max_t], axis=1)


# ---------------------------------------------------------------------------
# Vectorized path over the array-backed history store.
#
# The recurrences below run the *same* IEEE-754 operation sequence as the
# scalar reference above, just batched across clients (pad + mask instead of
# ragged loops), so the results are bit-identical — the store-parity gate in
# tests/test_fleet_scale.py depends on this.
# ---------------------------------------------------------------------------

def pad_ragged(lists: Sequence[Sequence[float]], fill: float = 0.0):
    """(values, lengths): ragged lists padded into an (N, Lmax) float64
    matrix.  Cost is O(total observations), not O(fleet)."""
    lengths = np.fromiter((len(v) for v in lists), np.int64, len(lists))
    width = int(lengths.max()) if lengths.size else 0
    values = np.full((len(lists), width), fill, np.float64)
    for i, vs in enumerate(lists):
        if vs:
            values[i, :len(vs)] = vs
    return values, lengths


def batched_ema(values: np.ndarray, lengths: np.ndarray,
                alpha: float = 0.5) -> np.ndarray:
    """Row-wise `ema` over padded rows; empty rows → 0.0.

    Iterates over *columns* (sequence length ≈ #rounds), vectorized over
    rows (#clients) — and each step applies exactly
    ``alpha * v + (1 - alpha) * acc`` like the scalar loop.
    """
    n, width = values.shape
    if width == 0:
        return np.zeros(n, np.float64)
    acc = np.where(lengths > 0, values[:, 0], 0.0)
    one_minus = 1.0 - alpha
    for j in range(1, width):
        step = alpha * values[:, j] + one_minus * acc
        acc = np.where(j < lengths, step, acc)
    return acc


def batched_missed_round_ema(missed: Sequence[Sequence[int]],
                             current_round: int,
                             alpha: float = 0.5) -> np.ndarray:
    """Vectorized `missed_round_ema` over ragged missed-round lists."""
    n = len(missed)
    if current_round <= 0 or n == 0:
        return np.zeros(n, np.float64)
    values, lengths = pad_ragged(missed, fill=np.inf)
    if values.shape[1] == 0:
        return np.zeros(n, np.float64)
    values.sort(axis=1)                  # per-row sorted; inf pads sink right
    np.putmask(values, ~np.isfinite(values), 0.0)
    ratios = np.minimum(1.0, (values + 1.0) / float(current_round + 1))
    return batched_ema(ratios, lengths, alpha)


def _store_t_emas(db, idx: np.ndarray, alpha: float,
                  dtype=np.float64) -> np.ndarray:
    """Training-time EMAs for store rows — the maintained `_t_ema`
    column when `alpha` matches the store's smoothing factor (an O(|idx|)
    gather), else the ragged recompute.  Both paths are bit-identical.
    `dtype=float32` gathers the store's downcast shadow column."""
    pre = (db.t_ema_of(idx, alpha, dtype)
           if hasattr(db, "t_ema_of") else None)
    if pre is not None:
        return pre
    t_vals, t_lens = pad_ragged(db.ragged_times(idx))
    return batched_ema(t_vals, t_lens, alpha)


def _store_missed_emas(db, idx: np.ndarray, current_round: int,
                       alpha: float) -> np.ndarray:
    """Missed-round EMAs for store rows — must be recomputed per propose
    (the ratios depend on `current_round`), but off the store's dense
    inf-padded matrix instead of N ragged Python lists when possible.
    Returns None to mean "identically zero" (no selected row has any
    missed round) so callers can skip the zero-array passes."""
    if current_round <= 0 or idx.size == 0:
        return None
    dense = db.missed_matrix(idx) if hasattr(db, "missed_matrix") else None
    if dense is None:
        return batched_missed_round_ema(db.ragged_missed(idx),
                                        current_round, alpha)
    values, lengths = dense
    if values.shape[1] == 0:
        return None
    values.sort(axis=1)                  # fancy-index copy: safe in place
    np.putmask(values, ~np.isfinite(values), 0.0)
    ratios = np.minimum(1.0, (values + 1.0) / float(current_round + 1))
    return batched_ema(ratios, lengths, alpha)


def feature_matrix_from_store(db, idx: np.ndarray, current_round: int,
                              alpha: float = 0.5,
                              dtype=np.float64,
                              max_t: "float | None" = None) -> np.ndarray:
    """`feature_matrix` computed straight off a `ClientHistoryDB`'s arrays
    for the rows in `idx` — bit-identical to the record-based path at the
    float64 default.  Fleet-scale callers pass float32: the matrix only
    feeds the sketch clusterer there, and halving its footprint halves
    the bandwidth of every downstream pass.  `max_t` lets a caller that
    already knows max(t_max[idx]) — or can compute it more cheaply, via
    a thunk — supply it; it must equal that max exactly.  It is only
    evaluated when some selected row has missed a round (the zero
    missed-EMA column never scales)."""
    if idx.size == 0:
        return np.zeros((0, 2), dtype=dtype)
    t_emas = _store_t_emas(db, idx, alpha, dtype)
    m_emas = _store_missed_emas(db, idx, current_round, alpha)
    if m_emas is not None:
        if callable(max_t):
            max_t = max_t()
        elif max_t is None:
            max_t = float(db.t_max_of(idx).max()) or 1.0
    if dtype == np.float64:
        col1 = (np.zeros(idx.size, np.float64) if m_emas is None
                else m_emas * max_t)    # 0·max_t == 0: same bits
        return np.stack([t_emas, col1], axis=1)
    out = np.empty((idx.size, 2), dtype=dtype)
    out[:, 0] = t_emas
    if m_emas is None:
        out[:, 1] = 0.0
    else:
        out[:, 1] = m_emas * max_t
    return out


def total_ema_from_store(db, idx: np.ndarray, current_round: int,
                         max_training_time: float,
                         alpha: float = 0.5) -> np.ndarray:
    """Vectorized Eq. 2 over store rows `idx`."""
    if idx.size == 0:
        return np.zeros(0, np.float64)
    t_emas = _store_t_emas(db, idx, alpha)
    m_emas = _store_missed_emas(db, idx, current_round, alpha)
    if m_emas is None:
        return t_emas                   # t + 0·max ≡ t: same bits
    return t_emas + m_emas * max_training_time
