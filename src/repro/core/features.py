"""Clustering features — paper §V-C.

trainingEma      : EMA over the client's recorded training times; a weighted
                   average that gives higher weight to recent rounds.
missedRoundEma   : EMA over (missed_round / current_round) ratios — recent
                   misses penalise more, and a given miss decays as training
                   progresses (the denominator grows).
totalEma (Eq. 2) : trainingEma + missedRoundEma * maxTrainingTime.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .history import ClientRecord


def ema_step(previous: "float | None", value: float,
             alpha: float = 0.5) -> float:
    """One EMA update: `alpha` on the new observation, seeded by the
    first value (matching `ema` over the full sequence)."""
    if previous is None:
        return float(value)
    return alpha * float(value) + (1.0 - alpha) * float(previous)


def normalize01(values: np.ndarray, mask: "np.ndarray | None" = None
                ) -> np.ndarray:
    """Min-max normalise to [0, 1] over the entries selected by `mask`
    (all by default); constant input maps to 0.0, unselected entries to
    the midpoint 0.5 (a neutral prior for clients without data)."""
    values = np.asarray(values, dtype=np.float64)
    out = np.full(values.shape, 0.5, dtype=np.float64)
    sel = np.ones(values.shape, bool) if mask is None else np.asarray(mask)
    if not np.any(sel):
        return out
    lo, hi = float(values[sel].min()), float(values[sel].max())
    out[sel] = 0.0 if hi <= lo else (values[sel] - lo) / (hi - lo)
    return out


def ema(values: Sequence[float], alpha: float = 0.5) -> float:
    """Exponential moving average, most-recent-last.

    alpha is the smoothing factor applied to the newest observation; the
    paper uses an (unspecified-parameter) EMA, we default to 0.5 which
    half-lives one round.
    """
    if len(values) == 0:
        return 0.0
    acc = float(values[0])
    for v in values[1:]:
        acc = alpha * float(v) + (1.0 - alpha) * acc
    return acc


def training_ema(rec: ClientRecord, alpha: float = 0.5) -> float:
    return ema(rec.training_times, alpha)


def missed_round_ema(rec: ClientRecord, current_round: int,
                     alpha: float = 0.5) -> float:
    """EMA over missed-round ratios (paper §V-C).

    Each missed round number is divided by the current round number, so the
    penalty of a specific miss decreases as training progresses.
    """
    if current_round <= 0 or not rec.missed_rounds:
        return 0.0
    ratios = [min(1.0, (m + 1) / (current_round + 1))
              for m in sorted(rec.missed_rounds)]
    return ema(ratios, alpha)


def total_ema(rec: ClientRecord, current_round: int,
              max_training_time: float, alpha: float = 0.5) -> float:
    """Eq. 2: totalEma = trainingEma + missedRoundEma * maxTrainingTime."""
    return (training_ema(rec, alpha)
            + missed_round_ema(rec, current_round, alpha) * max_training_time)


def feature_matrix(records: Sequence[ClientRecord], current_round: int,
                   alpha: float = 0.5) -> np.ndarray:
    """(N, 2) clustering features: [trainingEma, missedRoundEma·maxT].

    maxTrainingTime is taken over the participating records (so the missed-
    round penalty is commensurate with the training-time scale), matching
    Eq. 2's scaling.
    """
    if not records:
        return np.zeros((0, 2), dtype=np.float64)
    t_emas = np.array([training_ema(r, alpha) for r in records])
    max_t = float(np.max([max(r.training_times) if r.training_times else 0.0
                          for r in records])) or 1.0
    m_emas = np.array(
        [missed_round_ema(r, current_round, alpha) for r in records])
    return np.stack([t_emas, m_emas * max_t], axis=1)
