"""Client selection — paper Algorithm 2 (§V-C), vectorized.

Priority: rookies → clustered participants (sorted clusters, progress-offset
start) → stragglers.  Selection is deterministic given the RNG seed.

Every step is a single pass over the array-backed history store — tier
predicates are boolean masks, Eq. 2 scores come from the batched EMA
kernels in core/features.py, and the per-cluster "least-invoked first"
pick is an `argpartition` over a composite integer key
(`invocations * (N+1) + lex_rank(client_id)`), which orders exactly like
the reference `sorted(members, key=(invocations, client_id))` because
lexicographic ranks are order-isomorphic to the id strings.  RNG draws
use `rng.choice(n, ...)` index form, which consumes the identical stream
as the legacy `rng.choice(list_of_ids, ...)` calls — same-seed cohorts
are byte-identical to the dict-backed implementation
(tests/test_fleet_scale.py gates this against golden traces).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from .clustering import SKETCH_MAX, cluster_clients_sketch
from .features import feature_matrix_from_store
from .history import ClientHistoryDB


@dataclass
class SelectionPlan:
    selected: List[str]
    rookies: List[str]
    cluster_clients: List[str]
    straggler_clients: List[str]
    n_clusters: int
    eps: float


def select_clients(history: ClientHistoryDB, client_ids: Sequence[str],
                   round_number: int, max_rounds: int,
                   clients_per_round: int, rng: np.random.Generator,
                   ema_alpha: float = 0.5,
                   exclude=frozenset()) -> SelectionPlan:
    """Algorithm 2 of the paper.  `exclude` drops in-flight clients from
    the pool (vectorized — pool order preserved, exactly as if the
    caller had passed a pre-filtered id list)."""
    if not hasattr(client_ids, "__len__"):
        client_ids = list(client_ids)
    idx = history.indices_for(client_ids)
    if exclude:
        lookup = history.interner.lookup
        ex = np.fromiter((lookup(c) for c in exclude), np.int64,
                         len(exclude))
        ex = ex[ex >= 0]
        if ex.size:
            idx = idx[~np.isin(idx, ex)]
    full = history.is_full_pool(idx)
    rookie_m, part_m, strag_m = history.tier_masks(idx, full_pool=full)
    if full:
        # idx is the identity permutation: mask positions ARE the store
        # indices, so flatnonzero replaces the fancy-index gathers.  The
        # straggler tier stays a lazy count — it is only materialized
        # when rookies + participants cannot fill the round, which never
        # happens at fleet scale.
        rookie_idx = np.flatnonzero(rookie_m)
        part_idx = np.flatnonzero(part_m)
        strag_idx = None
        n_strag = int(np.count_nonzero(strag_m))
    else:
        rookie_idx = idx[rookie_m]
        part_idx = idx[part_m]
        strag_idx = idx[strag_m]
        n_strag = strag_idx.size

    # Lines 3-5: rookies first — guarantees every client contributes once
    # and seeds behavioural data for future clustering.
    if rookie_idx.size >= clients_per_round:
        pos = rng.choice(rookie_idx.size, size=clients_per_round,
                         replace=False)
        chosen = history.ids_of(rookie_idx[pos])
        return SelectionPlan(chosen, chosen, [], [], 0, 0.0)

    selected_rookies = history.ids_of(rookie_idx)
    remaining = clients_per_round - len(selected_rookies)

    # Lines 6-8: how many we need from tiers 2 and 3. Stragglers are only
    # used when rookies+participants cannot fill the round.
    n_cluster_clients = min(remaining, part_idx.size)
    n_straggler_clients = min(remaining - n_cluster_clients, n_strag)
    selected_stragglers: List[str] = []
    if n_straggler_clients > 0:
        if strag_idx is None:
            strag_idx = np.flatnonzero(strag_m)
        pos = rng.choice(strag_idx.size, size=n_straggler_clients,
                         replace=False)
        selected_stragglers = history.ids_of(strag_idx[pos])

    # Lines 9-17: cluster participants on (trainingEma, missedRoundEma·maxT).
    selected_cluster: List[str] = []
    n_clusters, eps = 0, 0.0
    if n_cluster_clients > 0:
        big = part_idx.size > SKETCH_MAX
        # full pool → masked max over t_max in place of an O(|part|)
        # gather-then-reduce (same float; tier_masks guarantees part_m
        # positions are store rows there).  Passed as a thunk: max_t
        # only matters when some participant has missed a round, and
        # the feature builder skips the whole pass otherwise.
        mt = ((lambda: history.t_max_masked(part_m) or 1.0)
              if full else None)
        feats = feature_matrix_from_store(
            history, part_idx, round_number, alpha=ema_alpha,
            dtype=np.float32 if big else np.float64, max_t=mt)
        result = cluster_clients_sketch(feats, rng=rng)
        n_clusters, eps = result.n_clusters, result.eps
        labels = result.labels
        if result.sketch_labels is not None:
            # sketch path (no byte-parity constraint — the exact path
            # covers ≤ SKETCH_MAX): order clusters by the mean Eq. 2
            # total of their *sketch* members, an unbiased estimate of
            # the full-fleet mean that avoids a bincount over 10^6 rows
            sk = feats[result.sketch_pos]
            sk_tot = (sk[:, 0] + sk[:, 1]).astype(np.float64)
            k = int(result.sketch_labels.max()) + 1
            counts = np.bincount(result.sketch_labels, minlength=k)
            sums = np.bincount(result.sketch_labels, weights=sk_tot,
                               minlength=k)
            mean_arr = sums / counts    # every label occurs in its sketch
            order = [int(i) for i in np.argsort(mean_arr, kind="stable")]
        else:
            # Sort clusters by ascending mean totalEma (Eq. 2) of their
            # members.  feats already holds [trainingEma, missedEma·maxT]
            # with the same maxT, so the Eq. 2 sum reuses it
            # bit-identically instead of recomputing both EMA passes.
            totals = feats[:, 0] + feats[:, 1]
            uniq, first = np.unique(labels, return_index=True)
            first_seen = uniq[np.argsort(first)]    # first-occurrence order
            means = {int(lab): float(np.mean(totals[labels == lab]))
                     for lab in first_seen}
            order = sorted(means, key=means.__getitem__)  # stable on ties

        # Start from the cluster matching current training progress and wrap
        # (avoids always draining the fastest cluster; paper §V-C).
        progress = (0.0 if max_rounds <= 0
                    else min(1.0, round_number / max_rounds))
        start = int(progress * len(order)) % len(order)
        rotated = order[start:] + order[:start]

        # Prefer least-invoked members → balanced contributions (§VI-B);
        # client-id tiebreak via lexicographic ranks keeps the key integral.
        # Keys are gathered per drained cluster — the rotated loop usually
        # stops after one or two clusters, so building the composite key
        # for the whole participant tier would be mostly wasted work.
        lex = history.interner.lex_ranks()
        stride = np.int64(len(history.interner) + 1)

        need = n_cluster_clients
        for lab in rotated:
            if need <= 0:
                break
            members = part_idx[labels == lab]
            mkey = history.invocations_of(members) * stride + lex[members]
            if members.size <= need:
                take = members[np.argsort(mkey)]
            else:
                head = np.argpartition(mkey, need - 1)[:need]
                take = members[head[np.argsort(mkey[head])]]
            selected_cluster.extend(history.ids_of(take))
            need -= take.size

    selected = selected_rookies + selected_cluster + selected_stragglers
    return SelectionPlan(selected, selected_rookies, selected_cluster,
                         selected_stragglers, n_clusters, eps)


def select_random(client_ids: Sequence[str], clients_per_round: int,
                  rng: np.random.Generator) -> List[str]:
    """FedAvg/FedProx client selection: uniform random sample."""
    if not hasattr(client_ids, "__len__"):
        client_ids = list(client_ids)
    k = min(clients_per_round, len(client_ids))
    pos = rng.choice(len(client_ids), size=k, replace=False)
    return [client_ids[int(i)] for i in pos]
