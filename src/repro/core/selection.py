"""Client selection — paper Algorithm 2 (§V-C).

Priority: rookies → clustered participants (sorted clusters, progress-offset
start) → stragglers.  Selection is deterministic given the RNG seed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from .clustering import cluster_clients
from .features import feature_matrix, total_ema
from .history import ClientHistoryDB, ClientRecord


@dataclass
class SelectionPlan:
    selected: List[str]
    rookies: List[str]
    cluster_clients: List[str]
    straggler_clients: List[str]
    n_clusters: int
    eps: float


def select_clients(history: ClientHistoryDB, client_ids: Sequence[str],
                   round_number: int, max_rounds: int,
                   clients_per_round: int, rng: np.random.Generator,
                   ema_alpha: float = 0.5) -> SelectionPlan:
    """Algorithm 2 of the paper."""
    rookies, participants, stragglers = history.partition(client_ids)

    # Lines 3-5: rookies first — guarantees every client contributes once
    # and seeds behavioural data for future clustering.
    if len(rookies) >= clients_per_round:
        chosen = list(rng.choice([r.client_id for r in rookies],
                                 size=clients_per_round, replace=False))
        return SelectionPlan(chosen, chosen, [], [], 0, 0.0)

    selected_rookies = [r.client_id for r in rookies]
    remaining = clients_per_round - len(selected_rookies)

    # Lines 6-8: how many we need from tiers 2 and 3. Stragglers are only
    # used when rookies+participants cannot fill the round.
    n_cluster_clients = min(remaining, len(participants))
    n_straggler_clients = min(remaining - n_cluster_clients, len(stragglers))
    straggler_ids = [s.client_id for s in stragglers]
    selected_stragglers = (
        list(rng.choice(straggler_ids, size=n_straggler_clients,
                        replace=False))
        if n_straggler_clients > 0 else [])

    # Lines 9-17: cluster participants on (trainingEma, missedRoundEma·maxT).
    selected_cluster: List[str] = []
    n_clusters, eps = 0, 0.0
    if n_cluster_clients > 0:
        feats = feature_matrix(participants, round_number, alpha=ema_alpha)
        result = cluster_clients(feats)
        n_clusters, eps = result.n_clusters, result.eps

        # Sort clusters by ascending mean totalEma (Eq. 2) of their members.
        max_t = float(max((max(p.training_times) if p.training_times else 0.0)
                          for p in participants)) or 1.0
        by_label = {}
        for rec, lab in zip(participants, result.labels):
            by_label.setdefault(int(lab), []).append(rec)
        order = sorted(
            by_label,
            key=lambda lab: float(np.mean([
                total_ema(r, round_number, max_t, ema_alpha)
                for r in by_label[lab]])))

        # Start from the cluster matching current training progress and wrap
        # (avoids always draining the fastest cluster; paper §V-C).
        progress = 0.0 if max_rounds <= 0 else min(1.0, round_number / max_rounds)
        start = int(progress * len(order)) % len(order)
        rotated = order[start:] + order[:start]

        need = n_cluster_clients
        for lab in rotated:
            if need <= 0:
                break
            members = by_label[lab]
            # Prefer least-invoked members → balanced contributions (§VI-B).
            members = sorted(members, key=lambda r: (r.invocations, r.client_id))
            take = members[:need]
            selected_cluster.extend(r.client_id for r in take)
            need -= len(take)

    selected = selected_rookies + selected_cluster + selected_stragglers
    return SelectionPlan(selected, selected_rookies, selected_cluster,
                         selected_stragglers, n_clusters, eps)


def select_random(client_ids: Sequence[str], clients_per_round: int,
                  rng: np.random.Generator) -> List[str]:
    """FedAvg/FedProx client selection: uniform random sample."""
    k = min(clients_per_round, len(client_ids))
    return list(rng.choice(list(client_ids), size=k, replace=False))
