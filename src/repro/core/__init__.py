"""FedLesScan core: client history, clustering, selection, aggregation."""
from .aggregation import (ClientUpdate, RunningAggregator, UpdateStore,
                          fedavg_aggregate,
                          fedavg_coefficients, flat_update_matrix,
                          staleness_aggregate, staleness_coefficients)
from .device_batch import (DeviceUpdateBatch, pipeline_enabled,
                           reset_transfer_stats, transfer_stats)
from .clustering import (ClusteringResult, calinski_harabasz,
                         calinski_harabasz_batch, cluster_clients, dbscan,
                         pairwise_sq_dists)
from .features import (ema, ema_step, feature_matrix, missed_round_ema,
                       normalize01, total_ema, training_ema)
from .history import ClientHistoryDB, ClientRecord
from .merge import SERVER_OPTS, MergePipeline, ServerOptConfig
from .selection import SelectionPlan, select_clients, select_random
from .strategies import (STRATEGIES, FedAsync, FedAvg, FedBuff, FedLesScan,
                         FedProx, Strategy, StrategyConfig, make_strategy)

__all__ = [
    "ClientUpdate", "RunningAggregator", "UpdateStore", "fedavg_aggregate", "fedavg_coefficients",
    "staleness_aggregate", "staleness_coefficients", "ClusteringResult",
    "calinski_harabasz", "calinski_harabasz_batch", "cluster_clients",
    "dbscan", "pairwise_sq_dists", "ema", "ema_step", "feature_matrix",
    "missed_round_ema", "normalize01", "total_ema", "training_ema", "ClientHistoryDB",
    "ClientRecord", "SelectionPlan", "select_clients", "select_random",
    "STRATEGIES", "FedAsync", "FedAvg", "FedBuff", "FedLesScan", "FedProx",
    "Strategy", "StrategyConfig", "make_strategy",
    "SERVER_OPTS", "MergePipeline", "ServerOptConfig",
    "DeviceUpdateBatch", "pipeline_enabled", "transfer_stats",
    "reset_transfer_stats", "flat_update_matrix",
]
