"""FedLesScan core: client history, clustering, selection, aggregation."""
from .aggregation import (ClientUpdate, RunningAggregator, UpdateStore,
                          fedavg_aggregate,
                          fedavg_coefficients, staleness_aggregate,
                          staleness_coefficients)
from .clustering import ClusteringResult, calinski_harabasz, cluster_clients, dbscan
from .features import ema, feature_matrix, missed_round_ema, total_ema, training_ema
from .history import ClientHistoryDB, ClientRecord
from .selection import SelectionPlan, select_clients, select_random
from .strategies import (STRATEGIES, FedAsync, FedAvg, FedBuff, FedLesScan,
                         FedProx, Strategy, StrategyConfig, make_strategy)

__all__ = [
    "ClientUpdate", "RunningAggregator", "UpdateStore", "fedavg_aggregate", "fedavg_coefficients",
    "staleness_aggregate", "staleness_coefficients", "ClusteringResult",
    "calinski_harabasz", "cluster_clients", "dbscan", "ema", "feature_matrix",
    "missed_round_ema", "total_ema", "training_ema", "ClientHistoryDB",
    "ClientRecord", "SelectionPlan", "select_clients", "select_random",
    "STRATEGIES", "FedAsync", "FedAvg", "FedBuff", "FedLesScan", "FedProx",
    "Strategy", "StrategyConfig", "make_strategy",
]
